//! END-TO-END driver (the required real-workload proof): a CHOPT session
//! tunes lr / momentum / random-erasing prob+sh for the residual-MLP
//! image classifier with **real PJRT training** — the AOT-compiled
//! fwd+bwd+SGD `train_step` HLO executes on the CPU PJRT client for every
//! epoch; Python never runs.
//!
//! Logs per-session loss curves to reports/image_classification/ and
//! prints the leaderboard.  Recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example image_classification

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::runtime::Manifest;
use chopt::trainer::real::RealTrainer;
use chopt::trainer::Trainer;
use chopt::viz;

const CONFIG: &str = r#"{
  "h_params": {
    "lr": {"parameters": [0.01, 0.15], "distribution": "log_uniform",
           "type": "float", "p_range": [0.001, 0.3]},
    "momentum": {"parameters": [0.5, 0.99], "distribution": "uniform",
           "type": "float", "p_range": [0.0, 0.999]},
    "prob": {"parameters": [0.0, 0.6], "distribution": "uniform",
           "type": "float", "p_range": [0.0, 0.9]},
    "sh": {"parameters": [0.2, 0.6], "distribution": "uniform",
           "type": "float", "p_range": [0.1, 0.9]}
  },
  "measure": "test/accuracy",
  "order": "descending",
  "step": 4,
  "population": 6,
  "tune": {"pbt": {"exploit": "truncation", "explore": "perturb"}},
  "termination": {"max_session_number": 14},
  "model": "ic_d2_w1",
  "max_epochs": 24,
  "max_gpus": 6,
  "seed": 3
}"#;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cfg = ChoptConfig::from_json_str(CONFIG)?;
    let order = cfg.order;
    println!("== image classification (REAL PJRT training, variant ic_d2_w1) ==");
    println!("PBT population 6, step 4, 14 models, 24 epochs each");
    let t0 = std::time::Instant::now();

    let outcome = run_sim(SimSetup::single(cfg, 6), |id| {
        Box::new(RealTrainer::new(Manifest::default_dir(), 500 + id).expect("runtime"))
            as Box<dyn Trainer>
    });

    let agent = &outcome.agents[0];
    let sessions: Vec<_> = agent.sessions.values().cloned().collect();
    viz::report::outcome_table(agent).print();
    viz::report::leaderboard_table(&sessions, order, 8).print();

    // Loss curves (the "scalar plot view").
    std::fs::create_dir_all("reports/image_classification")?;
    let curves = viz::export::curves_doc(&sessions);
    std::fs::write(
        "reports/image_classification/curves.json",
        curves.to_string_pretty(),
    )?;
    println!("\nper-session loss curves:");
    let mut by_id: Vec<_> = sessions.iter().collect();
    by_id.sort_by_key(|s| s.id);
    for s in by_id.iter().take(6) {
        let curve: Vec<String> = s
            .history
            .iter()
            .map(|p| format!("e{}:{:.3}", p.epoch, p.loss))
            .collect();
        println!("  {}  [{}]  {}", s.id, curve.join(" "), s.hparams.render());
    }

    let (sid, best) = agent.best().expect("best exists");
    println!(
        "\nbest model {sid}: eval accuracy {best:.2}% ({} epochs) hparams: {}",
        agent.sessions[&sid].epochs,
        agent.sessions[&sid].hparams.render()
    );
    // Loss must actually have decreased for the best model (real learning).
    let hist = &agent.sessions[&sid].history;
    let first_loss = hist.first().map(|p| p.loss).unwrap_or(f64::NAN);
    let last_loss = hist.last().map(|p| p.loss).unwrap_or(f64::NAN);
    println!("best-model train loss: {first_loss:.3} -> {last_loss:.3}");
    assert!(
        last_loss < first_loss,
        "training must reduce loss end-to-end"
    );
    println!(
        "wall time {:.1}s, exports in reports/image_classification/",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
