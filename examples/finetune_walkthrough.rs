//! The paper's §4 practical use case: six sequential CHOPT sessions that
//! incrementally fine-tune ResNet+RandomErasing on CIFAR-100-like data
//! (surrogate), following the Fig. 6 usage flow:
//!
//!   1. tune lr                      (ES on)
//!   2. narrowed lr + momentum       (ES on)
//!   3. + prob                       (ES on)
//!   4. + sh                         (ES on)
//!   5. + depth                      (ES on)   <- biased by early stopping
//!   6. same as 5                    (ES OFF)  <- recovers deep models
//!
//! After each session the top-10 models narrow the ranges
//! (`analysis::narrow_config`) and a new axis is appended
//! (`analysis::append_param`) — exactly the paper's Table-1 progression.
//! Produces the Fig. 3/4/5/7 artifacts under reports/finetune/.
//!
//!     cargo run --release --example finetune_walkthrough

use std::collections::HashSet;

use chopt::analysis;
use chopt::config::{ChoptConfig, Order};
use chopt::coordinator::{run_sim, SimSetup};
use chopt::hparam::{Dist, ParamDef, ParamType, Value};
use chopt::nsml::NsmlSession;
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::util::bench::Table;
use chopt::viz;

fn base_config() -> ChoptConfig {
    let text = r#"{
      "h_params": {
        "lr": {"parameters": [0.001, 0.2], "distribution": "log_uniform",
               "type": "float", "p_range": [0.0005, 0.5]}
      },
      "measure": "test/accuracy",
      "order": "descending",
      "step": 7,
      "population": 5,
      "tune": {"random": {}},
      "termination": {"max_session_number": 40},
      "model": "surrogate:resnet_re",
      "max_epochs": 300,
      "max_gpus": 5,
      "seed": 42
    }"#;
    ChoptConfig::from_json_str(text).unwrap()
}

fn fdef(name: &str, lo: f64, hi: f64, p_lo: f64, p_hi: f64) -> ParamDef {
    ParamDef {
        name: name.into(),
        ptype: ParamType::Float,
        dist: Dist::Uniform,
        parameters: vec![Value::Float(lo), Value::Float(hi)],
        p_range: vec![p_lo, p_hi],
    }
}

fn depth_def() -> ParamDef {
    ParamDef {
        name: "depth".into(),
        ptype: ParamType::Int,
        dist: Dist::Categorical,
        parameters: [20, 92, 110, 122, 134, 140]
            .iter()
            .map(|&d| Value::Int(d))
            .collect(),
        p_range: vec![],
    }
}

fn run_one(cfg: ChoptConfig, seed: u64) -> (Vec<NsmlSession>, f64) {
    let outcome = run_sim(SimSetup::single(cfg, 8), move |id| {
        Box::new(SurrogateTrainer::new(seed * 100 + id)) as Box<dyn Trainer>
    });
    let agent = &outcome.agents[0];
    let best = agent.best().map(|(_, m)| m).unwrap_or(f64::NAN);
    (agent.sessions.values().cloned().collect(), best)
}

fn main() -> anyhow::Result<()> {
    let order = Order::Descending;
    let mut cfg = base_config();
    let mut runs: Vec<(String, Vec<NsmlSession>)> = Vec::new();
    let mut table = Table::new(
        "Table 1 progression: fine tuning per session",
        &["no.", "top acc", "early stopped", "tuned axes"],
    );

    let steps: [(&str, Option<ParamDef>, bool); 6] = [
        ("1st: lr", None, true),
        ("2nd: +momentum", Some(fdef("momentum", 0.1, 0.999, 0.0, 1.0)), true),
        ("3rd: +prob", Some(fdef("prob", 0.0, 0.9, 0.0, 1.0)), true),
        ("4th: +sh", Some(fdef("sh", 0.2, 0.9, 0.05, 1.0)), true),
        ("5th: +depth (ES)", Some(depth_def()), true),
        ("6th: depth (no ES)", None, false),
    ];

    for (i, (label, new_param, es)) in steps.into_iter().enumerate() {
        // Usage-flow step 3: narrow from the previous run's top-10.
        if let Some((_, prev_sessions)) = runs.last() {
            let top = analysis::top_k(prev_sessions, order, 10);
            cfg = analysis::narrow_config(&cfg, &top);
        }
        // Usage-flow step 4: append the next axis.
        if let Some(def) = new_param {
            cfg = analysis::append_param(&cfg, def);
        }
        cfg.step = if es { 7 } else { -1 };
        cfg.seed = 42 + i as u64;
        let (sessions, best) = run_one(cfg.clone(), i as u64 + 1);
        let axes: Vec<&str> = cfg.space.defs.iter().map(|d| d.name.as_str()).collect();
        println!("{label}: best {best:.2}% over {} models", sessions.len());
        table.row(&[
            format!("{}", i + 1),
            format!("{best:.2}"),
            format!("{es}"),
            axes.join(", "),
        ]);
        runs.push((label.to_string(), sessions));
    }
    table.print();

    // The headline §4 claim: removing ES in session 6 beats session 5.
    let best5 = analysis::top_k(&runs[4].1, order, 1)[0]
        .best_measure(order)
        .unwrap();
    let best6 = analysis::top_k(&runs[5].1, order, 1)[0]
        .best_measure(order)
        .unwrap();
    println!("\nES-biased session 5: {best5:.2}%  ->  no-ES session 6: {best6:.2}%");
    assert!(best6 > best5, "no-ES must recover the deep models");

    // ------- Fig. 3/4/5/7 artifacts ------------------------------------
    std::fs::create_dir_all("reports/finetune")?;
    // Merged parallel coordinates over all six runs (Fig. 7), with top-3
    // of the final run highlighted (Fig. 4 masking).
    let space = cfg.space.clone();
    let groups: Vec<viz::parallel_coords::RunGroup> = runs
        .iter()
        .map(|(label, sessions)| viz::parallel_coords::RunGroup {
            label,
            sessions,
        })
        .collect();
    let highlight: HashSet<_> = analysis::top_k(&runs[5].1, order, 3)
        .iter()
        .map(|s| s.id)
        .collect();
    viz::parallel_coords::render(&space, &groups, order, &highlight)
        .save("reports/finetune/fig7_parallel.svg")?;

    let last = &runs[5].1;
    viz::plots::scatter(last, "prob", order).save("reports/finetune/scatter_prob.svg")?;
    viz::plots::histogram(last, "lr", 12).save("reports/finetune/hist_lr.svg")?;
    viz::plots::duration_bars(&runs[4].1).save("reports/finetune/fig5_duration_es.svg")?;
    viz::plots::duration_bars(last).save("reports/finetune/fig5_duration_no_es.svg")?;
    viz::cluster_view::render(&space, last, order).save("reports/finetune/fig5_cluster.svg")?;
    viz::hierarchy::render(last).save("reports/finetune/fig5_hierarchy.svg")?;
    std::fs::write(
        "reports/finetune/parallel.json",
        viz::export::parallel_coords_doc(&space, last, order, "6th").to_string_pretty(),
    )?;
    let top_refs = analysis::top_k(last, order, 3);
    std::fs::write(
        "reports/finetune/summary.json",
        viz::export::summary_doc(&top_refs, order).to_string_pretty(),
    )?;
    println!("viz artifacts in reports/finetune/ (fig7_parallel.svg, fig5_*, scatter, hist)");
    Ok(())
}
