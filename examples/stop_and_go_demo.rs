//! Stop-and-Go demo (paper §3.3, Fig. 8): a CHOPT session on a shared
//! cluster with the A–E external-load trace.  Prints the zone-by-zone
//! allocation picture and writes the Fig.-8 timeline SVG.
//!
//!     cargo run --release --example stop_and_go_demo

use chopt::cluster::ExternalLoadTrace;
use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, AgentEvent, SimSetup, StopAndGoPolicy};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::util::bench::Table;
use chopt::viz::plots;

fn main() -> anyhow::Result<()> {
    let gpus = 16;
    let horizon = 200_000.0; // ~2.3 virtual days
    let cfg_text = r#"{
      "h_params": {
        "lr": {"parameters": [0.005, 0.09], "distribution": "log_uniform",
               "type": "float", "p_range": [0.001, 0.2]},
        "depth": {"parameters": [20, 140], "distribution": "uniform",
               "type": "int", "p_range": [20, 140]}
      },
      "measure": "test/accuracy",
      "order": "descending",
      "step": 5,
      "population": 6,
      "tune": {"random": {}},
      "termination": {"max_session_number": 4000},
      "model": "surrogate:resnet",
      "max_epochs": 120,
      "max_gpus": 6,
      "stop_ratio": 0.7,
      "seed": 17
    }"#;
    let cfg = ChoptConfig::from_json_str(cfg_text)?;
    let trace = ExternalLoadTrace::fig8(gpus, horizon, 23);

    println!("== Stop-and-Go demo: {gpus}-GPU shared cluster, Fig.8 A-E trace ==");
    let setup = SimSetup {
        cluster_gpus: gpus,
        configs: vec![cfg],
        submit_times: Vec::new(),
        agent_slots: 1,
        trace: Some(trace.clone()),
        policy: StopAndGoPolicy::default(),
        master_period: 300.0,
        horizon,
        failures: Vec::new(),
    };
    let outcome = run_sim(setup, |id| {
        Box::new(SurrogateTrainer::new(70 + id)) as Box<dyn Trainer>
    });

    // Zone summary from the master log.
    let mut table = Table::new(
        "Fig. 8 zones: mean GPUs by owner",
        &["zone", "external demand", "external held", "CHOPT held", "utilization"],
    );
    for (zone, lo, hi) in [
        ("A", 0.00, 0.15),
        ("B", 0.15, 0.30),
        ("C", 0.30, 0.55),
        ("D", 0.55, 0.80),
        ("E", 0.80, 1.00),
    ] {
        let rows: Vec<_> = outcome
            .master_log
            .iter()
            .filter(|r| r.t >= lo * horizon && r.t < hi * horizon)
            .collect();
        let mean = |f: &dyn Fn(&chopt::coordinator::MasterTickLog) -> f64| {
            rows.iter().map(|r| f(r)).sum::<f64>() / rows.len().max(1) as f64
        };
        table.row(&[
            zone.to_string(),
            format!("{:.1}", mean(&|r| r.external_demand as f64)),
            format!("{:.1}", mean(&|r| r.external_held as f64)),
            format!("{:.1}", mean(&|r| r.chopt_held as f64)),
            format!("{:.2}", mean(&|r| r.utilization)),
        ]);
    }
    table.print();

    let agent = &outcome.agents[0];
    let preempted = agent
        .events
        .iter()
        .filter(|e| matches!(e, AgentEvent::Preempted(..)))
        .count();
    let revived = agent
        .events
        .iter()
        .filter(|e| matches!(e, AgentEvent::Revived(_)))
        .count();
    println!(
        "\npreemptions: {preempted}, revivals: {revived}, models created: {}",
        agent.created
    );
    println!(
        "best model: {:.2}%  |  CHOPT GPU-hours: {:.1}",
        agent.best().map(|(_, m)| m).unwrap_or(f64::NAN),
        outcome.gpu_hours()
    );

    // The Fig. 8 SVG.
    std::fs::create_dir_all("reports/stop_and_go")?;
    let svg = plots::utilization_timeline(
        &outcome.cluster.usage_total.series,
        &outcome.cluster.usage_external.series,
        gpus,
        horizon,
    );
    svg.save("reports/stop_and_go/fig8_timeline.svg")?;
    println!("timeline written to reports/stop_and_go/fig8_timeline.svg");
    Ok(())
}
