//! Serve a CHOPT run through the web-based analytic tool: runs a quick
//! surrogate session, exports all views, serves them over HTTP, and
//! self-checks every route.  Pass `--hold` to keep the server alive for a
//! browser.
//!
//!     cargo run --release --example serve_viz [-- --hold]

use std::collections::HashSet;

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::viz::{self, server::{http_get, Routes, VizServer}};

fn main() -> anyhow::Result<()> {
    let hold = std::env::args().any(|a| a == "--hold");
    let mut cfg = ChoptConfig::from_json_str(chopt::config::LISTING1_EXAMPLE)?;
    cfg.model = "surrogate:wrn_re".to_string();
    cfg.max_epochs = 120;
    let order = cfg.order;
    let space = cfg.space.clone();

    println!("running a quick CHOPT session to have something to look at...");
    let outcome = run_sim(SimSetup::single(cfg, 8), |id| {
        Box::new(SurrogateTrainer::new(5 + id)) as Box<dyn Trainer>
    });
    let agent = &outcome.agents[0];
    let sessions: Vec<_> = agent.sessions.values().cloned().collect();

    // Build all routes.
    let mut routes = Routes::new();
    let parallel = viz::export::parallel_coords_doc(&space, &sessions, order, "demo");
    routes.insert(
        "/api/parallel.json".into(),
        ("application/json".into(), parallel.to_string_compact().into_bytes()),
    );
    routes.insert(
        "/api/curves.json".into(),
        (
            "application/json".into(),
            viz::export::curves_doc(&sessions).to_string_compact().into_bytes(),
        ),
    );
    let svg = viz::parallel_coords::render(
        &space,
        &[viz::parallel_coords::RunGroup {
            label: "demo",
            sessions: &sessions,
        }],
        order,
        &HashSet::new(),
    );
    routes.insert(
        "/svg/parallel.svg".into(),
        ("image/svg+xml".into(), svg.finish().into_bytes()),
    );
    routes.insert(
        "/svg/cluster.svg".into(),
        (
            "image/svg+xml".into(),
            viz::cluster_view::render(&space, &sessions, order)
                .finish()
                .into_bytes(),
        ),
    );

    let server = VizServer::start(0, routes)?;
    let addr = server.addr();
    println!("viz server on http://{addr}/");

    // Self-check every route.
    for path in ["/", "/api/parallel.json", "/api/curves.json", "/svg/parallel.svg", "/svg/cluster.svg"] {
        let (status, body) = http_get(addr, path)?;
        assert_eq!(status, 200, "route {path}");
        println!("  GET {path} -> 200 ({} bytes)", body.len());
    }
    println!("requests served: {}", server.requests.load(std::sync::atomic::Ordering::Relaxed));

    if hold {
        println!("holding (ctrl-c to stop)...");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    server.stop();
    println!("self-check OK");
    Ok(())
}
