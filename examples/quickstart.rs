//! Quickstart: run the paper's Listing-1 configuration end to end on the
//! surrogate trainer and print the leaderboard.
//!
//!     cargo run --release --example quickstart

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::viz::report;

fn main() -> anyhow::Result<()> {
    // The exact configuration from the paper's Listing 1 (PBT, step 5,
    // population 5, 50 models max), pointed at the resnet surrogate.
    let mut cfg = ChoptConfig::from_json_str(chopt::config::LISTING1_EXAMPLE)?;
    cfg.model = "surrogate:resnet".to_string();
    cfg.max_epochs = 100;
    cfg.seed = 7;
    let order = cfg.order;

    println!("== CHOPT quickstart: Listing-1 config on surrogate:resnet ==");
    println!(
        "tune={} population={} step={} termination=max {} models",
        cfg.tune.name(),
        cfg.population,
        cfg.step,
        cfg.termination.max_session_number.unwrap_or(0)
    );

    let outcome = run_sim(SimSetup::single(cfg, 8), |id| {
        Box::new(SurrogateTrainer::new(1000 + id)) as Box<dyn Trainer>
    });

    let agent = &outcome.agents[0];
    report::outcome_table(agent).print();
    let sessions: Vec<_> = agent.sessions.values().cloned().collect();
    report::leaderboard_table(&sessions, order, 10).print();

    let (sid, best) = agent.best().expect("a best model exists");
    println!(
        "\nbest model {sid}: {best:.2}% with {}",
        agent.sessions[&sid].hparams.render()
    );
    println!(
        "virtual time {:.1}h, CHOPT GPU-hours {:.1}, {} events",
        outcome.end_time / 3600.0,
        outcome.gpu_hours(),
        outcome.events_processed
    );
    Ok(())
}
