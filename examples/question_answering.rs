//! QA task driver: tune the BiDAF-lite model (real PJRT training) with
//! random search + median-rule early stopping — the paper's second
//! evaluation task (§5.1, SQuAD/BiDAF row of Table 2).
//!
//!     make artifacts && cargo run --release --example question_answering

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::runtime::Manifest;
use chopt::trainer::real::RealTrainer;
use chopt::trainer::Trainer;
use chopt::viz;

const CONFIG: &str = r#"{
  "h_params": {
    "lr": {"parameters": [0.05, 1.0], "distribution": "log_uniform",
           "type": "float", "p_range": [0.01, 2.0]},
    "momentum": {"parameters": [0.5, 0.95], "distribution": "uniform",
           "type": "float", "p_range": [0.0, 0.99]},
    "dropout": {"parameters": [0.0, 0.4], "distribution": "uniform",
           "type": "float", "p_range": [0.0, 0.6]}
  },
  "measure": "test/em",
  "order": "descending",
  "step": 5,
  "population": 4,
  "tune": {"random": {}},
  "termination": {"max_session_number": 10},
  "model": "qa_bidaf",
  "max_epochs": 30,
  "max_gpus": 4,
  "seed": 9
}"#;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cfg = ChoptConfig::from_json_str(CONFIG)?;
    let order = cfg.order;
    println!("== question answering (REAL PJRT training, BiDAF-lite) ==");
    println!("random search + median early stopping, 10 models, 20 epochs each");
    let t0 = std::time::Instant::now();

    let outcome = run_sim(SimSetup::single(cfg, 4), |id| {
        Box::new(RealTrainer::new(Manifest::default_dir(), 900 + id).expect("runtime"))
            as Box<dyn Trainer>
    });

    let agent = &outcome.agents[0];
    let sessions: Vec<_> = agent.sessions.values().cloned().collect();
    viz::report::outcome_table(agent).print();
    viz::report::leaderboard_table(&sessions, order, 6).print();

    std::fs::create_dir_all("reports/question_answering")?;
    std::fs::write(
        "reports/question_answering/curves.json",
        viz::export::curves_doc(&sessions).to_string_pretty(),
    )?;

    let (sid, best) = agent.best().expect("best exists");
    let s = &agent.sessions[&sid];
    println!(
        "\nbest model {sid}: exact-match {best:.2}% at epoch {} with {}",
        s.epochs,
        s.hparams.render()
    );
    let first = s.history.first().unwrap();
    let last = s.history.last().unwrap();
    println!("best-model loss {:.3} -> {:.3}", first.loss, last.loss);
    assert!(last.loss < first.loss, "QA training must reduce loss");
    println!("wall time {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
