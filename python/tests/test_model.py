"""L2: residual-MLP image classifier — shapes, learning, hparam effects."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


def data(seed=0):
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(model.BATCH, model.INPUT_DIM), jnp.float32)
    y = jnp.asarray(rs.randint(0, model.NUM_CLASSES, model.BATCH), jnp.int32)
    return x, y


@pytest.mark.parametrize("blocks,widen", [(1, 1), (2, 1), (2, 2)])
def test_param_specs_and_init_shapes(blocks, widen):
    specs = model.param_specs(blocks, widen)
    state = model.make_init(blocks, widen)(0)
    assert len(state) == 2 * len(specs)
    for (name, shape), arr in zip(specs, state[: len(specs)]):
        assert arr.shape == shape, name
    # Velocities zero-initialized.
    for arr in state[len(specs) :]:
        assert float(jnp.abs(arr).max()) == 0.0
    # Param count formula matches actual sizes.
    total = sum(int(np.prod(s)) for _, s in specs)
    assert model.param_count(blocks, widen) == total


def test_forward_shapes_and_determinism():
    state = model.make_init(1, 1)(3)
    params = list(state[: len(model.param_specs(1, 1))])
    x, _ = data()
    logits = model.forward(params, x, 1)
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)
    logits2 = model.forward(params, x, 1)
    assert_allclose(np.asarray(logits), np.asarray(logits2))


def test_initial_loss_near_uniform():
    state = model.make_init(1, 1)(0)
    params = list(state[: len(model.param_specs(1, 1))])
    x, y = data()
    loss, acc = model.loss_and_acc(params, x, y, 1)
    # He-init logits inflate CE somewhat above ln(C); it must still be in
    # the random-guess regime, far from a degenerate/exploded init.
    assert abs(float(loss) - np.log(model.NUM_CLASSES)) < 2.5
    assert float(acc) <= 0.2


def test_training_reduces_loss_and_improves_acc():
    blocks, widen = 1, 1
    ts = jax.jit(model.make_train_step(blocks, widen))
    es = jax.jit(model.make_eval_step(blocks, widen))
    state = list(model.make_init(blocks, widen)(1))
    n = len(model.param_specs(blocks, widen))
    x, y = data(1)
    first = None
    for i in range(30):
        out = ts(
            x, y,
            jnp.float32(0.08), jnp.float32(0.9),
            jnp.float32(0.0), jnp.float32(0.4), jnp.int32(i),
            *state,
        )
        if first is None:
            first = float(out[0])
        state = list(out[2:])
    last = float(out[0])
    assert last < first * 0.6, f"loss {first} -> {last}"
    # Train accuracy on the memorized batch improves.
    ev = es(x, y, *state[:n])
    assert float(ev[1]) > 0.3


def test_lr_zero_is_a_no_op():
    blocks, widen = 1, 1
    ts = jax.jit(model.make_train_step(blocks, widen))
    state = list(model.make_init(blocks, widen)(2))
    x, y = data(2)
    out = ts(
        x, y,
        jnp.float32(0.0), jnp.float32(0.9),
        jnp.float32(0.0), jnp.float32(0.4), jnp.int32(0),
        *state,
    )
    new_params = out[2 : 2 + len(model.param_specs(blocks, widen))]
    for old, new in zip(state, new_params):
        assert_allclose(np.asarray(old), np.asarray(new), atol=0)


def test_re_prob_zero_matches_no_augmentation():
    # With re_prob=0 the augmentation path must be exact identity on x.
    key = jax.random.PRNGKey(0)
    x, _ = data(4)
    out = model.apply_random_erase(x, jnp.float32(0.0), jnp.float32(0.4), key)
    assert_allclose(np.asarray(out), np.asarray(x))


def test_re_prob_one_erases_some_pixels():
    key = jax.random.PRNGKey(1)
    x = jnp.ones((model.BATCH, model.INPUT_DIM), jnp.float32)
    out = np.asarray(
        model.apply_random_erase(x, jnp.float32(1.0), jnp.float32(0.6), key)
    )
    assert (out == 0.0).sum() > 0
    assert (out == 1.0).sum() > 0


def test_deeper_variant_expressible():
    # Depth variants share the same train-step signature with more state.
    for name, (blocks, widen) in model.IC_VARIANTS.items():
        n = len(model.param_specs(blocks, widen))
        assert n == 4 + 4 * blocks, name
