"""L1 correctness: BiDAF attention kernel vs oracle (fwd + bwd)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.attention import bidaf_attention, vmem_bytes
from compile.kernels.ref import bidaf_attention_batched_ref, bidaf_attention_ref


def _mk(b, lc, lq, d, seed=0):
    rs = np.random.RandomState(seed)
    c = jnp.asarray(rs.randn(b, lc, d), jnp.float32)
    q = jnp.asarray(rs.randn(b, lq, d), jnp.float32)
    return c, q


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    lc=st.integers(2, 40),
    lq=st.integers(2, 24),
    d=st.integers(2, 48),
    seed=st.integers(0, 1000),
)
def test_matches_ref_hypothesis(b, lc, lq, d, seed):
    c, q = _mk(b, lc, lq, d, seed)
    got = bidaf_attention(c, q)
    want = bidaf_attention_batched_ref(c, q)
    assert got.shape == (b, lc, 4 * d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_model_shape_case():
    c, q = _mk(32, 32, 16, 32, 7)
    got = bidaf_attention(c, q)
    want = bidaf_attention_batched_ref(c, q)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_attends_to_matching_tokens():
    # If one context row equals a query row, c2q there should be ~that row.
    d = 16
    c, q = _mk(1, 8, 4, d, 3)
    c = c.at[0, 2].set(q[0, 1] * 4.0)  # strong match at position 2
    out = np.asarray(bidaf_attention(c, q))
    c2q = out[0, :, d : 2 * d]
    sim_match = np.dot(c2q[2], np.asarray(q[0, 1]))
    sim_other = np.dot(c2q[5], np.asarray(q[0, 1]))
    assert sim_match > sim_other


def test_gradients_flow_and_match_ref():
    c, q = _mk(2, 10, 6, 8, 5)

    def f_kernel(c, q):
        return jnp.sum(bidaf_attention(c, q) ** 2)

    def f_ref(c, q):
        return jnp.sum(bidaf_attention_batched_ref(c, q) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1))(c, q)
    gr = jax.grad(f_ref, argnums=(0, 1))(c, q)
    for a, e in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-4, atol=1e-4)


def test_single_example_ref_consistency():
    c, q = _mk(1, 6, 3, 4, 9)
    single = bidaf_attention_ref(c[0], q[0])
    batched = bidaf_attention_batched_ref(c, q)[0]
    assert_allclose(np.asarray(single), np.asarray(batched), rtol=1e-6)


def test_vmem_model():
    # BiDAF dims fit comfortably in a 16 MiB VMEM budget.
    assert vmem_bytes(32, 16, 32) < 16 * 1024 * 1024
    assert vmem_bytes(64, 32, 64) > vmem_bytes(32, 16, 32)
