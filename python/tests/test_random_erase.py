"""L1 correctness: random_erase kernel vs oracle + rect sampling."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.random_erase import random_erase, sample_rects
from compile.kernels.ref import random_erase_ref


def _mk(b, h, w, c, seed=0):
    rs = np.random.RandomState(seed)
    imgs = jnp.asarray(rs.randn(b, h, w, c), jnp.float32)
    y0 = rs.randint(0, h, b)
    x0 = rs.randint(0, w, b)
    rh = rs.randint(1, h + 1, b)
    rw = rs.randint(1, w + 1, b)
    rects = jnp.asarray(np.stack([y0, x0, rh, rw], axis=1), jnp.int32)
    apply_mask = jnp.asarray(rs.randint(0, 2, b), jnp.float32)
    return imgs, rects, apply_mask


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.integers(2, 12),
    w=st.integers(2, 12),
    c=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_matches_ref_hypothesis(b, h, w, c, seed):
    imgs, rects, apply_mask = _mk(b, h, w, c, seed)
    got = random_erase(imgs, rects, apply_mask, 0.0)
    want = random_erase_ref(imgs, rects, apply_mask, 0.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_no_apply_is_identity():
    imgs, rects, _ = _mk(4, 8, 8, 3)
    got = random_erase(imgs, rects, jnp.zeros(4, jnp.float32), 0.0)
    assert_allclose(np.asarray(got), np.asarray(imgs))


def test_full_rect_erases_everything():
    imgs = jnp.ones((2, 4, 4, 1), jnp.float32)
    rects = jnp.asarray([[0, 0, 4, 4], [0, 0, 4, 4]], jnp.int32)
    got = random_erase(imgs, rects, jnp.ones(2, jnp.float32), 0.5)
    assert np.allclose(np.asarray(got), 0.5)


def test_erased_area_matches_rect():
    imgs = jnp.ones((1, 8, 8, 1), jnp.float32)
    rects = jnp.asarray([[2, 3, 4, 2]], jnp.int32)  # y0=2,x0=3,h=4,w=2
    got = np.asarray(random_erase(imgs, rects, jnp.ones(1, jnp.float32), 0.0))
    erased = (got == 0.0).sum()
    assert erased == 4 * 2
    assert got[0, 2, 3, 0] == 0.0
    assert got[0, 1, 3, 0] == 1.0


def test_sample_rects_bounds_and_scaling():
    key = jax.random.PRNGKey(0)
    for sh in [0.1, 0.4, 0.9]:
        rects = np.asarray(sample_rects(key, 256, 8, 8, jnp.float32(sh)))
        y0, x0, rh, rw = rects.T
        assert (rh >= 1).all() and (rw >= 1).all()
        assert (y0 >= 0).all() and (x0 >= 0).all()
        assert ((y0 + rh) <= 8).all(), "rect exceeds image height"
        assert ((x0 + rw) <= 8).all(), "rect exceeds image width"
    small = np.asarray(sample_rects(key, 512, 8, 8, jnp.float32(0.15)))
    big = np.asarray(sample_rects(key, 512, 8, 8, jnp.float32(0.95)))
    assert big[:, 2].mean() > small[:, 2].mean() + 1.0


def test_traced_sh_is_allowed():
    # sh must work as a traced scalar inside jit (it's a tuned hparam).
    @jax.jit
    def f(sh):
        key = jax.random.PRNGKey(1)
        return sample_rects(key, 16, 8, 8, sh)

    r1 = f(jnp.float32(0.2))
    r2 = f(jnp.float32(0.8))
    assert r1.shape == (16, 4)
    assert not np.array_equal(np.asarray(r1), np.asarray(r2))
