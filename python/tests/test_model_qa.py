"""L2: BiDAF-lite QA model — shapes, learning on planted spans."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model_qa


def data(seed=0):
    rs = np.random.RandomState(seed)
    ctx = jnp.asarray(
        rs.randint(2, model_qa.VOCAB, (model_qa.QA_BATCH, model_qa.CTX_LEN)), jnp.int32
    )
    # Plant the answer: question copies ctx[start:end+1] bracketed by 1s.
    y_s = rs.randint(0, model_qa.CTX_LEN - 4, model_qa.QA_BATCH)
    span = 3
    y_e = y_s + span - 1
    qry = np.ones((model_qa.QA_BATCH, model_qa.QRY_LEN), np.int32)
    for i in range(model_qa.QA_BATCH):
        qry[i, 1 : 1 + span] = np.asarray(ctx)[i, y_s[i] : y_s[i] + span]
        qry[i, 1 + span + 1 :] = rs.randint(2, model_qa.VOCAB, model_qa.QRY_LEN - span - 2)
    return (
        ctx,
        jnp.asarray(qry, jnp.int32),
        jnp.asarray(y_s, jnp.int32),
        jnp.asarray(y_e, jnp.int32),
    )


def test_init_shapes():
    state = model_qa.make_init()(0)
    specs = model_qa.param_specs()
    assert len(state) == 2 * len(specs)
    for (name, shape), arr in zip(specs, state[: len(specs)]):
        assert arr.shape == shape, name
    assert model_qa.param_count() == sum(int(np.prod(s)) for _, s in specs)


def test_forward_shapes():
    state = model_qa.make_init()(1)
    params = list(state[: model_qa.N_PARAMS])
    ctx, qry, _, _ = data()
    start, end = model_qa.forward(
        params, ctx, qry, jnp.float32(0.0), jax.random.PRNGKey(0)
    )
    assert start.shape == (model_qa.QA_BATCH, model_qa.CTX_LEN)
    assert end.shape == (model_qa.QA_BATCH, model_qa.CTX_LEN)


def test_training_reduces_loss():
    ts = jax.jit(model_qa.make_train_step())
    state = list(model_qa.make_init()(2))
    ctx, qry, y_s, y_e = data(2)
    first = None
    for i in range(25):
        out = ts(
            ctx, qry, y_s, y_e,
            jnp.float32(0.5), jnp.float32(0.9), jnp.float32(0.0), jnp.int32(i),
            *state,
        )
        if first is None:
            first = float(out[0])
        state = list(out[2:])
    last = float(out[0])
    assert last < first * 0.8, f"qa loss {first} -> {last}"


def test_eval_step_no_dropout_deterministic():
    es = jax.jit(model_qa.make_eval_step())
    state = model_qa.make_init()(3)
    params = state[: model_qa.N_PARAMS]
    ctx, qry, y_s, y_e = data(3)
    a = es(ctx, qry, y_s, y_e, *params)
    b = es(ctx, qry, y_s, y_e, *params)
    assert float(a[0]) == float(b[0])
    assert 0.0 <= float(a[1]) <= 1.0


def test_dropout_changes_training_loss():
    ts = jax.jit(model_qa.make_train_step())
    state = list(model_qa.make_init()(4))
    ctx, qry, y_s, y_e = data(4)
    args = (ctx, qry, y_s, y_e, jnp.float32(0.1), jnp.float32(0.9))
    out0 = ts(*args, jnp.float32(0.0), jnp.int32(0), *state)
    out5 = ts(*args, jnp.float32(0.5), jnp.int32(0), *state)
    assert float(out0[0]) != float(out5[0])
