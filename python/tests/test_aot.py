"""AOT pipeline: HLO text lowering + manifest consistency.

Fast checks lower a tiny function; the manifest checks validate the real
artifacts directory when it exists (after `make artifacts`).
"""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model, model_qa
from compile.hlo import lower_to_hlo_text, spec_entry


def test_lower_tiny_function_to_hlo_text():
    import jax

    def fn(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = lower_to_hlo_text(fn, [spec, spec])
    assert "HloModule" in text
    assert "f32[2,2]" in text
    # Tuple root (return_tuple=True).
    assert "tuple" in text.lower()


def test_spec_entry_dtype_names():
    import jax

    e = spec_entry("x", jax.ShapeDtypeStruct((4, 3), jnp.float32))
    assert e == {"name": "x", "shape": [4, 3], "dtype": "f32"}
    e2 = spec_entry("y", jax.ShapeDtypeStruct((), jnp.int32))
    assert e2["dtype"] == "i32" and e2["shape"] == []


def test_ic_artifact_descriptions_consistent():
    arts = aot.ic_variant_artifacts("ic_d1_w1", 1, 1)
    names = [a[0] for a in arts]
    assert names == ["ic_d1_w1_train", "ic_d1_w1_eval", "ic_d1_w1_init"]
    train = arts[0]
    _, fn, example_args, input_names, output_names = train
    assert len(example_args) == len(input_names)
    n_params = len(model.param_specs(1, 1))
    assert len(input_names) == 7 + 2 * n_params
    assert len(output_names) == 2 + 2 * n_params
    assert input_names[:7] == ["x", "y", "lr", "momentum", "re_prob", "re_sh", "seed"]


def test_qa_artifact_descriptions_consistent():
    arts = aot.qa_artifacts()
    train = arts[0]
    _, _, example_args, input_names, output_names = train
    assert len(example_args) == len(input_names)
    assert len(output_names) == 2 + 2 * model_qa.N_PARAMS
    assert input_names[4] == "lr"


ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_matches_models():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == 1
    assert m["data"]["image"]["input_dim"] == model.INPUT_DIM
    assert m["data"]["qa"]["vocab"] == model_qa.VOCAB
    for name, v in m["variants"].items():
        for key in ["train", "eval", "init"]:
            art = m["artifacts"][v[key]]
            path = os.path.join(ART_DIR, art["file"])
            assert os.path.exists(path), f"{name}: missing {path}"
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head
        if v["task"] == "image_classification":
            blocks, widen = v["blocks"], v["widen"]
            assert v["param_count"] == model.param_count(blocks, widen)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built",
)
def test_built_train_artifact_io_counts():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        m = json.load(f)
    a = m["artifacts"]["ic_d2_w1_train"]
    n_params = len(model.param_specs(2, 1))
    assert len(a["inputs"]) == 7 + 2 * n_params
    assert a["n_outputs"] == 2 + 2 * n_params
    assert a["inputs"][0]["shape"] == [model.BATCH, model.INPUT_DIM]
