"""L1 correctness: fused_linear Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/activations/dtypes; explicit cases pin the exact
shapes the L2 models use (including non-dividing N like NUM_CLASSES=100).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.fused_linear import (
    activation_grad,
    fused_linear,
    matmul,
    mxu_utilization_estimate,
    vmem_bytes,
)
from compile.kernels.ref import ACTIVATIONS, fused_linear_ref


def _mk(m, k, n, seed=0, dtype=jnp.float32):
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(m, k), dtype)
    w = jnp.asarray(rs.randn(k, n) * 0.1, dtype)
    b = jnp.asarray(rs.randn(n) * 0.1, dtype)
    return x, w, b


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_matches_ref_model_shapes(activation):
    # The exact layer shapes of the IC model: (64,192)x(192,64), (64,64)x(64,64),
    # and the non-dividing classifier head (64,64)x(64,100).
    for m, k, n in [(64, 192, 64), (64, 64, 64), (64, 64, 100), (32, 128, 32)]:
        x, w, b = _mk(m, k, n, seed=m + n)
        got = fused_linear(x, w, b, activation)
        want = fused_linear_ref(x, w, b, activation)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 97),
    k=st.integers(1, 70),
    n=st.integers(1, 150),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(m, k, n, act, seed):
    x, w, b = _mk(m, k, n, seed=seed % 1000)
    got = fused_linear(x, w, b, act)
    want = fused_linear_ref(x, w, b, act)
    assert got.shape == (m, n)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 1000),
)
def test_matmul_helper(m, k, n, seed):
    rs = np.random.RandomState(seed)
    a = jnp.asarray(rs.randn(m, k), jnp.float32)
    b = jnp.asarray(rs.randn(k, n), jnp.float32)
    assert_allclose(
        np.asarray(matmul(a, b)), np.asarray(a) @ np.asarray(b), rtol=2e-5, atol=2e-5
    )


def test_bfloat16_inputs_accumulate_f32():
    x, w, b = _mk(16, 32, 48, dtype=jnp.bfloat16)
    got = fused_linear(x, w, b, "relu")
    assert got.dtype == jnp.bfloat16
    want = fused_linear_ref(x, w, b, "relu")
    assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_gradients_match_ref(activation):
    x, w, b = _mk(16, 24, 20, seed=3)

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, activation) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(fused_linear_ref(x, w, b, activation) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(e), rtol=2e-4, atol=2e-4)


def test_gradient_numerical():
    # Finite differences on a tiny problem, independent of jax autodiff.
    x, w, b = _mk(4, 5, 3, seed=9)

    def f(wflat):
        return float(
            jnp.sum(fused_linear(x, wflat.reshape(5, 3), b, "tanh") ** 2)
        )

    w0 = np.asarray(w).reshape(-1)
    g = np.asarray(
        jax.grad(lambda w_: jnp.sum(fused_linear(x, w_, b, "tanh") ** 2))(w)
    ).reshape(-1)
    eps = 1e-3
    for idx in [0, 3, 7, 14]:
        e = np.zeros_like(w0)
        e[idx] = eps
        num = (f(w0 + e) - f(w0 - e)) / (2 * eps)
        assert abs(num - g[idx]) < 5e-2 * max(1.0, abs(num))


def test_activation_grad_unknown_raises():
    with pytest.raises(ValueError):
        activation_grad(jnp.ones((2, 2)), jnp.ones((2, 2)), "swish")


def test_unknown_activation_raises():
    x, w, b = _mk(4, 4, 4)
    with pytest.raises(ValueError):
        fused_linear(x, w, b, "swish")


def test_perf_models_monotone():
    # Structural sanity of the perf estimators used in EXPERIMENTS.md §Perf.
    assert vmem_bytes(64, 192, 64) < vmem_bytes(128, 192, 128)
    assert mxu_utilization_estimate(128, 128, 128, bm=128, bn=128) == 1.0
    assert mxu_utilization_estimate(8, 128, 128) < 0.1
