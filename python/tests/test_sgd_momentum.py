"""L1 correctness: fused SGD-momentum kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import sgd_momentum_ref
from compile.kernels.sgd_momentum import (
    sgd_momentum,
    sgd_momentum_flat,
    sgd_momentum_tree,
)


def _mk(n, seed=0):
    rs = np.random.RandomState(seed)
    return (
        jnp.asarray(rs.randn(n), jnp.float32),
        jnp.asarray(rs.randn(n), jnp.float32),
        jnp.asarray(rs.randn(n), jnp.float32),
    )


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 5000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.999),
    seed=st.integers(0, 1000),
)
def test_matches_ref_hypothesis(n, lr, mu, seed):
    p, g, v = _mk(n, seed)
    p2, v2 = sgd_momentum_flat(p, g, v, lr, mu)
    pe, ve = sgd_momentum_ref(p, g, v, lr, mu)
    assert_allclose(np.asarray(p2), np.asarray(pe), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(v2), np.asarray(ve), rtol=1e-5, atol=1e-6)


def test_block_boundary_sizes():
    # Exactly one block, one element over, one element under.
    for n in [1023, 1024, 1025, 2048, 1]:
        p, g, v = _mk(n, n)
        p2, v2 = sgd_momentum_flat(p, g, v, 0.1, 0.9)
        pe, ve = sgd_momentum_ref(p, g, v, 0.1, 0.9)
        assert_allclose(np.asarray(p2), np.asarray(pe), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(v2), np.asarray(ve), rtol=1e-5, atol=1e-6)


def test_shape_polymorphic_wrapper():
    rs = np.random.RandomState(7)
    p = jnp.asarray(rs.randn(12, 7), jnp.float32)
    g = jnp.asarray(rs.randn(12, 7), jnp.float32)
    v = jnp.asarray(rs.randn(12, 7), jnp.float32)
    p2, v2 = sgd_momentum(p, g, v, 0.05, 0.8)
    pe, ve = sgd_momentum_ref(p, g, v, 0.05, 0.8)
    assert p2.shape == (12, 7)
    assert_allclose(np.asarray(p2), np.asarray(pe), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(v2), np.asarray(ve), rtol=1e-5, atol=1e-6)


def test_tree_update():
    shapes = [(3, 4), (4,), (5, 6), (6,)]
    rs = np.random.RandomState(1)
    ps = [jnp.asarray(rs.randn(*s), jnp.float32) for s in shapes]
    gs = [jnp.asarray(rs.randn(*s), jnp.float32) for s in shapes]
    vs = [jnp.asarray(rs.randn(*s), jnp.float32) for s in shapes]
    nps, nvs = sgd_momentum_tree(ps, gs, vs, 0.01, 0.9)
    assert len(nps) == len(shapes) and len(nvs) == len(shapes)
    for p, g, v, p2, v2 in zip(ps, gs, vs, nps, nvs):
        pe, ve = sgd_momentum_ref(p, g, v, 0.01, 0.9)
        assert_allclose(np.asarray(p2), np.asarray(pe), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(v2), np.asarray(ve), rtol=1e-5, atol=1e-6)


def test_zero_momentum_is_plain_sgd():
    p, g, v0 = _mk(100, 4)
    p2, v2 = sgd_momentum_flat(p, g, jnp.zeros_like(v0), 0.5, 0.0)
    assert_allclose(np.asarray(p2), np.asarray(p - 0.5 * g), rtol=1e-6)
    assert_allclose(np.asarray(v2), np.asarray(g), rtol=1e-6)
