"""AOT pipeline: lower every model variant to HLO text + manifest.json.

Run once at build time (``make artifacts``).  Python never runs on the
request path: the Rust runtime (rust/src/runtime/) loads these artifacts
through the PJRT C API and executes them from the coordinator's worker
threads.

Artifacts (under --out-dir, default ../artifacts):

    <variant>_train.hlo.txt   fwd+bwd+fused-SGD train step
    <variant>_eval.hlo.txt    loss/metric on a batch
    <variant>_init.hlo.txt    seeded parameter initialization
    manifest.json             input/output specs, param layout, data dims

Usage: ``cd python && python -m compile.aot [--out-dir DIR] [--variants a,b]``
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp

from . import model, model_qa
from .hlo import lower_to_hlo_text, spec_entry


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# Per-variant artifact descriptions
# ---------------------------------------------------------------------------


def ic_variant_artifacts(name: str, blocks: int, widen: int):
    """(artifact_name, fn, example_args, input_names, output_names) tuples."""
    specs = model.param_specs(blocks, widen)
    p_args = [_sds(s, F32) for _, s in specs]
    p_names = [n for n, _ in specs]
    v_names = [f"v_{n}" for n in p_names]
    x = _sds((model.BATCH, model.INPUT_DIM), F32)
    y = _sds((model.BATCH,), I32)
    scalar_f = _sds((), F32)
    scalar_i = _sds((), I32)

    train = (
        f"{name}_train",
        model.make_train_step(blocks, widen),
        [x, y, scalar_f, scalar_f, scalar_f, scalar_f, scalar_i] + p_args + p_args,
        ["x", "y", "lr", "momentum", "re_prob", "re_sh", "seed"] + p_names + v_names,
        ["loss", "acc"] + p_names + v_names,
    )
    ev = (
        f"{name}_eval",
        model.make_eval_step(blocks, widen),
        [x, y] + p_args,
        ["x", "y"] + p_names,
        ["loss", "acc"],
    )
    init = (
        f"{name}_init",
        model.make_init(blocks, widen),
        [scalar_i],
        ["seed"],
        p_names + v_names,
    )
    return [train, ev, init]


def qa_artifacts():
    specs = model_qa.param_specs()
    p_args = [_sds(s, F32) for _, s in specs]
    p_names = [n for n, _ in specs]
    v_names = [f"v_{n}" for n in p_names]
    ctx = _sds((model_qa.QA_BATCH, model_qa.CTX_LEN), I32)
    qry = _sds((model_qa.QA_BATCH, model_qa.QRY_LEN), I32)
    span = _sds((model_qa.QA_BATCH,), I32)
    scalar_f = _sds((), F32)
    scalar_i = _sds((), I32)

    train = (
        "qa_bidaf_train",
        model_qa.make_train_step(),
        [ctx, qry, span, span, scalar_f, scalar_f, scalar_f, scalar_i]
        + p_args
        + p_args,
        ["ctx", "qry", "y_start", "y_end", "lr", "momentum", "dropout", "seed"]
        + p_names
        + v_names,
        ["loss", "em"] + p_names + v_names,
    )
    ev = (
        "qa_bidaf_eval",
        model_qa.make_eval_step(),
        [ctx, qry, span, span] + p_args,
        ["ctx", "qry", "y_start", "y_end"] + p_names,
        ["loss", "em"],
    )
    init = ("qa_bidaf_init", model_qa.make_init(), [scalar_i], ["seed"], p_names + v_names)
    return [train, ev, init]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def build_manifest_entry(artifact_name, example_args, input_names, output_names):
    return {
        "file": f"{artifact_name}.hlo.txt",
        "inputs": [spec_entry(n, a) for n, a in zip(input_names, example_args)],
        "n_outputs": len(output_names),
        "output_names": output_names,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--variants",
        default="all",
        help="comma-separated artifact-name prefixes, or 'all'",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = []
    variants = {}
    for name, (blocks, widen) in model.IC_VARIANTS.items():
        jobs += ic_variant_artifacts(name, blocks, widen)
        variants[name] = {
            "task": "image_classification",
            "blocks": blocks,
            "widen": widen,
            "logical_depth": 6 * blocks + 2,
            "param_count": model.param_count(blocks, widen),
            "train": f"{name}_train",
            "eval": f"{name}_eval",
            "init": f"{name}_init",
            "hyperparams": ["lr", "momentum", "re_prob", "re_sh"],
            "measure": "test/accuracy",
        }
    jobs += qa_artifacts()
    variants["qa_bidaf"] = {
        "task": "question_answering",
        "blocks": 1,
        "widen": 1,
        "logical_depth": 1,
        "param_count": model_qa.param_count(),
        "train": "qa_bidaf_train",
        "eval": "qa_bidaf_eval",
        "init": "qa_bidaf_init",
        "hyperparams": ["lr", "momentum", "dropout"],
        "measure": "test/em",
    }

    if args.variants != "all":
        keep = tuple(args.variants.split(","))
        jobs = [j for j in jobs if j[0].startswith(keep)]

    manifest = {
        "format": 1,
        "data": {
            "image": {
                "height": model.IMG_H,
                "width": model.IMG_W,
                "channels": model.IMG_C,
                "input_dim": model.INPUT_DIM,
                "classes": model.NUM_CLASSES,
                "batch": model.BATCH,
            },
            "qa": {
                "vocab": model_qa.VOCAB,
                "embed_dim": model_qa.EMBED_DIM,
                "ctx_len": model_qa.CTX_LEN,
                "qry_len": model_qa.QRY_LEN,
                "batch": model_qa.QA_BATCH,
            },
        },
        "variants": variants,
        "artifacts": {},
    }

    for artifact_name, fn, example_args, input_names, output_names in jobs:
        path = os.path.join(args.out_dir, f"{artifact_name}.hlo.txt")
        text = lower_to_hlo_text(fn, example_args)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][artifact_name] = build_manifest_entry(
            artifact_name, example_args, input_names, output_names
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
