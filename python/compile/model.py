"""Layer-2: residual-MLP image classifier (the "ResNet/WRN-like" family).

The paper tunes ResNet / Wide-ResNet (+ Random Erasing) on CIFAR-100.  We
reproduce the *tuning problem* with a residual MLP over synthetic
CIFAR-like images: ``depth`` (number of residual blocks) and ``widen``
(hidden-width factor) are architecture hyperparameters selecting an AOT
variant, while ``lr``, ``momentum``, ``re_prob`` (erase probability) and
``re_sh`` (erase scale) are *runtime* scalar inputs of the compiled
``train_step`` — exactly the hyperparameters of the paper's Table 1 — so
CHOPT (Rust, L3) can tune them without recompilation.

Everything hot goes through the L1 Pallas kernels: ``fused_linear`` for
all layers, ``random_erase`` for augmentation, ``sgd_momentum`` for the
fused optimizer update.  fwd + bwd + update are one jitted function per
variant, AOT-lowered by ``aot.py`` to a single HLO module.

Parameter interchange with Rust is a *flat list* of arrays in the order
given by :func:`param_specs`; ``manifest.json`` records names/shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear
from .kernels.random_erase import random_erase, sample_rects
from .kernels.sgd_momentum import sgd_momentum_tree

# ---------------------------------------------------------------------------
# Problem dimensions (shared with rust via manifest.json "data" section)
# ---------------------------------------------------------------------------

IMG_H = 8
IMG_W = 8
IMG_C = 3
INPUT_DIM = IMG_H * IMG_W * IMG_C  # 192
NUM_CLASSES = 100
BATCH = 64
BASE_HIDDEN = 64


def hidden_dim(widen: int) -> int:
    return BASE_HIDDEN * widen


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------


def param_specs(blocks: int, widen: int):
    """Flat, ordered (name, shape) list — the Rust interchange contract."""
    h = hidden_dim(widen)
    specs = [("w_in", (INPUT_DIM, h)), ("b_in", (h,))]
    for i in range(blocks):
        specs += [
            (f"blk{i}_w1", (h, h)),
            (f"blk{i}_b1", (h,)),
            (f"blk{i}_w2", (h, h)),
            (f"blk{i}_b2", (h,)),
        ]
    specs += [("w_out", (h, NUM_CLASSES)), ("b_out", (NUM_CLASSES,))]
    return specs


def param_count(blocks: int, widen: int) -> int:
    """Total trainable parameters (Table 3's constraint metric)."""
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_specs(blocks, widen))


def make_init(blocks: int, widen: int):
    """init(seed) -> (*params, *velocities). He-normal weights, zero biases."""
    specs = param_specs(blocks, widen)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = []
        for name, shape in specs:
            key, sub = jax.random.split(key)
            if len(shape) == 2:
                fan_in = shape[0]
                w = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                    2.0 / fan_in
                )
                params.append(w)
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        velocities = [jnp.zeros(s, jnp.float32) for _, s in specs]
        return tuple(params) + tuple(velocities)

    return init


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def forward(params, x, blocks: int):
    """Pre-activation residual MLP. x: (B, INPUT_DIM) -> logits (B, C)."""
    idx = 0
    h = fused_linear(x, params[idx], params[idx + 1], "relu")
    idx += 2
    for _ in range(blocks):
        r = fused_linear(h, params[idx], params[idx + 1], "relu")
        r = fused_linear(r, params[idx + 2], params[idx + 3], "linear")
        h = jnp.maximum(h + r, 0.0)
        idx += 4
    return fused_linear(h, params[idx], params[idx + 1], "linear")


def cross_entropy(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def loss_and_acc(params, x, y, blocks: int):
    logits = forward(params, x, blocks)
    loss = cross_entropy(logits, y)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# Augmentation
# ---------------------------------------------------------------------------


def apply_random_erase(x, re_prob, re_sh, key):
    """Random Erasing on flattened images; re_prob == 0 is the identity."""
    b = x.shape[0]
    imgs = x.reshape(b, IMG_H, IMG_W, IMG_C)
    k_rect, k_apply = jax.random.split(key)
    rects = sample_rects(k_rect, b, IMG_H, IMG_W, re_sh)
    apply_mask = jax.random.bernoulli(k_apply, re_prob, (b,)).astype(jnp.float32)
    erased = random_erase(imgs, rects, apply_mask, 0.0)
    return erased.reshape(b, INPUT_DIM)


# ---------------------------------------------------------------------------
# Train / eval steps (the AOT entry points)
# ---------------------------------------------------------------------------


def make_train_step(blocks: int, widen: int):
    """train_step(x, y, lr, momentum, re_prob, re_sh, seed, *state).

    ``state`` is ``(*params, *velocities)`` per :func:`param_specs`.
    Returns ``(loss, acc, *new_state)``.
    """
    n = len(param_specs(blocks, widen))

    def train_step(x, y, lr, momentum, re_prob, re_sh, seed, *state):
        assert len(state) == 2 * n, f"expected {2*n} state arrays, got {len(state)}"
        params = list(state[:n])
        velocities = list(state[n:])
        key = jax.random.PRNGKey(seed)
        x_aug = apply_random_erase(x, re_prob, re_sh, key)

        def loss_fn(ps):
            return loss_and_acc(ps, x_aug, y, blocks)

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_velocities = sgd_momentum_tree(
            params, grads, velocities, lr, momentum
        )
        return (loss, acc) + tuple(new_params) + tuple(new_velocities)

    return train_step


def make_eval_step(blocks: int, widen: int):
    """eval_step(x, y, *params) -> (loss, acc) — no augmentation, no update."""
    n = len(param_specs(blocks, widen))

    def eval_step(x, y, *params):
        assert len(params) == n
        loss, acc = loss_and_acc(list(params), x, y, blocks)
        return loss, acc

    return eval_step


# ---------------------------------------------------------------------------
# Variant registry (what aot.py lowers)
# ---------------------------------------------------------------------------

# name -> (blocks, widen). Depth/widen mirror the paper's ResNet vs WRN
# families; the "+RE" behaviour is runtime (re_prob > 0), not a variant.
IC_VARIANTS = {
    "ic_d1_w1": (1, 1),
    "ic_d2_w1": (2, 1),
    "ic_d3_w1": (3, 1),
    "ic_d2_w2": (2, 2),
}
