"""Shared AOT lowering helper: jitted jax fn -> HLO *text*.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
XLA (xla_extension 0.5.1, the version the published ``xla`` crate pins)
rejects (``proto.id() <= INT_MAX``).  The text parser on the Rust side
reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc

DTYPE_NAMES = {
    "float32": "f32",
    "int32": "i32",
    "uint32": "u32",
    "bfloat16": "bf16",
}


def lower_to_hlo_text(fn, example_args) -> str:
    """Lower ``fn(*example_args)`` and return HLO text (tuple root)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_entry(name: str, aval) -> dict:
    """Manifest entry for one input/output aval."""
    dt = str(aval.dtype)
    return {
        "name": name,
        "shape": list(int(d) for d in aval.shape),
        "dtype": DTYPE_NAMES.get(dt, dt),
    }
