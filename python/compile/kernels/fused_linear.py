"""Pallas kernel: fused ``act(x @ w + b)`` with a Pallas backward pass.

This is the compute hot-spot of the residual-MLP image classifier and the
BiDAF-lite QA model: every layer is one call of this kernel, so the whole
L2 ``train_step`` graph is dominated by it.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):

* The grid tiles the *output* ``(M, N)`` plane; each program instance owns
  one ``(bm, bn)`` tile, streams the full ``K`` strip of ``x`` and ``w``
  through VMEM, and accumulates in f32 (MXU-style accumulation even for
  bf16 inputs).
* ``bm``/``bn`` default to MXU-friendly multiples (8 sublanes x 128 lanes)
  clamped to the problem size; non-dividing shapes are zero-padded by the
  wrapper (zero padding is exact for matmul, and the pad/slice pair fuses
  into the surrounding HLO).
* Bias-add + activation happen in-register before the tile is written
  back, so the fusion never round-trips HBM.

Autodiff: ``pallas_call`` has no built-in VJP, so ``fused_linear`` carries
a ``jax.custom_vjp``.  The forward kernel emits both the activated output
``y`` and the pre-activation ``z`` (one extra VMEM->HBM store, saving a
full recompute matmul in the backward).  The backward runs the activation
gradient element-wise and two Pallas matmuls (``dx = dz w^T``,
``dw = x^T dz``); ``db`` is a row-sum.

On this image all kernels run with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); the BlockSpec structure is what a real TPU
lowering would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ACTIVATIONS, apply_activation


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(dim: int, target: int) -> int:
    """Whole dim if it already fits, else the MXU-friendly target."""
    return dim if dim <= target else target


# ---------------------------------------------------------------------------
# Forward kernel: one (bm, bn) tile of y = act(x @ w + b), plus z
# ---------------------------------------------------------------------------


def _fused_linear_kernel(x_ref, w_ref, b_ref, y_ref, z_ref, *, activation: str):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    z = jnp.dot(x, w, preferred_element_type=jnp.float32)
    z = z + b_ref[...].astype(jnp.float32)[None, :]
    z_ref[...] = z.astype(z_ref.dtype)
    y_ref[...] = apply_activation(z, activation).astype(y_ref.dtype)


def _fused_linear_fwd_pallas(x, w, b, activation: str, bm: int, bn: int):
    m, k = x.shape
    _, n = w.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b

    y, z = pl.pallas_call(
        functools.partial(_fused_linear_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(xp, wp, bp)
    return y[:m, :n], z[:m, :n]


# ---------------------------------------------------------------------------
# Plain tiled matmul kernel (used by the backward pass)
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def matmul(a, b, bm: int = 64, bn: int = 128):
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N) f32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    ap = jnp.pad(a, ((0, mp - m), (0, 0))) if mp != m else a
    bp = jnp.pad(b, ((0, 0), (0, np_ - n))) if np_ != n else b
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Activation gradients (element-wise, fuse into surrounding HLO)
# ---------------------------------------------------------------------------


def activation_grad(dy, z, activation: str):
    """dz = dy * act'(z)."""
    if activation == "linear":
        return dy
    if activation == "relu":
        return dy * (z > 0.0).astype(dy.dtype)
    if activation == "tanh":
        t = jnp.tanh(z)
        return dy * (1.0 - t * t)
    if activation == "sigmoid":
        s = 1.0 / (1.0 + jnp.exp(-z))
        return dy * s * (1.0 - s)
    if activation == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        u = c * (z + 0.044715 * z**3)
        t = jnp.tanh(u)
        du = c * (1.0 + 3 * 0.044715 * z * z)
        return dy * (0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du)
    raise ValueError(f"unknown activation {activation!r}")


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_linear(x, w, b, activation: str = "linear", bm: int = 64, bn: int = 128):
    """``act(x @ w + b)`` via a tiled Pallas kernel (differentiable).

    x: (M, K); w: (K, N); b: (N,).  Returns (M, N) in ``x.dtype``.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    y, _ = _fused_linear_fwd_pallas(x, w, b, activation, bm, bn)
    return y


def _fl_fwd(x, w, b, activation, bm, bn):
    y, z = _fused_linear_fwd_pallas(x, w, b, activation, bm, bn)
    return y, (x, w, z)


def _fl_bwd(activation, bm, bn, res, dy):
    x, w, z = res
    dz = activation_grad(dy.astype(jnp.float32), z, activation)
    dx = matmul(dz, w.T.astype(jnp.float32), bm, bn).astype(x.dtype)
    dw = matmul(x.T.astype(jnp.float32), dz, bm, bn).astype(w.dtype)
    db = jnp.sum(dz, axis=0).astype(w.dtype)
    return dx, dw, db


fused_linear.defvjp(_fl_fwd, _fl_bwd)


# ---------------------------------------------------------------------------
# Perf models (used by EXPERIMENTS.md §Perf / DESIGN.md)
# ---------------------------------------------------------------------------


def vmem_bytes(m: int, k: int, n: int, bm: int = 64, bn: int = 128, itemsize: int = 4):
    """Estimated VMEM working set per program instance.

    x tile (bm, K) + w strip (K, bn) + bias (bn,) + y and z tiles
    (bm, bn each), all resident simultaneously; double-buffered inputs
    would double the first two terms.
    """
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return itemsize * (bm * k + k * bn + bn + 2 * bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int, bm: int = 64, bn: int = 128):
    """Fraction of the 128x128 MXU a tile keeps busy (padding tax model)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return min(bm / 128.0, 1.0) * min(bn / 128.0, 1.0) * min(k / 128.0, 1.0)
