"""Pure-jnp correctness oracles for every Pallas kernel in this package.

Each ``*_ref`` function is the mathematical definition of the matching
kernel; pytest (``python/tests/``) sweeps shapes/dtypes with hypothesis and
asserts ``assert_allclose`` between kernel and oracle.  The oracles are
also used by the L2 model tests as an independent forward-pass check.
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

ACTIVATIONS = ("linear", "relu", "tanh", "sigmoid", "gelu")


def apply_activation(h, activation: str):
    if activation == "linear":
        return h
    if activation == "relu":
        return jnp.maximum(h, 0.0)
    if activation == "tanh":
        return jnp.tanh(h)
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-h))
    if activation == "gelu":
        # tanh approximation (matches the kernel).
        c = jnp.sqrt(2.0 / jnp.pi).astype(h.dtype)
        return 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * h**3)))
    raise ValueError(f"unknown activation {activation!r}")


def fused_linear_ref(x, w, b, activation: str = "linear"):
    """act(x @ w + b) with f32 accumulation."""
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    acc = acc + b.astype(jnp.float32)[None, :]
    return apply_activation(acc, activation).astype(x.dtype)


# ---------------------------------------------------------------------------
# sgd_momentum
# ---------------------------------------------------------------------------


def sgd_momentum_ref(param, grad, velocity, lr, momentum):
    """Classic momentum: v' = mu*v + g ; p' = p - lr*v'. Returns (p', v')."""
    v = momentum * velocity + grad
    p = param - lr * v
    return p, v


# ---------------------------------------------------------------------------
# random_erase
# ---------------------------------------------------------------------------


def random_erase_ref(images, rects, apply_mask, fill):
    """Erase (set to ``fill``) a rectangle per image.

    images: (B, H, W, C) f32
    rects:  (B, 4) i32 rows of [y0, x0, h, w]
    apply_mask: (B,) f32 in {0, 1} — whether to erase this sample
    fill: scalar f32
    """
    _, h, w, _ = images.shape
    rows = jnp.arange(h)[None, :, None]  # (1, H, 1)
    cols = jnp.arange(w)[None, None, :]  # (1, 1, W)
    y0 = rects[:, 0][:, None, None]
    x0 = rects[:, 1][:, None, None]
    rh = rects[:, 2][:, None, None]
    rw = rects[:, 3][:, None, None]
    inside = (rows >= y0) & (rows < y0 + rh) & (cols >= x0) & (cols < x0 + rw)
    inside = inside & (apply_mask[:, None, None] > 0.5)
    return jnp.where(inside[..., None], jnp.asarray(fill, images.dtype), images)


# ---------------------------------------------------------------------------
# bidaf attention
# ---------------------------------------------------------------------------


def softmax_ref(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def bidaf_attention_ref(c, q):
    """Bidirectional attention flow (single example).

    c: (Lc, d) context encodings; q: (Lq, d) query encodings.
    Returns G: (Lc, 4d) = [c ; c2q ; c*c2q ; c*q2c] (Seo et al., 2016).
    """
    d = c.shape[-1]
    s = jnp.matmul(c, q.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))  # (Lc, Lq)
    a = softmax_ref(s, axis=1)  # context-to-query
    c2q = jnp.matmul(a, q)  # (Lc, d)
    b = softmax_ref(jnp.max(s, axis=1), axis=0)  # (Lc,) query-to-context
    q2c = jnp.sum(b[:, None] * c, axis=0)[None, :]  # (1, d)
    q2c = jnp.broadcast_to(q2c, c.shape)
    return jnp.concatenate([c, c2q, c * c2q, c * q2c], axis=-1)


def bidaf_attention_batched_ref(c, q):
    """Batched oracle: c (B, Lc, d), q (B, Lq, d) -> (B, Lc, 4d)."""
    import jax

    return jax.vmap(bidaf_attention_ref)(c, q)
