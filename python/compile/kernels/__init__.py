"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles.

Public surface used by the L2 models:

* :func:`fused_linear.fused_linear` — act(x@w+b), tiled.
* :func:`sgd_momentum.sgd_momentum` / ``sgd_momentum_tree`` — fused update.
* :func:`random_erase.random_erase` / ``sample_rects`` — RE augmentation.
* :func:`attention.bidaf_attention` — fused bidirectional attention.
"""

from . import attention, fused_linear, random_erase, ref, sgd_momentum  # noqa: F401
