"""Pallas kernel: batched Random Erasing (Zhong et al., 2017).

The data-augmentation hot-spot of the "+RE" model variants (Tables 2-3 of
the paper).  For each image in the batch, a rectangle ``[y0:y0+h, x0:x0+w]``
is overwritten with a fill value iff that sample's ``apply`` flag is set.

Rectangle geometry is *data*, not shape: the caller samples ``rects`` with
``jax.random`` inside the jitted train step (so the erase probability
``re_prob`` and scale ``re_sh`` stay runtime-tunable hyperparameters) and
the kernel builds the mask from 2-D iotas compared against the per-sample
bounds — no dynamic shapes, TPU-vectorizable, one pass over HBM.

Grid: one program instance per image; the (H, W, C) block plus the (1, 4)
rect row live in VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _erase_kernel(img_ref, rect_ref, apply_ref, fill_ref, o_ref):
    img = img_ref[...]  # (1, H, W, C)
    _, h, w, _ = img.shape
    y0 = rect_ref[0, 0]
    x0 = rect_ref[0, 1]
    rh = rect_ref[0, 2]
    rw = rect_ref[0, 3]
    rows = jax.lax.broadcasted_iota(jnp.int32, (1, h, w, 1), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, h, w, 1), 2)
    inside = (rows >= y0) & (rows < y0 + rh) & (cols >= x0) & (cols < x0 + rw)
    inside = inside & (apply_ref[0] > 0.5)
    o_ref[...] = jnp.where(inside, fill_ref[0].astype(img.dtype), img)


@jax.jit
def random_erase(images, rects, apply_mask, fill):
    """Erase one rectangle per image.

    images: (B, H, W, C) f32; rects: (B, 4) i32 [y0, x0, h, w];
    apply_mask: (B,) f32 in {0,1}; fill: scalar f32.
    """
    b, h, w, c = images.shape
    assert rects.shape == (b, 4), rects.shape
    assert apply_mask.shape == (b,), apply_mask.shape
    fill1 = jnp.reshape(jnp.asarray(fill, images.dtype), (1,))
    return pl.pallas_call(
        _erase_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(images.shape, images.dtype),
        interpret=True,
    )(images, rects, apply_mask, fill1)


def sample_rects(key, batch, height, width, re_sh):
    """Sample per-image erase rectangles inside the jitted train step.

    ``re_sh`` (the paper's ``sh`` hyperparameter) scales the maximum
    erased side length as a fraction of the image side.  Traced-scalar
    friendly: all shapes are static, only values depend on ``re_sh``.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    max_h = jnp.clip(re_sh * height, 1.0, float(height))
    max_w = jnp.clip(re_sh * width, 1.0, float(width))
    rh = jnp.floor(jax.random.uniform(k1, (batch,)) * max_h).astype(jnp.int32) + 1
    rw = jnp.floor(jax.random.uniform(k2, (batch,)) * max_w).astype(jnp.int32) + 1
    rh = jnp.minimum(rh, height)
    rw = jnp.minimum(rw, width)
    y0 = jnp.floor(
        jax.random.uniform(k3, (batch,)) * (height - rh).astype(jnp.float32)
    ).astype(jnp.int32)
    x0 = jnp.floor(
        jax.random.uniform(k4, (batch,)) * (width - rw).astype(jnp.float32)
    ).astype(jnp.int32)
    return jnp.stack([y0, x0, rh, rw], axis=1)
