"""Pallas kernel: BiDAF bidirectional attention flow (Seo et al., 2016).

The QA hot-spot.  For each batch element the kernel fuses the whole
attention block in VMEM:

    S    = C Q^T / sqrt(d)                (Lc, Lq) similarity
    A    = softmax_rows(S)                context-to-query weights
    c2q  = A Q                            (Lc, d)
    bvec = softmax(max_cols(S))           (Lc,)  query-to-context weights
    q2c  = sum_i bvec_i C_i               (d,), broadcast to (Lc, d)
    G    = [C ; c2q ; C*c2q ; C*q2c]      (Lc, 4d)

One HBM read of C and Q, one HBM write of G — the similarity matrix and
both softmaxes never leave VMEM (the flash-attention-style fusion, sized
for BiDAF's short sequences where the whole S tile fits at once).

Grid: one program instance per batch element.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_last(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _bidaf_kernel(c_ref, q_ref, o_ref):
    c = c_ref[0]  # (Lc, d)
    q = q_ref[0]  # (Lq, d)
    d = c.shape[-1]
    s = jnp.dot(c, q.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    a = _softmax_last(s)  # (Lc, Lq)
    c2q = jnp.dot(a, q, preferred_element_type=jnp.float32)  # (Lc, d)
    b = _softmax_last(jnp.max(s, axis=1)[None, :])[0]  # (Lc,)
    q2c = jnp.dot(b[None, :], c, preferred_element_type=jnp.float32)  # (1, d)
    q2c = jnp.broadcast_to(q2c, c.shape)
    g = jnp.concatenate([c, c2q, c * c2q, c * q2c], axis=-1)
    o_ref[0] = g.astype(o_ref.dtype)


def _bidaf_pallas(c, q):
    b, lc, d = c.shape
    b2, lq, d2 = q.shape
    assert b == b2 and d == d2, (c.shape, q.shape)
    return pl.pallas_call(
        _bidaf_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, lc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lq, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lc, 4 * d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lc, 4 * d), c.dtype),
        interpret=True,
    )(c, q)


# ``pallas_call`` has no built-in VJP.  The forward runs the fused Pallas
# kernel; the backward applies the vjp of the mathematically identical
# pure-jnp oracle (XLA fuses it) — the standard "custom forward kernel,
# compiler-generated backward" pattern.  Equivalence of the two forwards
# is pinned by python/tests/test_attention.py, which makes the pairing
# exact up to float association.
@jax.custom_vjp
def bidaf_attention(c, q):
    """Batched BiDAF attention: c (B, Lc, d), q (B, Lq, d) -> (B, Lc, 4d)."""
    return _bidaf_pallas(c, q)


def _bidaf_fwd(c, q):
    return _bidaf_pallas(c, q), (c, q)


def _bidaf_bwd(res, dg):
    from .ref import bidaf_attention_batched_ref

    c, q = res
    _, vjp = jax.vjp(bidaf_attention_batched_ref, c, q)
    return vjp(dg)


bidaf_attention.defvjp(_bidaf_fwd, _bidaf_bwd)


def vmem_bytes(lc: int, lq: int, d: int, itemsize: int = 4) -> int:
    """VMEM working set per program instance: C, Q, S, A, G resident."""
    return itemsize * (lc * d + lq * d + 2 * lc * lq + lc * 4 * d)
