"""Pallas kernel: fused SGD-with-momentum parameter update.

Computes, element-wise over a flat parameter vector::

    v' = momentum * v + g
    p' = p - lr * v'

in a single pass, so each parameter/velocity element is read once and
written once per optimizer step (three HBM reads + two writes per
element, vs five reads + two writes for the unfused jnp expression).

The scalars ``lr`` and ``momentum`` are runtime inputs — CHOPT tunes them —
passed as (1,)-shaped arrays pinned to block (0,) of every program
instance (the SMEM-scalar idiom; interpret mode has no SMEM but keeps the
structure).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(p_ref, g_ref, v_ref, lr_ref, mu_ref, po_ref, vo_ref):
    lr = lr_ref[0]
    mu = mu_ref[0]
    v = mu * v_ref[...] + g_ref[...]
    vo_ref[...] = v
    po_ref[...] = p_ref[...] - lr * v


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_momentum_flat(param, grad, velocity, lr, momentum, block: int = 1024):
    """Fused update over 1-D arrays. Returns (new_param, new_velocity)."""
    (n,) = param.shape
    assert grad.shape == (n,) and velocity.shape == (n,)
    blk = min(block, n) if n > 0 else 1
    np_ = _round_up(max(n, 1), blk)
    pad = np_ - n

    def padded(a):
        return jnp.pad(a, (0, pad)) if pad else a

    lr1 = jnp.reshape(jnp.asarray(lr, param.dtype), (1,))
    mu1 = jnp.reshape(jnp.asarray(momentum, param.dtype), (1,))
    p2, v2 = pl.pallas_call(
        _sgd_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), param.dtype),
            jax.ShapeDtypeStruct((np_,), param.dtype),
        ],
        interpret=True,
    )(padded(param), padded(grad), padded(velocity), lr1, mu1)
    return p2[:n], v2[:n]


def sgd_momentum(param, grad, velocity, lr, momentum):
    """Shape-polymorphic wrapper: flattens, updates, restores shape."""
    shape = param.shape
    p, v = sgd_momentum_flat(
        param.reshape(-1), grad.reshape(-1), velocity.reshape(-1), lr, momentum
    )
    return p.reshape(shape), v.reshape(shape)


def sgd_momentum_tree(params, grads, velocities, lr, momentum):
    """Apply the fused update across a list of parameter arrays."""
    new_p, new_v = [], []
    for p, g, v in zip(params, grads, velocities):
        p2, v2 = sgd_momentum(p, g, v, lr, momentum)
        new_p.append(p2)
        new_v.append(v2)
    return new_p, new_v
