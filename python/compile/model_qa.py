"""Layer-2: BiDAF-lite question-answering model (SQuAD-like task).

Reproduces the paper's QA tuning problem (BiDAF on SQuAD 1.1) at toy
scale: embedding -> token-wise tanh encoder (fused_linear kernel) ->
bidirectional attention flow (Pallas attention kernel) -> modeling layer
-> answer-span start/end logits over the context.

Runtime-tunable hyperparameters (scalar inputs of the AOT ``train_step``):
``lr``, ``momentum``, ``dropout`` (embedding dropout rate).  Metric is
exact-match (start and end both correct), the "test/em"-style measure the
paper optimizes for BiDAF.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.attention import bidaf_attention
from .kernels.fused_linear import fused_linear
from .kernels.sgd_momentum import sgd_momentum_tree

# ---------------------------------------------------------------------------
# Problem dimensions (shared with rust via manifest.json)
# ---------------------------------------------------------------------------

VOCAB = 256
EMBED_DIM = 32
CTX_LEN = 32
QRY_LEN = 16
QA_BATCH = 32


def param_specs():
    d = EMBED_DIM
    return [
        ("embed", (VOCAB, d)),
        ("w_enc", (d, d)),
        ("b_enc", (d,)),
        ("w_model", (4 * d, d)),
        ("b_model", (d,)),
        ("w_start", (d, 1)),
        ("b_start", (1,)),
        ("w_end", (d, 1)),
        ("b_end", (1,)),
    ]


def param_count() -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_specs())


def make_init():
    specs = param_specs()

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = []
        for name, shape in specs:
            key, sub = jax.random.split(key)
            if name == "embed":
                # Unit-scale embeddings: token-identity matches must
                # produce O(1) attention logits from step 0, otherwise the
                # tanh encoder squashes the similarity signal and span
                # learning stalls.
                params.append(jax.random.normal(sub, shape, jnp.float32))
            elif len(shape) == 2:
                scale = jnp.sqrt(1.0 / shape[0])
                params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        velocities = [jnp.zeros(s, jnp.float32) for _, s in specs]
        return tuple(params) + tuple(velocities)

    return init


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _encode(tokens, embed, w_enc, b_enc, dropout, key):
    """Embed + dropout + token-wise tanh projection via the L1 kernel."""
    b, length = tokens.shape
    d = embed.shape[1]
    emb = jnp.take(embed, tokens, axis=0)  # (B, L, d)
    keep = 1.0 - dropout
    mask = jax.random.bernoulli(key, keep, emb.shape).astype(emb.dtype)
    # Inverted dropout; dropout==0 -> identity (keep==1, mask==1).
    emb = emb * mask / jnp.maximum(keep, 1e-6)
    enc = fused_linear(emb.reshape(b * length, d), w_enc, b_enc, "tanh")
    return enc.reshape(b, length, d)


def forward(params, ctx, qry, dropout, key):
    """Returns (start_logits, end_logits), each (B, CTX_LEN)."""
    embed, w_enc, b_enc, w_model, b_model, w_start, b_start, w_end, b_end = params
    k_c, k_q = jax.random.split(key)
    c_enc = _encode(ctx, embed, w_enc, b_enc, dropout, k_c)
    q_enc = _encode(qry, embed, w_enc, b_enc, dropout, k_q)
    g = bidaf_attention(c_enc, q_enc)  # (B, Lc, 4d)
    b, lc, gd = g.shape
    m = fused_linear(g.reshape(b * lc, gd), w_model, b_model, "tanh")
    start = fused_linear(m, w_start, b_start, "linear").reshape(b, lc)
    end = fused_linear(m, w_end, b_end, "linear").reshape(b, lc)
    return start, end


def cross_entropy(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def loss_and_em(params, ctx, qry, y_start, y_end, dropout, key):
    start, end = forward(params, ctx, qry, dropout, key)
    loss = cross_entropy(start, y_start) + cross_entropy(end, y_end)
    em = jnp.mean(
        (
            (jnp.argmax(start, axis=-1) == y_start)
            & (jnp.argmax(end, axis=-1) == y_end)
        ).astype(jnp.float32)
    )
    return loss, em


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

N_PARAMS = len(param_specs())


def make_train_step():
    """train_step(ctx, qry, y_start, y_end, lr, momentum, dropout, seed, *state)."""

    def train_step(ctx, qry, y_start, y_end, lr, momentum, dropout, seed, *state):
        assert len(state) == 2 * N_PARAMS
        params = list(state[:N_PARAMS])
        velocities = list(state[N_PARAMS:])
        key = jax.random.PRNGKey(seed)

        def loss_fn(ps):
            return loss_and_em(ps, ctx, qry, y_start, y_end, dropout, key)

        (loss, em), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_velocities = sgd_momentum_tree(
            params, grads, velocities, lr, momentum
        )
        return (loss, em) + tuple(new_params) + tuple(new_velocities)

    return train_step


def make_eval_step():
    """eval_step(ctx, qry, y_start, y_end, *params) -> (loss, em)."""

    def eval_step(ctx, qry, y_start, y_end, *params):
        assert len(params) == N_PARAMS
        key = jax.random.PRNGKey(0)
        loss, em = loss_and_em(
            list(params), ctx, qry, y_start, y_end, jnp.float32(0.0), key
        )
        return loss, em

    return eval_step
