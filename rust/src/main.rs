//! `chopt` — CLI for the CHOPT coordinator.
//!
//! Subcommands:
//!   run            run a CHOPT session from a config file (sim or real)
//!   example-config print the paper's Listing-1 example configuration
//!   artifacts      inspect the AOT artifact manifest
//!   serve          serve stored results through the viz HTTP server

use std::collections::HashSet;

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::storage::SessionStore;
use chopt::trainer::{real::RealTrainer, surrogate::SurrogateTrainer, Trainer};
use chopt::util::cli::{CliError, Command};
use chopt::viz;

fn cli() -> Command {
    Command::new("chopt", "cloud-based hyperparameter optimization framework")
        .subcommand(
            Command::new("run", "run a CHOPT session from a config file")
                .opt_required("config", "path to a Listing-1 style JSON config")
                .opt("gpus", Some("8"), "simulated cluster size")
                .opt("out", Some("reports/run"), "output directory for exports")
                .opt("seed", None, "override the config seed")
                .flag("real", "train with the PJRT runtime instead of the surrogate"),
        )
        .subcommand(Command::new(
            "example-config",
            "print the paper's Listing-1 example configuration",
        ))
        .subcommand(
            Command::new("artifacts", "inspect the AOT artifact manifest")
                .opt("dir", Some("artifacts"), "artifacts directory"),
        )
        .subcommand(
            Command::new("serve", "serve a stored run through the viz server")
                .opt_required("store", "path to a sessions.json written by `run`")
                .opt("port", Some("8787"), "listen port"),
        )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = cli();
    let matches = match cmd.parse(&argv) {
        Ok(m) => m,
        Err(CliError::HelpRequested) => {
            print!("{}", cmd.help_text());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cmd.help_text());
            std::process::exit(2);
        }
    };
    let result = match &matches.subcommand {
        Some((name, sub)) => match name.as_str() {
            "run" => cmd_run(sub),
            "example-config" => {
                println!("{}", chopt::config::LISTING1_EXAMPLE);
                Ok(())
            }
            "artifacts" => cmd_artifacts(sub),
            "serve" => cmd_serve(sub),
            _ => unreachable!(),
        },
        None => {
            print!("{}", cmd.help_text());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_run(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let mut cfg = ChoptConfig::load(m.get("config").unwrap())?;
    if let Some(seed) = m.get_u64("seed") {
        cfg.seed = seed;
    }
    let gpus = m.get_usize("gpus").unwrap_or(8);
    let out_dir = m.get_or("out", "reports/run").to_string();
    let use_real = m.flag("real");
    let space = cfg.space.clone();
    let order = cfg.order;

    println!(
        "running CHOPT: tune={} model={} population={} step={} gpus={gpus} real={use_real}",
        cfg.tune.name(),
        cfg.model,
        cfg.population,
        cfg.step
    );
    let seed = cfg.seed;
    let outcome = run_sim(SimSetup::single(cfg, gpus), move |id| -> Box<dyn Trainer> {
        if use_real {
            Box::new(
                RealTrainer::new(chopt::runtime::Manifest::default_dir(), seed + id)
                    .expect("real trainer requires `make artifacts`"),
            )
        } else {
            Box::new(SurrogateTrainer::new(seed + id))
        }
    });

    for agent in &outcome.agents {
        viz::report::outcome_table(agent).print();
        let sessions: Vec<_> = agent.sessions.values().cloned().collect();
        viz::report::leaderboard_table(&sessions, order, 5).print();

        // Exports.
        let mut store = SessionStore::new();
        store.put_run(&format!("chopt-{}", agent.id), sessions.clone());
        store.save(format!("{out_dir}/sessions.json"))?;
        let doc = viz::export::parallel_coords_doc(&space, &sessions, order, "run");
        std::fs::write(
            format!("{out_dir}/parallel.json"),
            doc.to_string_pretty(),
        )?;
        let svg = viz::parallel_coords::render(
            &space,
            &[viz::parallel_coords::RunGroup {
                label: "run",
                sessions: &sessions,
            }],
            order,
            &HashSet::new(),
        );
        svg.save(format!("{out_dir}/parallel.svg"))?;
        println!("exports written to {out_dir}/");
    }
    println!(
        "done: {} events, {:.1} virtual hours, {:.1} CHOPT GPU-hours",
        outcome.events_processed,
        outcome.end_time / 3600.0,
        outcome.gpu_hours()
    );
    Ok(())
}

fn cmd_artifacts(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let dir = m.get_or("dir", "artifacts");
    let manifest = chopt::runtime::Manifest::load(dir)?;
    println!("artifacts dir: {dir}");
    println!(
        "data: input_dim={} classes={} batch={} | qa vocab={} ctx={} qry={} batch={}",
        manifest.data.input_dim,
        manifest.data.classes,
        manifest.data.batch,
        manifest.data.qa_vocab,
        manifest.data.qa_ctx_len,
        manifest.data.qa_qry_len,
        manifest.data.qa_batch
    );
    let mut names: Vec<_> = manifest.variants.keys().collect();
    names.sort();
    for name in names {
        let v = &manifest.variants[name];
        println!(
            "variant {name}: task={} blocks={} widen={} params={} measure={}",
            v.task, v.blocks, v.widen, v.param_count, v.measure
        );
    }
    Ok(())
}

fn cmd_serve(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let store_path = m.get("store").unwrap();
    let port: u16 = m.get_usize("port").unwrap_or(8787) as u16;
    let doc = SessionStore::load_json(store_path)?;
    let mut routes = viz::server::Routes::new();
    routes.insert(
        "/api/sessions.json".into(),
        (
            "application/json".into(),
            doc.to_string_pretty().into_bytes(),
        ),
    );
    let server = viz::server::VizServer::start(port, routes)?;
    println!("serving {store_path} on http://{}/ (ctrl-c to stop)", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
