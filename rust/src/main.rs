//! `chopt` — CLI for the CHOPT coordinator.
//!
//! Subcommands:
//!   run            run a CHOPT session from a config file (sim or real)
//!   watch          run through the live Platform: progress stream,
//!                  periodic snapshots, stop-and-go restore
//!   multi          run N studies from a manifest on one shared cluster
//!                  (fair-share quotas + cross-study Stop-and-Go)
//!   sweep          evaluate a (scenario x tuner x policy) grid from a
//!                  sweep spec into a comparison artifact (sweep.json)
//!   validate       check a manifest / scenario / sweep spec without
//!                  running it (file:line:col diagnostics)
//!   example-config print the paper's Listing-1 example configuration
//!   artifacts      inspect the AOT artifact manifest
//!   serve          serve stored results, a sweep artifact, or a live
//!                  run through the viz HTTP server

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, MultiPlatform, Platform, SimSetup, StudyManifest, StudySpec};
use chopt::storage::{SessionStore, StoredRun};
use chopt::trainer::{real::RealTrainer, surrogate, surrogate::SurrogateTrainer, Trainer};
use chopt::util::cli::{CliError, Command};
use chopt::viz;
use chopt::viz::api::{ApiQuery, RunSource};
use chopt::viz::fanout::{FanoutConfig, FanoutSource};
use chopt::viz::sse::EventFeed;

fn cli() -> Command {
    Command::new("chopt", "cloud-based hyperparameter optimization framework")
        .subcommand(
            Command::new("run", "run a CHOPT session from a config file")
                .opt_required("config", "path to a Listing-1 style JSON config")
                .opt("gpus", Some("8"), "simulated cluster size")
                .opt("out", Some("reports/run"), "output directory for exports")
                .opt("seed", None, "override the config seed")
                .flag("real", "train with the PJRT runtime instead of the surrogate"),
        )
        .subcommand(
            Command::new("watch", "run through the live Platform, observable as it goes")
                .opt("config", None, "path to a Listing-1 style JSON config")
                .opt("restore", None, "resume from a snapshot.json instead of a config")
                .opt("gpus", Some("8"), "simulated cluster size")
                .opt(
                    "out",
                    Some("reports/watch"),
                    "output directory (events.jsonl, snapshot.json, exports)",
                )
                .opt("seed", None, "override the config seed")
                .opt("chunk", Some("3600"), "virtual seconds per progress report")
                .opt("snapshot-every", Some("14400"), "virtual seconds between snapshots"),
        )
        .subcommand(
            Command::new("multi", "run N studies from a manifest on one shared cluster")
                .opt("manifest", None, "path to a studies manifest (see README)")
                .opt("restore", None, "resume from a multi-study snapshot.json")
                .opt(
                    "out",
                    Some("reports/multi"),
                    "output directory (events-<study>.jsonl, snapshot.json, fair_share.json)",
                )
                .opt("chunk", Some("3600"), "virtual seconds per progress report")
                .opt("snapshot-every", Some("14400"), "virtual seconds between snapshots")
                .opt(
                    "step-threads",
                    Some("1"),
                    "worker threads for windowed study stepping (bit-identical output)",
                )
                .opt(
                    "scenario",
                    None,
                    "scenario JSON (adversarial cluster weather) overriding the manifest's",
                )
                .opt(
                    "shards",
                    Some("1"),
                    "engine-worker shards (sharded control plane; requires borrow: false)",
                )
                .opt(
                    "queue-capacity",
                    Some("64"),
                    "bounded submission-queue depth (sharded runs; overflow spills + retries)",
                ),
        )
        .subcommand(
            Command::new("sweep", "evaluate a (scenario x tuner x policy) grid from a sweep spec")
                .opt_required("spec", "path to a sweep spec JSON (see README §Sweeps)")
                .opt(
                    "out",
                    Some("reports/sweep"),
                    "output directory (cells/<id>/..., sweep.json)",
                )
                .opt("cell-workers", Some("2"), "worker threads running whole cells in parallel")
                .flag("resume", "keep completed cells whose content hash matches the plan")
                .flag("quiet", "suppress per-cell progress lines"),
        )
        .subcommand(
            Command::new("validate", "check a manifest / scenario / sweep spec without running it")
                .opt("manifest", None, "studies manifest to check")
                .opt("scenario", None, "scenario JSON to check")
                .opt("sweep", None, "sweep spec JSON to check (axes + base manifest + cells)"),
        )
        .subcommand(Command::new(
            "example-config",
            "print the paper's Listing-1 example configuration",
        ))
        .subcommand(
            Command::new("artifacts", "inspect the AOT artifact manifest")
                .opt("dir", Some("artifacts"), "artifacts directory"),
        )
        .subcommand(
            Command::new("serve", "serve a stored run (or a live one) through the viz server")
                .opt(
                    "store",
                    None,
                    "run directory (snapshot.json + events JSONL) written by `watch`/`multi`",
                )
                .opt(
                    "sweep",
                    None,
                    "sweep directory (or sweep.json) written by `sweep`; serves \
                     /api/v1/sweep read-only",
                )
                .opt("port", Some("8787"), "listen port")
                .flag("live", "drive a run in-process and answer /api/v1 as it advances")
                .opt("config", None, "config for --live mode")
                .opt("manifest", None, "studies manifest for multi-study --live mode")
                .opt("gpus", Some("8"), "simulated cluster size (--live)")
                .opt("chunk", Some("1800"), "virtual seconds advanced per refresh (--live)")
                .opt("throttle-ms", Some("250"), "wall-clock pause between refreshes (--live)")
                .opt(
                    "step-threads",
                    Some("1"),
                    "worker threads for windowed study stepping (multi-study --live)",
                )
                .opt(
                    "shards",
                    Some("1"),
                    "engine-worker shards for multi-study --live (requires borrow: false)",
                )
                .opt(
                    "queue-capacity",
                    Some("64"),
                    "bounded submission-queue depth (sharded --live; overflow spills + retries)",
                )
                .opt(
                    "scenario",
                    None,
                    "scenario JSON (adversarial cluster weather) overriding the manifest's (--live)",
                )
                .opt(
                    "api-token",
                    None,
                    "bearer token for POST /api/v1/commands (or CHOPT_API_TOKEN; reads stay open)",
                )
                .opt("http-workers", Some("8"), "HTTP worker threads (request concurrency)")
                .opt(
                    "http-queue",
                    Some("128"),
                    "pending-connection queue depth (beyond it, connections get 503)",
                )
                .opt(
                    "cache-mb",
                    Some("32"),
                    "response-cache budget in MiB (0 disables caching; ETags stay on)",
                )
                .opt(
                    "out",
                    None,
                    "directory for the SSE history log (--live; enables /api/v1/events?since=N \
                     below the ring's retention window)",
                ),
        )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = cli();
    let matches = match cmd.parse(&argv) {
        Ok(m) => m,
        Err(CliError::HelpRequested) => {
            print!("{}", cmd.help_text());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cmd.help_text());
            std::process::exit(2);
        }
    };
    let result = match &matches.subcommand {
        Some((name, sub)) => match name.as_str() {
            "run" => cmd_run(sub),
            "watch" => cmd_watch(sub),
            "multi" => cmd_multi(sub),
            "sweep" => cmd_sweep(sub),
            "validate" => cmd_validate(sub),
            "example-config" => {
                println!("{}", chopt::config::LISTING1_EXAMPLE);
                Ok(())
            }
            "artifacts" => cmd_artifacts(sub),
            "serve" => cmd_serve(sub),
            _ => unreachable!(),
        },
        None => {
            print!("{}", cmd.help_text());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_run(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let mut cfg = ChoptConfig::load(m.get("config").unwrap())?;
    if let Some(seed) = m.get_u64("seed") {
        cfg.seed = seed;
    }
    let gpus = m.get_usize("gpus").unwrap_or(8);
    let out_dir = m.get_or("out", "reports/run").to_string();
    let use_real = m.flag("real");
    let space = cfg.space.clone();
    let order = cfg.order;

    println!(
        "running CHOPT: tune={} model={} population={} step={} gpus={gpus} real={use_real}",
        cfg.tune.name(),
        cfg.model,
        cfg.population,
        cfg.step
    );
    let seed = cfg.seed;
    let outcome = run_sim(SimSetup::single(cfg, gpus), move |id| -> Box<dyn Trainer> {
        if use_real {
            Box::new(
                RealTrainer::new(chopt::runtime::Manifest::default_dir(), seed + id)
                    .expect("real trainer requires `make artifacts`"),
            )
        } else {
            Box::new(SurrogateTrainer::new(seed + id))
        }
    });

    for agent in &outcome.agents {
        viz::report::outcome_table(agent).print();
        let sessions: Vec<_> = agent.sessions.values().cloned().collect();
        viz::report::leaderboard_table(&sessions, order, 5).print();

        // Exports.
        let mut store = SessionStore::new();
        store.put_run(&format!("chopt-{}", agent.id), sessions.clone());
        store.save(format!("{out_dir}/sessions.json"))?;
        let doc = viz::export::parallel_coords_doc(&space, &sessions, order, "run");
        std::fs::write(
            format!("{out_dir}/parallel.json"),
            doc.to_string_pretty(),
        )?;
        let svg = viz::parallel_coords::render(
            &space,
            &[viz::parallel_coords::RunGroup {
                label: "run",
                sessions: &sessions,
            }],
            order,
            &HashSet::new(),
        );
        svg.save(format!("{out_dir}/parallel.svg"))?;
        println!("exports written to {out_dir}/");
    }
    println!(
        "done: {} events, {:.1} virtual hours, {:.1} CHOPT GPU-hours",
        outcome.events_processed,
        outcome.end_time / 3600.0,
        outcome.gpu_hours()
    );
    Ok(())
}

/// `chopt watch`: drive a run through the live [`Platform`] — structured
/// progress on stdout, a JSONL event stream, periodic snapshots, and
/// stop-and-go resume via `--restore`.
fn cmd_watch(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let out_dir = m.get_or("out", "reports/watch").to_string();
    let chunk = m.get_f64("chunk").unwrap_or(3600.0).max(1.0);
    let snap_every = m.get_f64("snapshot-every").unwrap_or(14400.0);
    let snap_path = format!("{out_dir}/snapshot.json");
    std::fs::create_dir_all(&out_dir)?;

    let mut platform = if let Some(restore) = m.get("restore") {
        // The factory seed comes from the snapshot's own configs, so a
        // restored run replays with the trainers the original run built.
        let platform = Platform::restore(restore, surrogate::default_factory)?;
        println!(
            "restored from {restore}: t={:.0}s, {} events replayed",
            platform.now(),
            platform.engine().events_processed()
        );
        // The previous process logged transitions past the snapshot point
        // before it died; the continued run re-emits that window, so trim
        // those records or the append-mode log would hold them twice.
        trim_event_log(&format!("{out_dir}/events.jsonl"), platform.now())?;
        platform
    } else {
        let Some(config_path) = m.get("config") else {
            anyhow::bail!("watch needs --config (or --restore)");
        };
        let mut cfg = ChoptConfig::load(config_path)?;
        if let Some(seed) = m.get_u64("seed") {
            cfg.seed = seed;
        }
        let gpus = m.get_usize("gpus").unwrap_or(8);
        println!(
            "watching CHOPT: tune={} model={} population={} gpus={gpus}",
            cfg.tune.name(),
            cfg.model,
            cfg.population
        );
        // Fresh run: a leftover log from a previous run would be appended
        // to (EventLog opens in append mode, which is what --restore
        // wants), interleaving two runs' histories — start clean instead.
        // The old snapshot goes too: until this run's first snapshot
        // lands, --restore would otherwise silently resume the *previous*
        // run on top of this run's log.
        let _ = std::fs::remove_file(format!("{out_dir}/events.jsonl"));
        let _ = std::fs::remove_file(&snap_path);
        Platform::new(SimSetup::single(cfg, gpus), surrogate::default_factory)
    };
    platform = platform
        .with_event_log(format!("{out_dir}/events.jsonl"))?
        .with_snapshots(&snap_path, snap_every);

    loop {
        let n = platform.advance(chunk);
        let status = platform.status_doc();
        println!(
            "t={:>10.0}s events={:>7} queue={} agents={} pools l/s/d={}/{}/{} best={}",
            platform.now(),
            status.get("events_processed").and_then(|v| v.as_i64()).unwrap_or(0),
            status.get("queue_len").and_then(|v| v.as_i64()).unwrap_or(0),
            status.get("active_agents").and_then(|v| v.as_i64()).unwrap_or(0),
            status.get("pool_live").and_then(|v| v.as_i64()).unwrap_or(0),
            status.get("pool_stop").and_then(|v| v.as_i64()).unwrap_or(0),
            status.get("pool_dead").and_then(|v| v.as_i64()).unwrap_or(0),
            status
                .get("best")
                .and_then(|v| v.as_f64())
                .map(|b| format!("{b:.2}"))
                .unwrap_or_else(|| "-".into()),
        );
        if platform.is_done() || n == 0 {
            break;
        }
    }
    platform.snapshot_now()?;

    // Final exports (same shape `run` writes, so `serve --store` works).
    std::fs::write(
        format!("{out_dir}/sessions.json"),
        platform.sessions_doc().to_string_pretty(),
    )?;
    let sessions = platform.sessions();
    if let Some(agent) = platform.engine().all_agents().next() {
        viz::report::leaderboard_table(&sessions, agent.cfg.order, 5).print();
    }
    println!(
        "done: {} events, {:.1} virtual hours, {} progress events\nwrote {out_dir}/{{events.jsonl,snapshot.json,sessions.json}}\nresume anytime: chopt watch --restore {snap_path}",
        platform.engine().events_processed(),
        platform.now() / 3600.0,
        platform.progress_events,
    );
    Ok(())
}

/// The trainer factory every multi-study entry point shares —
/// `chopt multi`, `--restore`, `serve --live --manifest`, and
/// `serve --store` on a multi run directory all resolve to the
/// library's one definition (restore-by-replay requires the factory
/// the original run used).
fn multi_trainer(study: usize, id: u64) -> Box<dyn Trainer + Send> {
    surrogate::default_multi_factory(study, id)
}

/// Take the scenario-driven submissions out of a manifest.  The driver
/// admits each one by *splitting its advance* at the requested time —
/// `run_until(sub.at)` then `submit_study(spec, sub.at)` — so a
/// submission lands at exactly `submit_at` in every topology (single
/// scheduler or `--shards N`), never clamped forward by a chunk
/// boundary that overshot it.
fn take_scenario_submissions(
    manifest: &mut StudyManifest,
) -> anyhow::Result<Vec<(f64, StudySpec)>> {
    let mut subs = Vec::new();
    if let Some(sc) = manifest.scenario.as_mut() {
        let taken = std::mem::take(&mut sc.submissions);
        for (i, sub) in taken.iter().enumerate() {
            subs.push((
                sub.at,
                StudySpec::from_json(&sub.spec, manifest.studies.len() + i)?,
            ));
        }
        // A submissions-only scenario leaves nothing for the scheduler
        // to poll; dropping it keeps parallel stepping eligible.
        if sc.sources.is_empty() {
            manifest.scenario = None;
        }
    }
    subs.sort_by(|a, b| a.0.total_cmp(&b.0));
    Ok(subs)
}

/// Advance a single-scheduler [`MultiPlatform`] by `chunk`, splitting
/// at each pending scenario submission (see
/// [`take_scenario_submissions`]).  Jumps idle gaps to the next
/// submission so a pending study is never stranded behind a drained
/// event queue.  Admissions count as progress.
fn advance_with_submissions(
    platform: &mut MultiPlatform<'_>,
    subs: &mut Vec<(f64, StudySpec)>,
    chunk: f64,
) -> u64 {
    let target = platform.now() + chunk;
    let mut n = 0;
    while subs.first().map(|&(at, _)| at <= target).unwrap_or(false) {
        let (at, spec) = subs.remove(0);
        n += platform.run_until(at);
        n += admit_scenario_study(platform, spec, at);
    }
    n += platform.advance((target - platform.now()).max(0.0));
    if n == 0 && !subs.is_empty() {
        // Idle before the next scheduled submission: jump to it.
        let (at, spec) = subs.remove(0);
        n += platform.run_until(at);
        n += admit_scenario_study(platform, spec, at);
    }
    n
}

fn admit_scenario_study(platform: &mut MultiPlatform<'_>, spec: StudySpec, at: f64) -> u64 {
    let name = spec.name.clone();
    match platform.submit_study(spec, at) {
        Some(t) => {
            println!("scenario submission '{name}' admitted at t={t:.0}s");
            1
        }
        None => {
            eprintln!(
                "scenario submission '{name}' rejected (duplicate name, bad quota/priority, \
                 or quota does not fit)"
            );
            0
        }
    }
}

/// `chopt multi`: drive N studies from a manifest on one shared cluster
/// through the live [`MultiPlatform`] — per-study JSONL streams, the
/// merged fair-share document, periodic snapshots, and `--restore`.
/// With `--shards N` (N > 1) the run is partitioned across engine-worker
/// shards behind a [`FanoutSource`] instead.
fn cmd_multi(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let out_dir = m.get_or("out", "reports/multi").to_string();
    let chunk = m.get_f64("chunk").unwrap_or(3600.0).max(1.0);
    let snap_every = m.get_f64("snapshot-every").unwrap_or(14400.0);
    let snap_path = format!("{out_dir}/snapshot.json");
    std::fs::create_dir_all(&out_dir)?;

    // Sharded dispatch: an explicit --shards N, or a --restore file
    // whose snapshot is composite (written by a sharded run).
    let shards = m.get_usize("shards").unwrap_or(1);
    let restore_doc = match m.get("restore") {
        Some(path) => Some(chopt::util::json::parse(&std::fs::read_to_string(path)?)?),
        None => None,
    };
    let restored_sharded = restore_doc
        .as_ref()
        .map(|d| d.get("kind").and_then(|v| v.as_str()) == Some("sharded_multi_study"))
        .unwrap_or(false);
    if shards > 1 || restored_sharded {
        anyhow::ensure!(
            restore_doc.is_none() || restored_sharded,
            "--shards cannot resume a single-scheduler snapshot; restore it without --shards"
        );
        return cmd_multi_sharded(m, shards, restore_doc.filter(|_| restored_sharded));
    }

    let mut subs: Vec<(f64, StudySpec)> = Vec::new();
    let mut platform = if let Some(restore) = m.get("restore") {
        let platform = MultiPlatform::restore(restore, multi_trainer)?;
        println!(
            "restored from {restore}: t={:.0}s, {} events replayed, {} studies",
            platform.now(),
            platform.scheduler().events_processed(),
            platform.scheduler().studies().len()
        );
        // The previous process logged past the snapshot point before it
        // died; the continued run re-emits that window, so trim it from
        // every per-study stream (the logs open in append mode).
        for st in platform.scheduler().studies() {
            trim_event_log(
                &format!("{out_dir}/events-{}.jsonl", st.name()),
                platform.now(),
            )?;
        }
        platform
    } else {
        let Some(manifest_path) = m.get("manifest") else {
            anyhow::bail!("multi needs --manifest (or --restore)");
        };
        let mut manifest = StudyManifest::load(manifest_path)?;
        if let Some(path) = m.get("scenario") {
            manifest.scenario = Some(chopt::cluster::Scenario::load(path)?);
        }
        subs = take_scenario_submissions(&mut manifest)?;
        println!(
            "multi-study CHOPT: {} studies on {} GPUs (borrow={}, scenario={}, submissions={})",
            manifest.studies.len(),
            manifest.cluster_gpus,
            manifest.borrow,
            manifest
                .scenario
                .as_ref()
                .map(|s| s.sources.len())
                .map(|n| format!("{n} sources"))
                .unwrap_or_else(|| "none".into()),
            subs.len(),
        );
        for s in &manifest.studies {
            println!(
                "  study {:<16} quota={} tune={} submit_at={:.0}s",
                s.name,
                s.quota,
                s.config.tune.name(),
                s.submit_at
            );
        }
        // Start clean: leftover logs from a previous run would be
        // appended to (append mode is what --restore wants).  Scan the
        // directory instead of the manifest so per-study files from an
        // earlier run with *different* study names go too.
        if let Ok(entries) = std::fs::read_dir(&out_dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                let stale = (name.starts_with("events-") && name.ends_with(".jsonl"))
                    || (name.starts_with("sessions-") && name.ends_with(".json"))
                    || name.as_ref() == "fair_share.json";
                if stale {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        let _ = std::fs::remove_file(&snap_path);
        MultiPlatform::new(manifest, multi_trainer)
    };
    platform = platform
        .with_event_logs(&out_dir)?
        .with_snapshots(&snap_path, snap_every);
    platform.set_step_threads(m.get_u64("step-threads").unwrap_or(1) as usize);

    loop {
        let n = advance_with_submissions(&mut platform, &mut subs, chunk);
        let fair = platform.fair_share_doc();
        let per_study: Vec<String> = fair
            .get("studies")
            .and_then(|v| v.as_arr())
            .map(|rows| {
                rows.iter()
                    .map(|r| {
                        format!(
                            "{}:{}/{}g{}",
                            r.get("study").and_then(|v| v.as_str()).unwrap_or("?"),
                            r.get("held").and_then(|v| v.as_i64()).unwrap_or(0),
                            r.get("quota").and_then(|v| v.as_i64()).unwrap_or(0),
                            if r.get("done").and_then(|v| v.as_bool()) == Some(true) {
                                " done"
                            } else {
                                ""
                            }
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        println!(
            "t={:>10.0}s events={:>7} util={:.2} [{}]",
            platform.now(),
            platform.scheduler().events_processed(),
            fair.get("utilization").and_then(|v| v.as_f64()).unwrap_or(0.0),
            per_study.join(" "),
        );
        if (platform.is_done() && subs.is_empty()) || n == 0 {
            break;
        }
    }
    platform.snapshot_now()?;
    std::fs::write(
        format!("{out_dir}/fair_share.json"),
        platform.fair_share_doc().to_string_pretty(),
    )?;

    let names: Vec<String> = platform
        .scheduler()
        .studies()
        .iter()
        .map(|s| s.name().to_string())
        .collect();
    for name in &names {
        std::fs::write(
            format!("{out_dir}/sessions-{name}.json"),
            platform.study_sessions_doc(name).to_string_pretty(),
        )?;
        if let Some(st) = platform.scheduler().study(name) {
            if let Some(agent) = st.agent() {
                println!("\nstudy {name} (quota {}):", st.quota());
                let sessions: Vec<_> = agent.sessions.values().cloned().collect();
                viz::report::leaderboard_table(&sessions, agent.cfg.order, 5).print();
            }
        }
    }
    println!(
        "\ndone: {} events, {:.1} virtual hours, {} progress events\nwrote {out_dir}/{{events-<study>.jsonl,snapshot.json,fair_share.json,sessions-<study>.json}}\nresume anytime: chopt multi --restore {snap_path}",
        platform.scheduler().events_processed(),
        platform.now() / 3600.0,
        platform.progress_events,
    );
    Ok(())
}

/// `chopt multi --shards N`: the sharded control plane.  Studies are
/// partitioned across N engine-worker threads (each owning its own
/// scheduler over a full-size cluster), global capacity is arbitrated by
/// the quota-ledger broker, new studies are admitted through the bounded
/// submission queue, and every document is re-merged by the
/// [`FanoutSource`] — bit-identical per study to the single-scheduler
/// run for borrow-free manifests.
fn cmd_multi_sharded(
    m: &chopt::util::cli::Matches,
    shards: usize,
    restore_doc: Option<chopt::util::json::Value>,
) -> anyhow::Result<()> {
    let out_dir = m.get_or("out", "reports/multi").to_string();
    let chunk = m.get_f64("chunk").unwrap_or(3600.0).max(1.0);
    let snap_every = m.get_f64("snapshot-every").unwrap_or(14400.0);
    let snap_path = format!("{out_dir}/snapshot.json");
    std::fs::create_dir_all(&out_dir)?;

    let cfg = || FanoutConfig {
        shards,
        queue_capacity: m.get_usize("queue-capacity").unwrap_or(64),
        step_threads: m.get_u64("step-threads").unwrap_or(1) as usize,
        log_dir: Some(out_dir.clone().into()),
        feed: None,
        snapshot: Some((snap_path.clone().into(), snap_every)),
    };
    let mut fan = if let Some(doc) = restore_doc {
        let fan = FanoutSource::restore_doc(&doc, Arc::new(multi_trainer), cfg())?;
        println!(
            "restored sharded run: t={:.0}s, {} shards, {} studies",
            fan.now(),
            fan.shards(),
            fan.study_names().len()
        );
        for name in fan.study_names() {
            trim_event_log(&format!("{out_dir}/events-{name}.jsonl"), fan.now())?;
        }
        fan
    } else {
        let Some(manifest_path) = m.get("manifest") else {
            anyhow::bail!("multi needs --manifest (or --restore)");
        };
        let mut manifest = StudyManifest::load(manifest_path)?;
        if let Some(path) = m.get("scenario") {
            manifest.scenario = Some(chopt::cluster::Scenario::load(path)?);
        }
        println!(
            "sharded multi-study CHOPT: {} studies on {} GPUs across {shards} shards",
            manifest.studies.len(),
            manifest.cluster_gpus,
        );
        // Start clean, same as the single-scheduler path: leftover logs
        // from a previous run would be appended to.
        if let Ok(entries) = std::fs::read_dir(&out_dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                let stale = (name.starts_with("events-") && name.ends_with(".jsonl"))
                    || (name.starts_with("sessions-") && name.ends_with(".json"))
                    || name.as_ref() == "fair_share.json";
                if stale {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        let _ = std::fs::remove_file(&snap_path);
        FanoutSource::new(manifest, Arc::new(multi_trainer), cfg())?
    };

    loop {
        let n = fan.advance(chunk);
        let fair = fan
            .query(&ApiQuery::FairShare)
            .map_err(|e| anyhow::anyhow!("fair_share query failed: {}", e.message()))?;
        let (queued, spilled, admitted, _, rejected) = fan.queue_stats();
        println!(
            "t={:>10.0}s events={:>7} util={:.2} queue={queued}+{spilled} admitted={admitted} rejected={rejected}",
            fan.now(),
            fan.generation(),
            fair.get("utilization").and_then(|v| v.as_f64()).unwrap_or(0.0),
        );
        if fan.is_done() || n == 0 {
            break;
        }
    }
    fan.snapshot_now()?;
    std::fs::write(
        format!("{out_dir}/fair_share.json"),
        fan.query(&ApiQuery::FairShare)
            .map_err(|e| anyhow::anyhow!("fair_share query failed: {}", e.message()))?
            .to_string_pretty(),
    )?;
    println!(
        "\ndone: {} events across {} shards, {:.1} virtual hours, {} studies\nwrote {out_dir}/{{events-<study>.jsonl,snapshot.json,fair_share.json}}\nresume anytime: chopt multi --restore {snap_path}",
        fan.generation(),
        fan.shards(),
        fan.now() / 3600.0,
        fan.study_names().len(),
    );
    Ok(())
}

/// `chopt sweep`: expand a (scenario × tuner × policy) grid from a
/// sweep spec, run every cell as an independent deterministic
/// multi-study run on a bounded worker pool, and fold the per-cell
/// metrics into `sweep.json`.  Cells are content-addressed, so
/// `--resume` recomputes only missing or stale ones and a re-run of the
/// same spec is byte-identical.
fn cmd_sweep(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let spec_path = m.get("spec").unwrap();
    // Fail fast with the same file:line:col diagnostics `chopt validate
    // --sweep` prints, before any cell starts burning virtual hours.
    let report = chopt::sweep::validate_sweep_file(spec_path);
    let rendered = report.render();
    if !rendered.is_empty() {
        eprintln!("{rendered}");
    }
    anyhow::ensure!(!report.has_errors(), "sweep spec {spec_path} failed validation");

    let spec = chopt::sweep::SweepSpec::load(spec_path)?;
    let out = m.get_or("out", "reports/sweep").to_string();
    let opts = chopt::sweep::SweepOptions {
        workers: m.get_usize("cell-workers").unwrap_or(2).max(1),
        resume: m.flag("resume"),
        quiet: m.flag("quiet"),
    };
    println!(
        "sweep: {} scenarios × {} tuners × {} policies = {} cells on {} workers{}",
        spec.scenarios.len(),
        spec.tuners.len(),
        spec.policies.len(),
        spec.scenarios.len() * spec.tuners.len() * spec.policies.len(),
        opts.workers,
        if opts.resume { " (resume)" } else { "" },
    );
    let outcome = chopt::sweep::run_sweep(&spec, &out, &opts)?;
    if !outcome.cells_skipped.is_empty() {
        println!(
            "reused {} completed cells: {}",
            outcome.cells_skipped.len(),
            outcome.cells_skipped.join(" ")
        );
    }
    let top: Vec<&str> = outcome
        .artifact
        .path("rankings.by_score")
        .and_then(|v| v.as_arr())
        .map(|ids| ids.iter().filter_map(|v| v.as_str()).take(3).collect())
        .unwrap_or_default();
    println!(
        "done: {} cells ({} computed), best by score: {}\nwrote {out}/{{sweep.json,cells/<id>/...}}\nserve it: chopt serve --sweep {out}",
        outcome.cells_total,
        outcome.cells_run.len(),
        if top.is_empty() {
            "-".to_string()
        } else {
            top.join(" > ")
        },
    );
    Ok(())
}

/// `chopt validate`: parse + semantic checks for a manifest, scenario,
/// or sweep spec without running anything.  Diagnostics render as
/// `path:line:col: severity: message`; exits non-zero on errors so CI
/// and the sweep harness can gate on it.
fn cmd_validate(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let mut reports = Vec::new();
    if let Some(path) = m.get("manifest") {
        reports.push(chopt::sweep::validate_manifest_file(path));
    }
    if let Some(path) = m.get("scenario") {
        reports.push(chopt::sweep::validate_scenario_file(path));
    }
    if let Some(path) = m.get("sweep") {
        reports.push(chopt::sweep::validate_sweep_file(path));
    }
    anyhow::ensure!(
        !reports.is_empty(),
        "validate needs --manifest, --scenario, or --sweep"
    );
    let mut errors = false;
    for report in &reports {
        let rendered = report.render();
        if !rendered.is_empty() {
            println!("{rendered}");
        }
        if report.has_errors() {
            errors = true;
        } else {
            println!("{}: ok", report.path);
        }
    }
    anyhow::ensure!(!errors, "validation failed");
    Ok(())
}

/// Drop event-log records stamped after `cut` (the restored snapshot's
/// virtual time): the continued run re-emits that window, and the log is
/// opened in append mode, so keeping them would duplicate every pool
/// transition between the last snapshot and the interruption.
fn trim_event_log(path: &str, cut: f64) -> anyhow::Result<()> {
    if !std::path::Path::new(path).exists() {
        return Ok(());
    }
    let events = chopt::storage::EventLog::read_all(path)?;
    let kept: Vec<String> = events
        .iter()
        .filter(|e| {
            e.get("t")
                .and_then(|v| v.as_f64())
                .map(|t| t <= cut)
                .unwrap_or(true)
        })
        .map(|e| e.to_string_compact())
        .collect();
    let dropped = events.len() - kept.len();
    if dropped > 0 {
        let mut body = kept.join("\n");
        if !body.is_empty() {
            body.push('\n');
        }
        std::fs::write(path, body)?;
        println!("trimmed {dropped} post-snapshot records from {path}");
    }
    Ok(())
}

fn cmd_artifacts(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let dir = m.get_or("dir", "artifacts");
    let manifest = chopt::runtime::Manifest::load(dir)?;
    println!("artifacts dir: {dir}");
    println!(
        "data: input_dim={} classes={} batch={} | qa vocab={} ctx={} qry={} batch={}",
        manifest.data.input_dim,
        manifest.data.classes,
        manifest.data.batch,
        manifest.data.qa_vocab,
        manifest.data.qa_ctx_len,
        manifest.data.qa_qry_len,
        manifest.data.qa_batch
    );
    let mut names: Vec<_> = manifest.variants.keys().collect();
    names.sort();
    for name in names {
        let v = &manifest.variants[name];
        println!(
            "variant {name}: task={} blocks={} widen={} params={} measure={}",
            v.task, v.blocks, v.widen, v.param_count, v.measure
        );
    }
    Ok(())
}

/// SSE idle-heartbeat cadence for the CLI servers.
const SSE_HEARTBEAT: Duration = Duration::from_secs(15);

/// Resolve the command-surface bearer token: `--api-token` wins, then
/// the `CHOPT_API_TOKEN` environment variable.
fn api_token(m: &chopt::util::cli::Matches) -> Option<String> {
    m.get("api-token")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("CHOPT_API_TOKEN").ok())
        .filter(|s| !s.is_empty())
}

/// Worker-pool and response-cache sizing from the serve flags.
fn server_config(m: &chopt::util::cli::Matches) -> viz::server::ServerConfig {
    let defaults = viz::server::ServerConfig::default();
    viz::server::ServerConfig {
        workers: m.get_usize("http-workers").unwrap_or(defaults.workers).max(1),
        queue: m.get_usize("http-queue").unwrap_or(defaults.queue).max(1),
        cache_bytes: m
            .get_usize("cache-mb")
            .map(|mb| mb.saturating_mul(1 << 20))
            .unwrap_or(defaults.cache_bytes),
    }
}

/// The progress feed for a live serve: plain ring buffer, or — when
/// `--out` names a directory — a ring mirrored to `<out>/events.jsonl`
/// so `?since=<seq>` can replay records the ring already evicted.
fn live_feed(m: &chopt::util::cli::Matches) -> anyhow::Result<Arc<EventFeed>> {
    match m.get("out") {
        Some(dir) => {
            let path = format!("{dir}/events.jsonl");
            let feed = EventFeed::with_history(chopt::viz::sse::DEFAULT_FEED_CAPACITY, &path)?;
            println!("SSE history log: {path}");
            Ok(feed)
        }
        None => Ok(EventFeed::new(chopt::viz::sse::DEFAULT_FEED_CAPACITY)),
    }
}

fn cmd_serve(m: &chopt::util::cli::Matches) -> anyhow::Result<()> {
    let port: u16 = m.get_usize("port").unwrap_or(8787) as u16;
    if m.flag("live") {
        return cmd_serve_live(m, port);
    }
    if let Some(sweep_path) = m.get("sweep") {
        return cmd_serve_sweep(m, port, sweep_path);
    }
    let Some(store_path) = m.get("store") else {
        anyhow::bail!("serve needs --store, --sweep, or --live with --config");
    };
    // The stored run is rebuilt into the same incremental documents the
    // live path serves (full-fidelity replay), so every /api/v1 query
    // answers with bodies byte-identical to the run served live.  The
    // legacy /api/*.json aliases are retired: they answer 410 Gone with
    // a Link header pointing at the /api/v1 replacement.
    let stored = StoredRun::open(store_path)?;
    // SSE replays the recorded progress stream, then heartbeats.
    let feed = EventFeed::new(usize::MAX);
    for line in stored.event_lines() {
        feed.publish(line);
    }
    let server =
        viz::server::VizServer::start_with(port, viz::server::Routes::new(), server_config(m))?;
    server.serve_events(feed.clone(), SSE_HEARTBEAT);
    let inbox = server.enable_api();
    println!(
        "serving stored run {store_path} on http://{}/ — GET /api/v1/{{status,cluster,sessions,leaderboard,parallel,curves{}}}, /api/v1/events (SSE, {} recorded events){} (read-only; ctrl-c to stop)",
        server.addr(),
        if stored.is_multi() {
            ",fair_share,studies"
        } else {
            ""
        },
        feed.last_seq(),
        if stored.is_multi() {
            ""
        } else {
            "; scrub any query with ?at_event=N"
        },
    );
    let mut source = stored;
    loop {
        inbox.serve_one(&mut source, Duration::from_millis(500));
    }
}

/// `chopt serve --sweep`: serve a sweep artifact read-only through the
/// same worker-pool HTTP server.  The artifact has a fixed generation,
/// so every response body is rendered once and stays cache-resident;
/// an individual cell's run directory is still servable in full with
/// `--store <out>/cells/<id>` (cells are valid stored runs).
fn cmd_serve_sweep(m: &chopt::util::cli::Matches, port: u16, path: &str) -> anyhow::Result<()> {
    let mut source = chopt::sweep::SweepSource::open(path)?;
    // No recorded progress stream for an artifact: SSE stays connected
    // on heartbeats alone so dashboards keep one code path.
    let feed = EventFeed::new(usize::MAX);
    let server =
        viz::server::VizServer::start_with(port, viz::server::Routes::new(), server_config(m))?;
    server.serve_events(feed, SSE_HEARTBEAT);
    let inbox = server.enable_api();
    println!(
        "serving sweep {path} on http://{}/ — GET /api/v1/sweep, /api/v1/sweep/cells/<id> ({} cells) (read-only; ctrl-c to stop)",
        server.addr(),
        source.cell_ids().len(),
    );
    loop {
        inbox.serve_one(&mut source, Duration::from_millis(500));
    }
}

/// `chopt serve --live`: run the engine in-process behind the versioned
/// control plane.  Queries (`GET /api/v1/...`) are answered on demand
/// from the platform's incremental documents — nothing is re-rendered
/// per tick for nobody — and commands (`POST /api/v1/commands`) are
/// applied at tick boundaries, so a browser can watch *and steer* the
/// optimization (paper §3.5's analytic tool made read-write).
fn cmd_serve_live(m: &chopt::util::cli::Matches, port: u16) -> anyhow::Result<()> {
    if m.get("manifest").is_some() {
        return cmd_serve_live_multi(m, port);
    }
    let Some(config_path) = m.get("config") else {
        anyhow::bail!("serve --live needs --config (or --manifest)");
    };
    let cfg = ChoptConfig::load(config_path)?;
    let gpus = m.get_usize("gpus").unwrap_or(8);
    let chunk = m.get_f64("chunk").unwrap_or(1800.0).max(1.0);
    let throttle = std::time::Duration::from_millis(m.get_u64("throttle-ms").unwrap_or(250));
    let token = api_token(m);

    let feed = live_feed(m)?;
    let mut platform = Platform::new(SimSetup::single(cfg, gpus), surrogate::default_factory)
        .with_progress_feed(feed.clone());
    let server =
        viz::server::VizServer::start_with(port, viz::server::Routes::new(), server_config(m))?;
    server.serve_events(feed, SSE_HEARTBEAT);
    let authed = token.is_some();
    server.set_api_token(token);
    let inbox = server.enable_api();
    // The platform publishes its generation into the server's cache
    // gauge after every advance, so cached bodies from the previous
    // tick can never be served once the engine has moved on.
    platform.set_generation_gauge(inbox.generation_gauge());
    println!(
        "live run on http://{}/ — GET /api/v1/{{status,cluster,sessions,leaderboard,parallel,curves}}, /api/v1/events (SSE), POST /api/v1/commands{}",
        server.addr(),
        if authed { " (bearer token required)" } else { "" }
    );
    loop {
        let n = platform.advance(chunk);
        let done = platform.is_done() || n == 0;
        if done {
            println!(
                "run complete at t={:.0}s ({} events); still serving /api/v1 — a submit command revives it, ctrl-c to stop",
                platform.now(),
                platform.engine().events_processed()
            );
            // Idle: block on the inbox until a command revives the run.
            while platform.is_done() {
                inbox.serve_one(&mut platform, std::time::Duration::from_millis(500));
            }
        } else {
            // The between-advances breather doubles as the API window:
            // queries answered now, commands land on this tick boundary.
            inbox.serve_for(&mut platform, throttle);
        }
    }
}

/// `chopt serve --live --manifest`: the multi-tenant control plane —
/// fair-share and per-study queries under `/api/v1/studies/<name>/`,
/// plus study-level commands (submit/pause/resume/stop/set_quota).
fn cmd_serve_live_multi(m: &chopt::util::cli::Matches, port: u16) -> anyhow::Result<()> {
    let mut manifest = StudyManifest::load(m.get("manifest").unwrap())?;
    if let Some(path) = m.get("scenario") {
        manifest.scenario = Some(chopt::cluster::Scenario::load(path)?);
    }
    let shards = m.get_usize("shards").unwrap_or(1);
    if shards > 1 {
        return cmd_serve_live_sharded(m, port, manifest, shards);
    }
    let mut subs = take_scenario_submissions(&mut manifest)?;
    let chunk = m.get_f64("chunk").unwrap_or(1800.0).max(1.0);
    let throttle = std::time::Duration::from_millis(m.get_u64("throttle-ms").unwrap_or(250));
    let token = api_token(m);

    let feed = live_feed(m)?;
    let mut platform = MultiPlatform::new(manifest, multi_trainer).with_progress_feed(feed.clone());
    platform.set_step_threads(m.get_u64("step-threads").unwrap_or(1) as usize);
    let server =
        viz::server::VizServer::start_with(port, viz::server::Routes::new(), server_config(m))?;
    server.serve_events(feed, SSE_HEARTBEAT);
    let authed = token.is_some();
    server.set_api_token(token);
    let inbox = server.enable_api();
    // Same generation-gauge wiring as the single-study live serve.
    platform.set_generation_gauge(inbox.generation_gauge());
    println!(
        "live multi-study run on http://{}/ — GET /api/v1/{{status,cluster,fair_share,studies}}, /api/v1/studies/<name>/..., /api/v1/events (SSE), POST /api/v1/commands{}",
        server.addr(),
        if authed { " (bearer token required)" } else { "" }
    );
    loop {
        let n = advance_with_submissions(&mut platform, &mut subs, chunk);
        let done = (platform.is_done() && subs.is_empty()) || n == 0;
        if done {
            println!(
                "run complete at t={:.0}s ({} events); still serving /api/v1 — a submit_study command revives it, ctrl-c to stop",
                platform.now(),
                platform.scheduler().events_processed()
            );
            // Idle: block on the inbox until a command revives the run.
            while platform.is_done() {
                inbox.serve_one(&mut platform, std::time::Duration::from_millis(500));
            }
        } else {
            // The between-advances breather doubles as the API window:
            // queries answered now, commands land on this tick boundary.
            inbox.serve_for(&mut platform, throttle);
        }
    }
}

/// `chopt serve --live --manifest --shards N`: the sharded control plane
/// behind the unchanged `/api/v1` surface.  Queries are answered by the
/// aggregating [`FanoutSource`] (merged fair_share/status/leaderboard
/// documents, per-study routes to the owning shard), commands route
/// through it (submissions enter the bounded queue), and SSE interleaves
/// every shard's progress stream in virtual-time order.
fn cmd_serve_live_sharded(
    m: &chopt::util::cli::Matches,
    port: u16,
    manifest: StudyManifest,
    shards: usize,
) -> anyhow::Result<()> {
    let chunk = m.get_f64("chunk").unwrap_or(1800.0).max(1.0);
    let throttle = std::time::Duration::from_millis(m.get_u64("throttle-ms").unwrap_or(250));
    let token = api_token(m);

    let feed = live_feed(m)?;
    let mut fan = FanoutSource::new(
        manifest,
        Arc::new(multi_trainer),
        FanoutConfig {
            shards,
            queue_capacity: m.get_usize("queue-capacity").unwrap_or(64),
            step_threads: m.get_u64("step-threads").unwrap_or(1) as usize,
            log_dir: None,
            feed: Some(feed.clone()),
            snapshot: None,
        },
    )?;
    let server =
        viz::server::VizServer::start_with(port, viz::server::Routes::new(), server_config(m))?;
    server.serve_events(feed, SSE_HEARTBEAT);
    let authed = token.is_some();
    server.set_api_token(token);
    let inbox = server.enable_api();
    fan.set_generation_gauge(inbox.generation_gauge());
    println!(
        "live sharded multi-study run ({shards} shards) on http://{}/ — GET /api/v1/{{status,cluster,fair_share,studies}}, /api/v1/studies/<name>/..., /api/v1/events (SSE), POST /api/v1/commands{}",
        server.addr(),
        if authed { " (bearer token required)" } else { "" }
    );
    loop {
        let n = fan.advance(chunk);
        let done = fan.is_done() || n == 0;
        if done {
            println!(
                "run complete at t={:.0}s ({} events across {shards} shards); still serving /api/v1 — a submit_study command revives it, ctrl-c to stop",
                fan.now(),
                fan.generation()
            );
            // Idle: block on the inbox until a command revives the run.
            while fan.is_done() {
                inbox.serve_one(&mut fan, std::time::Duration::from_millis(500));
            }
        } else {
            // The between-advances breather doubles as the API window:
            // queries answered now, commands land on this tick boundary.
            inbox.serve_for(&mut fan, throttle);
        }
    }
}

