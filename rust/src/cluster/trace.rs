//! Non-CHOPT workload trace generator.
//!
//! Reproduces the load pattern of the paper's Fig. 8, which divides time
//! into zones:
//!
//!   A — no CHOPT sessions; moderate external load only.
//!   B — CHOPT sessions start; external load unchanged.
//!   C — external users go idle; the cluster is under-utilized, so the
//!       master agent hands idle GPUs to CHOPT.
//!   D — external users surge back; the master agent claws GPUs back from
//!       CHOPT sessions.
//!   E — CHOPT sessions drain and finish; external load tapers.
//!
//! The trace emits *demanded* external GPUs as a function of virtual time:
//! a piecewise base level plus seeded jitter, so runs are reproducible but
//! not perfectly flat.

use crate::events::SimTime;
use crate::util::rng::Rng;

/// Named zone of the Fig. 8 timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceZone {
    A,
    B,
    C,
    D,
    E,
}

/// Piecewise external-demand trace over `[0, horizon)`.
#[derive(Debug, Clone)]
pub struct ExternalLoadTrace {
    pub horizon: SimTime,
    /// Fraction of total GPUs demanded per zone (A..E base levels).
    pub base: [f64; 5],
    pub total_gpus: usize,
    pub jitter: f64,
    seed: u64,
}

impl ExternalLoadTrace {
    /// The canonical Fig. 8 shape over `horizon` seconds of virtual time.
    pub fn fig8(total_gpus: usize, horizon: SimTime, seed: u64) -> ExternalLoadTrace {
        ExternalLoadTrace {
            horizon,
            // A: moderate, B: moderate, C: idle, D: surge, E: taper.
            base: [0.55, 0.55, 0.15, 0.85, 0.35],
            total_gpus,
            jitter: 0.05,
            seed,
        }
    }

    /// Zone boundaries at 15% / 30% / 55% / 80% of the horizon.
    pub fn zone(&self, t: SimTime) -> TraceZone {
        let f = (t / self.horizon).clamp(0.0, 1.0);
        if f < 0.15 {
            TraceZone::A
        } else if f < 0.30 {
            TraceZone::B
        } else if f < 0.55 {
            TraceZone::C
        } else if f < 0.80 {
            TraceZone::D
        } else {
            TraceZone::E
        }
    }

    /// External GPU demand at time `t` (deterministic in (seed, t-bucket)).
    pub fn demand(&self, t: SimTime) -> usize {
        let zone = self.zone(t);
        let base = self.base[zone as usize];
        // Jitter varies per ~1%-of-horizon bucket so adjacent samples move.
        let bucket = ((t / self.horizon) * 100.0) as u64;
        let mut rng = Rng::new(self.seed ^ bucket.wrapping_mul(0xA24B_AED4_963E_E407));
        let jit = (rng.f64() * 2.0 - 1.0) * self.jitter;
        let frac = (base + jit).clamp(0.0, 1.0);
        (frac * self.total_gpus as f64).round() as usize
    }

    /// Does the CHOPT workload exist in this zone? (Zones B..E.)
    pub fn chopt_active(&self, t: SimTime) -> bool {
        !matches!(self.zone(t), TraceZone::A)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zones_partition_timeline() {
        let tr = ExternalLoadTrace::fig8(40, 1000.0, 1);
        assert_eq!(tr.zone(0.0), TraceZone::A);
        assert_eq!(tr.zone(200.0), TraceZone::B);
        assert_eq!(tr.zone(400.0), TraceZone::C);
        assert_eq!(tr.zone(700.0), TraceZone::D);
        assert_eq!(tr.zone(950.0), TraceZone::E);
    }

    #[test]
    fn demand_matches_zone_shape() {
        let tr = ExternalLoadTrace::fig8(100, 1000.0, 2);
        // C must be the trough, D the peak.
        let c: usize = tr.demand(400.0);
        let d: usize = tr.demand(700.0);
        let a: usize = tr.demand(50.0);
        assert!(c < a, "C ({c}) should be below A ({a})");
        assert!(d > a, "D ({d}) should be above A ({a})");
        assert!(d > c + 30);
    }

    #[test]
    fn demand_deterministic_and_bounded() {
        let tr = ExternalLoadTrace::fig8(64, 500.0, 3);
        for i in 0..100 {
            let t = i as f64 * 5.0;
            let d1 = tr.demand(t);
            let d2 = tr.demand(t);
            assert_eq!(d1, d2);
            assert!(d1 <= 64);
        }
    }

    #[test]
    fn chopt_activity_window() {
        let tr = ExternalLoadTrace::fig8(10, 1000.0, 4);
        assert!(!tr.chopt_active(10.0));
        assert!(tr.chopt_active(500.0));
    }
}
