//! # CHOPT — Cloud-based Hyperparameter OPTimization
//!
//! A from-scratch reproduction of *"CHOPT: Automated Hyperparameter
//! Optimization Framework for Cloud-Based Machine Learning Platforms"*
//! (Kim et al., 2018) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the CHOPT coordinator: session queue,
//!   agents, master agent with leader election, live/stop/dead session
//!   pools, and the *Stop-and-Go* shared-cluster resource controller.
//! * **Layer 2** — JAX models (residual-MLP image classifier, BiDAF-lite
//!   QA model) AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels (fused linear, SGD-momentum, random
//!   erasing, attention) called from the L2 graphs.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and executes them
//! from Rust worker threads.
//!
//! The paper's testbed (a multi-tenant NSML GPU cluster) is reproduced by
//! the [`cluster`] simulator + [`nsml`] platform substrate; training at
//! cluster scale (hundreds of models x 300 epochs) runs against the
//! [`trainer::surrogate`] learning-curve model in virtual time, while the
//! end-to-end examples drive *real* training through PJRT.

pub mod analysis;
pub mod cluster;
pub mod experiments;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod events;
pub mod hparam;
pub mod nsml;
pub mod runtime;
pub mod storage;
pub mod trainer;
pub mod tuner;
pub mod util;
pub mod viz;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
