//! # CHOPT — Cloud-based Hyperparameter OPTimization
//!
//! A from-scratch reproduction of *"CHOPT: Automated Hyperparameter
//! Optimization Framework for Cloud-Based Machine Learning Platforms"*
//! (Kim et al., 2018) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this workspace)** — the CHOPT coordinator: session
//!   queue, agents, master agent with leader election, live/stop/dead
//!   session pools, and the *Stop-and-Go* shared-cluster resource
//!   controller.
//! * **Layer 2** — JAX models (residual-MLP image classifier, BiDAF-lite
//!   QA model) AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels (fused linear, SGD-momentum, random
//!   erasing, attention) called from the L2 graphs.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and executes them
//! from Rust worker threads.
//!
//! The paper's testbed (a multi-tenant NSML GPU cluster) is reproduced by
//! the [`cluster`] simulator + [`nsml`] platform substrate; training at
//! cluster scale (hundreds of models x 300 epochs) runs against the
//! [`trainer::surrogate`] learning-curve model in virtual time, while the
//! end-to-end examples drive *real* training through PJRT.
//!
//! ## Workspace layout
//!
//! This crate is a thin **facade** over the workspace members, kept so
//! every published `chopt::...` path (tests, benches, examples, the CLI)
//! survives the crate split unchanged:
//!
//! * [`chopt_core`] — events, hparam, config, nsml, surrogate trainers,
//!   data, analysis/experiments, util (re-exported at the old paths).
//! * [`chopt_cluster`] — the GPU [`cluster`] allocator + load traces.
//! * [`chopt_tuners`] — the [`tuner`] zoo behind the `Tuner` trait.
//! * [`chopt_engine`] — the [`coordinator`] engine/agent/scheduler and
//!   [`storage`].
//! * [`chopt_control`] — the [`viz`] control plane (api/server/sse,
//!   `Platform`/`MultiPlatform`, stored runs, exports).
//!
//! Only the PJRT [`runtime`] and [`trainer::real`] live in this facade
//! crate directly: they are the one seam that needs the `xla` FFI, and
//! keeping them here keeps every workspace member FFI-free.

pub use chopt_core::{analysis, config, data, events, experiments, hparam, nsml, util};

// Re-export the core macros at their historical crate-root paths
// (`chopt::log_warn!` etc.); `#[macro_export]` already places them at
// the root of `chopt_core`, this carries them through the facade.
pub use chopt_core::{log_debug, log_error, log_info, log_warn, prop_assert};

/// The shared-cluster GPU allocator and external load traces
/// (re-export of [`chopt_cluster`]).
pub mod cluster {
    pub use chopt_cluster::*;
}

/// The tuner zoo: `Tuner` trait + random/median-stop/Hyperband/ASHA/PBT
/// (re-export of [`chopt_tuners`]).
pub mod tuner {
    pub use chopt_tuners::*;
}

/// Trainers behind one trait: the surrogate family from
/// [`chopt_core::trainer`] plus the PJRT-backed [`real::RealTrainer`],
/// which lives in this facade crate so the workspace members stay
/// FFI-free.
pub mod trainer {
    pub use chopt_core::trainer::{surrogate, EpochResult, Trainer};

    // Inside an inline module the declaration's components are appended
    // to this file's directory, so "real.rs" resolves to
    // rust/src/trainer/real.rs.
    #[path = "real.rs"]
    pub mod real;
}

pub mod runtime;

/// The simulation coordinator (re-export of
/// [`chopt_engine::coordinator`]) plus the live `Platform` /
/// `MultiPlatform` layer from [`chopt_control`], which historically
/// lived under this module.
pub mod coordinator {
    pub use chopt_control::platform::{MultiPlatform, Platform};
    pub use chopt_engine::coordinator::*;
}

/// The sharded control plane's engine side (re-export of
/// [`chopt_engine::shard`]): shard supervisor, placement plan, and the
/// bounded submission queue.  The aggregating `FanoutSource` lives in
/// [`viz`] (`chopt::viz::fanout`).
pub mod shard {
    pub use chopt_engine::shard::*;
}

/// Persistence (re-export of [`chopt_engine::storage`]) plus the
/// stored-run read models from [`chopt_control`], which historically
/// lived under this module.
pub mod storage {
    pub use chopt_control::stored::{ReplaySource, StoredRun};
    pub use chopt_engine::storage::*;
}

/// The control plane and analytic visual tool (re-export of
/// [`chopt_control`]).
pub mod viz {
    pub use chopt_control::*;
}

/// The sweep harness (re-export of [`chopt_sweep`]): declarative
/// (scenario × tuner × policy) grids over one base manifest, the
/// content-addressed cell runner, the `sweep.json` comparison
/// artifact, the read-only sweep `RunSource`, and `chopt validate`.
pub mod sweep {
    pub use chopt_sweep::*;
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
