//! Session result store, model-snapshot accounting, and the stored-run
//! read models behind `chopt serve --store`.
//!
//! [`StoredRun`] rebuilds a finished (or interrupted) run directory into
//! the *same* incremental documents the live platform serves — the
//! snapshot is replayed in full fidelity, so every `/api/v1` body is
//! byte-identical to the run served live at the same event count.
//! [`ReplaySource`] is its scrub sibling: `?at_event=N` replays a
//! single-study snapshot to any recorded event count.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::{MultiPlatform, Platform};
use crate::nsml::{NsmlSession, SessionId};
use crate::trainer::{surrogate, Trainer};
use crate::util::json::{self, Value as Json};
use crate::viz::api::{ApiCommand, ApiError, ApiQuery, CommandSink, RunSource};

/// Persists finished CHOPT runs (sessions + metadata) as a JSON document
/// the viz tool serves.
#[derive(Debug, Default)]
pub struct SessionStore {
    runs: Vec<(String, Vec<NsmlSession>)>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore::default()
    }

    /// Record one CHOPT run under a label (e.g. "session-1: lr only").
    pub fn put_run(&mut self, label: &str, sessions: Vec<NsmlSession>) {
        self.runs.push((label.to_string(), sessions));
    }

    pub fn runs(&self) -> &[(String, Vec<NsmlSession>)] {
        &self.runs
    }

    pub fn to_json(&self) -> Json {
        let runs = self
            .runs
            .iter()
            .map(|(label, sessions)| {
                let refs: Vec<&NsmlSession> = sessions.iter().collect();
                SessionStore::run_json(label, &refs)
            })
            .collect();
        Json::obj().with("runs", Json::Arr(runs))
    }

    /// One run as the `{"label", "sessions"}` object [`Self::to_json`]
    /// emits — shared with live views that render straight from borrowed
    /// sessions, so the owned and borrowed encodings cannot drift.
    pub fn run_json(label: &str, sessions: &[&NsmlSession]) -> Json {
        Json::obj()
            .with("label", Json::Str(label.to_string()))
            .with(
                "sessions",
                Json::Arr(sessions.iter().map(|s| s.to_json()).collect()),
            )
    }

    /// Full store-shaped document from borrowed runs — the live platform
    /// documents render through this instead of cloning every session
    /// into a temporary store per refresh.
    pub fn doc_from_refs(runs: &[(String, Vec<&NsmlSession>)]) -> Json {
        Json::obj().with(
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|(label, ss)| SessionStore::run_json(label, ss))
                    .collect(),
            ),
        )
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Count of sessions across all runs.
    pub fn session_count(&self) -> usize {
        self.runs.iter().map(|(_, s)| s.len()).sum()
    }

    pub fn load_json(path: impl AsRef<Path>) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Ok(json::parse(&text)?)
    }
}

/// Model snapshot store with dead-pool GC accounting.
///
/// Snapshots are byte blobs keyed by session; `gc` frees dead sessions'
/// snapshots and reports reclaimed bytes (the paper's storage-pressure
/// rationale for the dead pool, §3.2.1).
#[derive(Debug, Default)]
pub struct SnapshotStore {
    blobs: HashMap<SessionId, Vec<u8>>,
    reclaimed: u64,
    dir: Option<PathBuf>,
}

impl SnapshotStore {
    pub fn in_memory() -> SnapshotStore {
        SnapshotStore::default()
    }

    /// Spill snapshots to disk under `dir` as well (optional).
    pub fn on_disk(dir: impl AsRef<Path>) -> std::io::Result<SnapshotStore> {
        std::fs::create_dir_all(&dir)?;
        Ok(SnapshotStore {
            dir: Some(dir.as_ref().to_path_buf()),
            ..Default::default()
        })
    }

    pub fn put(&mut self, id: SessionId, blob: Vec<u8>) -> std::io::Result<()> {
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join(format!("{id}.ckpt")), &blob)?;
        }
        self.blobs.insert(id, blob);
        Ok(())
    }

    pub fn get(&self, id: SessionId) -> Option<&[u8]> {
        self.blobs.get(&id).map(|b| b.as_slice())
    }

    pub fn bytes_held(&self) -> u64 {
        self.blobs.values().map(|b| b.len() as u64).sum()
    }

    /// Drop snapshots of `dead` sessions; returns bytes reclaimed.
    pub fn gc(&mut self, dead: &[SessionId]) -> u64 {
        let mut freed = 0u64;
        for id in dead {
            if let Some(blob) = self.blobs.remove(id) {
                freed += blob.len() as u64;
                if let Some(dir) = &self.dir {
                    let _ = std::fs::remove_file(dir.join(format!("{id}.ckpt")));
                }
            }
        }
        self.reclaimed += freed;
        freed
    }

    pub fn total_reclaimed(&self) -> u64 {
        self.reclaimed
    }
}

/// Scrub-to-event replay over a single-study snapshot: the
/// [`RunSource`] behind `?at_event=N`.
///
/// Wraps `SimEngine::restore` (via [`Platform::restore_doc_at`]): a
/// query at event count `N` rebuilds the engine by replaying the first
/// `N` recorded events (re-issuing exactly the external inputs that had
/// been enqueued by then) and renders the document from that state.
/// The last scrub position is cached, so repeated queries at the same
/// `N` — the common dashboard case, several views of one moment — replay
/// once.  Determinism of the engine replay makes scrubbing stable:
/// the same `N` always yields the same bytes regardless of scrub order.
pub struct ReplaySource {
    snapshot: Json,
    /// The snapshot's recorded event count — scrub positions cap here.
    target: u64,
    make: Arc<dyn Fn(u64) -> Box<dyn Trainer>>,
    /// (position, replayed platform) of the last scrub.
    cache: RefCell<Option<(u64, Platform<'static>)>>,
}

impl ReplaySource {
    /// Build a scrubber over a parsed single-study snapshot document.
    /// `make` must be the trainer factory the original run used.
    pub fn new(
        snapshot: Json,
        make: impl Fn(u64) -> Box<dyn Trainer> + 'static,
    ) -> anyhow::Result<ReplaySource> {
        ReplaySource::with_factory(snapshot, Arc::new(make))
    }

    fn with_factory(
        snapshot: Json,
        make: Arc<dyn Fn(u64) -> Box<dyn Trainer>>,
    ) -> anyhow::Result<ReplaySource> {
        if snapshot.get("kind").and_then(|v| v.as_str()) == Some("multi_study") {
            anyhow::bail!("?at_event scrubbing supports single-study snapshots only");
        }
        let target = snapshot
            .get("events_processed")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("snapshot missing 'events_processed'"))?
            as u64;
        Ok(ReplaySource {
            snapshot,
            target,
            make,
            cache: RefCell::new(None),
        })
    }

    /// The snapshot's recorded event count (the maximum scrub position).
    pub fn target(&self) -> u64 {
        self.target
    }

    /// Ensure the cached platform sits at event count `min(at, target)`;
    /// returns the effective position.
    fn scrub_to(&self, at: u64) -> Result<u64, ApiError> {
        let at = at.min(self.target);
        if let Some((pos, _)) = self.cache.borrow().as_ref() {
            if *pos == at {
                return Ok(at);
            }
        }
        let f = self.make.clone();
        let platform = Platform::restore_doc_at(&self.snapshot, move |id| (*f)(id), at)
            .map_err(|e| ApiError::BadRequest(format!("replay to event {at} failed: {e:#}")))?;
        *self.cache.borrow_mut() = Some((at, platform));
        Ok(at)
    }
}

impl RunSource for ReplaySource {
    /// The current scrub position (the snapshot end before any scrub).
    fn generation(&self) -> u64 {
        self.cache
            .borrow()
            .as_ref()
            .map(|&(pos, _)| pos)
            .unwrap_or(self.target)
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        let at = self.generation();
        self.query_at(q, at).map(|(_, doc)| doc)
    }

    fn query_at(&self, q: &ApiQuery, at: u64) -> Result<(u64, Json), ApiError> {
        let at = self.scrub_to(at)?;
        let cache = self.cache.borrow();
        let (_, platform) = cache.as_ref().expect("scrub_to populated the cache");
        platform.query(q).map(|doc| (at, doc))
    }
}

/// Which platform shape a run directory restored into.
enum StoredPlatform {
    Single(Platform<'static>),
    Multi(MultiPlatform<'static>),
}

/// A run directory rebuilt into the live read model: the [`RunSource`]
/// behind `chopt serve --store`.
///
/// `open` reads `snapshot.json` (written by `chopt watch` / `chopt
/// multi` / their `serve --live` twins) and replays it **in full
/// fidelity** (`restore_doc_full`) through the same `Platform` /
/// `MultiPlatform` document pipeline the live server uses — which is
/// what makes every `/api/v1` body byte-identical between `serve
/// --store` and `serve --live` at the same event count.  The recorded
/// JSONL progress streams are exposed via [`StoredRun::event_lines`] so
/// `GET /api/v1/events` replays them over SSE.  Single-study runs also
/// carry a [`ReplaySource`] for `?at_event=` scrubbing.
///
/// Stored runs are read-only: the [`CommandSink`] half rejects every
/// command with a 400 pointing at `serve --live`.
pub struct StoredRun {
    platform: StoredPlatform,
    replay: Option<ReplaySource>,
    /// Recorded JSONL streams (one for single-study, one per study for
    /// multi), in deterministic filename order.
    events_paths: Vec<PathBuf>,
}

impl StoredRun {
    /// Open a run directory (or a `snapshot.json` path directly) with
    /// the standard CLI trainer factories.  Runs produced with custom
    /// factories restore through [`StoredRun::open_with`].
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<StoredRun> {
        StoredRun::open_with(
            path,
            surrogate::default_factory,
            surrogate::default_multi_factory,
        )
    }

    /// [`StoredRun::open`] with explicit trainer factories (`make` for
    /// single-study snapshots, `make_multi` for multi-study ones —
    /// restore-by-replay requires the factories the original run used).
    pub fn open_with(
        path: impl AsRef<Path>,
        make: impl Fn(u64) -> Box<dyn Trainer> + 'static,
        make_multi: impl FnMut(usize, u64) -> Box<dyn Trainer> + 'static,
    ) -> anyhow::Result<StoredRun> {
        let path = path.as_ref();
        let (snap_path, dir) = if path.is_dir() {
            (path.join("snapshot.json"), path.to_path_buf())
        } else {
            (
                path.to_path_buf(),
                path.parent()
                    .filter(|p| !p.as_os_str().is_empty())
                    .unwrap_or(Path::new("."))
                    .to_path_buf(),
            )
        };
        if !snap_path.exists() {
            anyhow::bail!(
                "no snapshot.json under '{}' — `serve --store` reads a run directory written by \
                 `chopt watch` or `chopt multi` (the legacy static sessions.json store was \
                 retired; see README §Control-plane API)",
                path.display()
            );
        }
        let text = std::fs::read_to_string(&snap_path)?;
        let doc = json::parse(&text)?;
        if doc.get("runs").is_some() && doc.get("events_processed").is_none() {
            anyhow::bail!(
                "'{}' is a legacy sessions.json store, not a run snapshot — re-run through \
                 `chopt watch`/`chopt multi` to produce a servable run directory",
                snap_path.display()
            );
        }
        if doc.get("kind").and_then(|v| v.as_str()) == Some("multi_study") {
            let platform = MultiPlatform::restore_doc_full(&doc, make_multi)?;
            let mut events_paths: Vec<PathBuf> = std::fs::read_dir(&dir)
                .map(|entries| {
                    entries
                        .flatten()
                        .map(|e| e.path())
                        .filter(|p| {
                            p.file_name()
                                .and_then(|n| n.to_str())
                                .map(|n| n.starts_with("events-") && n.ends_with(".jsonl"))
                                .unwrap_or(false)
                        })
                        .collect()
                })
                .unwrap_or_default();
            events_paths.sort();
            Ok(StoredRun {
                platform: StoredPlatform::Multi(platform),
                replay: None,
                events_paths,
            })
        } else {
            let make: Arc<dyn Fn(u64) -> Box<dyn Trainer>> = Arc::new(make);
            let f = make.clone();
            let platform = Platform::restore_doc_full(&doc, move |id| (*f)(id))?;
            let replay = ReplaySource::with_factory(doc, make)?;
            let events = dir.join("events.jsonl");
            Ok(StoredRun {
                platform: StoredPlatform::Single(platform),
                replay: Some(replay),
                events_paths: if events.exists() { vec![events] } else { Vec::new() },
            })
        }
    }

    pub fn is_multi(&self) -> bool {
        matches!(self.platform, StoredPlatform::Multi(_))
    }

    /// The recorded progress stream, in emit order: single-study runs
    /// return `events.jsonl` verbatim; multi-study runs merge the
    /// per-study streams by virtual time (ties keep filename order, so
    /// the merge is deterministic).  Feed these into an SSE `EventFeed`
    /// to replay the run's progress over `GET /api/v1/events`.
    pub fn event_lines(&self) -> Vec<String> {
        let mut records: Vec<(f64, usize, String)> = Vec::new();
        for (file_idx, path) in self.events_paths.iter().enumerate() {
            let Ok(text) = std::fs::read_to_string(path) else {
                continue;
            };
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let t = json::parse(line)
                    .ok()
                    .and_then(|doc| doc.get("t").and_then(|v| v.as_f64()))
                    .unwrap_or(0.0);
                records.push((t, file_idx, line.to_string()));
            }
        }
        // Stable by (t, file): intra-file order is preserved, cross-file
        // ties resolve by filename order.
        records.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        records.into_iter().map(|(_, _, line)| line).collect()
    }
}

impl RunSource for StoredRun {
    fn generation(&self) -> u64 {
        match &self.platform {
            StoredPlatform::Single(p) => p.generation(),
            StoredPlatform::Multi(m) => m.generation(),
        }
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        match &self.platform {
            StoredPlatform::Single(p) => p.query(q),
            StoredPlatform::Multi(m) => m.query(q),
        }
    }

    fn query_at(&self, q: &ApiQuery, at: u64) -> Result<(u64, Json), ApiError> {
        match &self.replay {
            Some(replay) => replay.query_at(q, at),
            None => Err(ApiError::BadRequest(
                "?at_event scrubbing is supported for single-study stored runs only".into(),
            )),
        }
    }

    /// A stored run's documents can never change: the HTTP response
    /// cache pins its entries, making the whole read surface
    /// cache-resident after first touch.  (`ReplaySource` must *not*
    /// claim this — scrubbing moves its generation.)
    fn fixed_generation(&self) -> bool {
        true
    }
}

impl CommandSink for StoredRun {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        Err(ApiError::BadRequest(format!(
            "stored run is read-only — '{}' needs a live server (chopt serve --live)",
            c.name()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hparam::Assignment;

    #[test]
    fn stored_run_rejects_missing_and_legacy_stores() {
        let dir = std::env::temp_dir().join(format!("chopt-stored-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // No snapshot.json at all.
        let err = StoredRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("snapshot.json"), "{err}");
        // A legacy sessions.json store is named as such.
        let legacy = dir.join("legacy.json");
        std::fs::write(&legacy, r#"{"runs": []}"#).unwrap();
        let err = StoredRun::open(&legacy).unwrap_err().to_string();
        assert!(err.contains("legacy sessions.json"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_roundtrip() {
        let mut st = SessionStore::new();
        let mut s = NsmlSession::new(SessionId(1), Assignment::new(), "m", 0.0);
        s.report(1, 0.5, 2.0);
        st.put_run("run-a", vec![s]);
        assert_eq!(st.session_count(), 1);
        let j = st.to_json();
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), 1);
        let path = std::env::temp_dir().join(format!("chopt-store-{}.json", std::process::id()));
        st.save(&path).unwrap();
        let loaded = SessionStore::load_json(&path).unwrap();
        assert_eq!(
            loaded.path("runs").unwrap().idx(0).unwrap().get("label").unwrap().as_str(),
            Some("run-a")
        );
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn snapshot_gc_reclaims() {
        let mut ss = SnapshotStore::in_memory();
        ss.put(SessionId(1), vec![0u8; 1000]).unwrap();
        ss.put(SessionId(2), vec![0u8; 500]).unwrap();
        assert_eq!(ss.bytes_held(), 1500);
        let freed = ss.gc(&[SessionId(1), SessionId(99)]);
        assert_eq!(freed, 1000);
        assert_eq!(ss.bytes_held(), 500);
        assert_eq!(ss.total_reclaimed(), 1000);
        assert!(ss.get(SessionId(1)).is_none());
        assert!(ss.get(SessionId(2)).is_some());
    }
}
