//! Persistence: session store, JSONL event log, snapshot GC accounting,
//! and the stored-run read models (`StoredRun` / `ReplaySource`) that
//! serve `/api/v1` from a run directory with live-identical bodies.
//!
//! The paper's motivation for the dead pool is storage pressure ("automl
//! systems commonly create models a lot and it often takes up too much
//! system storage space"); this module makes that concrete: snapshots of
//! dead sessions are reclaimed, stopped sessions' snapshots are retained.

mod event_log;
mod store;

pub use event_log::EventLog;
pub use store::{ReplaySource, SessionStore, SnapshotStore, StoredRun};
