//! Dependency-free HTTP server for the analytic tool.
//!
//! Three serving surfaces compose:
//!
//! * a **static route table** (`Routes`) for the embedded viewer and SVG
//!   renders,
//! * the **versioned control-plane API** (`/api/v1`, see [`crate::viz::api`])
//!   when enabled via [`VizServer::enable_api`]: API paths are parsed
//!   into typed calls and forwarded over a channel to the serving loop,
//!   which answers them between advances from any `RunSource` — a live
//!   platform, a stored run, or a replay scrubber.  Legacy `/api/*.json`
//!   paths are deprecated aliases onto the same v1 handlers.  When a
//!   bearer token is configured ([`VizServer::set_api_token`]) the
//!   command surface (`POST /api/v1/commands`) answers 401/403 in the
//!   envelope error format before anything reaches the engine loop; the
//!   read side stays open.
//! * the **SSE push stream** (`GET /api/v1/events`, see
//!   [`crate::viz::sse`]) when enabled via [`VizServer::serve_events`]:
//!   each connection gets a tailing thread with heartbeats and
//!   `Last-Event-ID` resume, so dashboards stop polling.
//!
//! Each accepted connection is handled on its own thread, so one slow
//! client cannot stall the listener; methods are parsed and enforced
//! (405 on mismatch) rather than treating every request as a GET.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use super::api::{self, ApiInbox, ApiRequest, RouteError};
use super::sse::EventFeed;

/// A route table: path → (content type, body).
pub type Routes = HashMap<String, (String, Vec<u8>)>;

/// Largest accepted request body (command manifests are small).
const MAX_BODY: usize = 1 << 20;

/// How long a connection thread waits for the engine loop to answer an
/// API request before giving up with a 503.
const API_REPLY_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Connection threads' handle to the API bridge (None until
/// [`VizServer::enable_api`]).
type ApiSender = Arc<Mutex<Option<mpsc::Sender<ApiRequest>>>>;

/// The SSE surface: the feed plus the idle heartbeat cadence.
#[derive(Clone)]
struct SseHandle {
    feed: Arc<EventFeed>,
    heartbeat: Duration,
}

/// Everything a connection thread needs, cloned per accept.
#[derive(Clone)]
struct ConnShared {
    routes: Arc<Mutex<Routes>>,
    api_tx: ApiSender,
    token: Arc<Mutex<Option<String>>>,
    sse: Arc<Mutex<Option<SseHandle>>>,
    stop: Arc<AtomicBool>,
}

/// The viz HTTP server.
pub struct VizServer {
    shared: ConnShared,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub requests: Arc<AtomicU64>,
}

impl VizServer {
    /// Bind on 127.0.0.1:`port` (0 = ephemeral) and start serving.
    pub fn start(port: u16, mut routes: Routes) -> std::io::Result<VizServer> {
        routes
            .entry("/".to_string())
            .or_insert(("text/html".to_string(), VIEWER_HTML.as_bytes().to_vec()));
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = ConnShared {
            routes: Arc::new(Mutex::new(routes)),
            api_tx: Arc::new(Mutex::new(None)),
            token: Arc::new(Mutex::new(None)),
            sse: Arc::new(Mutex::new(None)),
            stop: stop.clone(),
        };
        let requests = Arc::new(AtomicU64::new(0));
        let (sh2, s2, q2) = (shared.clone(), stop.clone(), requests.clone());
        let handle = std::thread::spawn(move || {
            while !s2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        q2.fetch_add(1, Ordering::Relaxed);
                        // One thread per connection: a slow or stalled
                        // client must not block the accept loop.  Builder
                        // (not thread::spawn) so thread exhaustion drops
                        // this one connection instead of panicking the
                        // accept loop dead.
                        let shared = sh2.clone();
                        let _ = std::thread::Builder::new()
                            .name("viz-conn".into())
                            .spawn(move || {
                                let _ = handle_conn(stream, &shared);
                            });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(VizServer {
            shared,
            addr,
            stop,
            handle: Some(handle),
            requests,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Enable the `/api/v1` surface: API paths stop falling through to
    /// the static table and are forwarded to the returned [`ApiInbox`],
    /// which the engine loop drains between advances.
    pub fn enable_api(&self) -> ApiInbox {
        let (tx, rx) = mpsc::channel();
        *self.shared.api_tx.lock().unwrap() = Some(tx);
        ApiInbox::new(rx)
    }

    /// Require `Authorization: Bearer <token>` on the command surface
    /// (`POST /api/v1/commands`).  The read side stays open; a missing
    /// header answers 401 and a mismatched token 403, both in the
    /// envelope error format.  `None` re-opens the surface.
    pub fn set_api_token(&self, token: Option<String>) {
        *self.shared.token.lock().unwrap() = token;
    }

    /// Serve `GET /api/v1/events` as an SSE stream of `feed`: one
    /// tailing thread per connection, a comment heartbeat every
    /// `heartbeat` while idle, and `Last-Event-ID` resume.
    pub fn serve_events(&self, feed: Arc<EventFeed>, heartbeat: Duration) {
        *self.shared.sse.lock().unwrap() = Some(SseHandle {
            feed,
            heartbeat: heartbeat.max(Duration::from_millis(10)),
        });
    }

    /// Replace/add a route while running.
    pub fn put_route(&self, path: &str, content_type: &str, body: Vec<u8>) {
        self.shared
            .routes
            .lock()
            .unwrap()
            .insert(path.to_string(), (content_type.to_string(), body));
    }

    /// Replace/add a JSON route while running (static-document serving;
    /// live runs answer through the v1 API instead).
    pub fn put_json(&self, path: &str, doc: &crate::util::json::Value) {
        self.put_route(path, "application/json", doc.to_string_compact().into_bytes());
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for VizServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One parsed HTTP request.
struct Request {
    method: String,
    path: String,
    query: String,
    body: Vec<u8>,
    /// Raw `Authorization` header value, if sent.
    authorization: Option<String>,
    /// Parsed `Last-Event-ID` header (SSE resume), if sent.
    last_event_id: Option<u64>,
}

fn read_request(stream: &TcpStream) -> std::io::Result<Option<Request>> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("GET").to_uppercase();
    let target = parts.next().unwrap_or("/");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    // Drain headers, keeping the ones the API layer consumes.
    let mut content_length = 0usize;
    let mut authorization = None;
    let mut last_event_id = None;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            } else if name.eq_ignore_ascii_case("authorization") {
                authorization = Some(value.trim().to_string());
            } else if name.eq_ignore_ascii_case("last-event-id") {
                last_event_id = value.trim().parse().ok();
            }
        }
    }
    if content_length > MAX_BODY {
        return Ok(None); // caller answers 400
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Some(Request {
        method,
        path,
        query,
        body,
        authorization,
        last_event_id,
    }))
}

fn handle_conn(mut stream: TcpStream, shared: &ConnShared) -> std::io::Result<()> {
    let req = match read_request(&stream)? {
        Some(r) => r,
        None => {
            return respond_json(
                &mut stream,
                400,
                &api::error_envelope(None, "request body too large"),
            )
        }
    };

    // The SSE push stream, when enabled, owns /api/v1/events (it never
    // goes through the engine-loop bridge — a slow stream consumer must
    // not occupy the inbox).
    let sse = shared.sse.lock().unwrap().clone();
    if let Some(sse) = sse {
        if req.path == "/api/v1/events" {
            if req.method != "GET" {
                let doc = api::error_envelope(None, "method not allowed");
                let body = doc.to_string_compact().into_bytes();
                return respond(&mut stream, 405, "application/json", &body, "Allow: GET\r\n");
            }
            return stream_events(&mut stream, &req, &sse, &shared.stop);
        }
    }

    // The control-plane API, when enabled, owns every other /api path.
    let api_tx = shared.api_tx.lock().unwrap().clone();
    if let Some(tx) = api_tx {
        if req.path.starts_with("/api/") {
            // Command auth happens here, before anything reaches the
            // engine loop; the read side stays open.
            let token = shared.token.lock().unwrap().clone();
            if req.path == "/api/v1/commands" && req.method == "POST" {
                if let Err(e) = check_bearer(&req, &token) {
                    return respond_json(
                        &mut stream,
                        e.http_status(),
                        &api::error_envelope(None, e.message()),
                    );
                }
            }
            return handle_api(&mut stream, &req, &tx);
        }
    }

    // Static routes are GET-only.
    if req.method != "GET" {
        let body = b"405 method not allowed";
        return respond(&mut stream, 405, "text/plain", body, "Allow: GET\r\n");
    }
    let found = shared.routes.lock().unwrap().get(&req.path).cloned();
    match found {
        Some((ctype, body)) => respond(&mut stream, 200, &ctype, &body, ""),
        None => respond(&mut stream, 404, "text/plain", b"404 not found", ""),
    }
}

/// Enforce `Authorization: Bearer <token>` when a token is configured:
/// missing/malformed credentials → 401, a wrong token → 403.
fn check_bearer(req: &Request, required: &Option<String>) -> Result<(), api::ApiError> {
    let Some(required) = required else {
        return Ok(());
    };
    match req
        .authorization
        .as_deref()
        .and_then(|h| h.strip_prefix("Bearer "))
    {
        None => Err(api::ApiError::Unauthorized(
            "commands require 'Authorization: Bearer <token>' on this server".into(),
        )),
        Some(sent) if sent.trim() == required => Ok(()),
        Some(_) => Err(api::ApiError::Forbidden("bearer token does not match".into())),
    }
}

/// Tail the event feed into one SSE connection: `id:`-framed progress
/// records, comment heartbeats while idle, resume from `Last-Event-ID`.
/// Ends when the client disconnects (write error) or the server stops.
fn stream_events(
    stream: &mut TcpStream,
    req: &Request,
    sse: &SseHandle,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    // A Last-Event-ID past anything published cannot be honored (the
    // header is client-controlled); treat it as "caught up to now" so
    // later events still flow.
    let mut cursor = req.last_event_id.unwrap_or(0).min(sse.feed.last_seq());
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let (missed, batch) = sse.feed.wait_after(cursor, sse.heartbeat);
        // A cursor that fell behind the retention window — at connect
        // time or mid-stream under publish pressure — is told how many
        // records it lost instead of silently skipping them.
        if missed > 0 {
            stream.write_all(format!(": resumed past {missed} dropped events\n\n").as_bytes())?;
        }
        if batch.is_empty() {
            stream.write_all(b": heartbeat\n\n")?;
        } else {
            let mut out = String::new();
            for (seq, line) in &batch {
                out.push_str(&format!("id: {seq}\ndata: {line}\n\n"));
                cursor = *seq;
            }
            stream.write_all(out.as_bytes())?;
        }
        stream.flush()?;
    }
}

fn handle_api(
    stream: &mut TcpStream,
    req: &Request,
    tx: &mpsc::Sender<ApiRequest>,
) -> std::io::Result<()> {
    let call = match api::parse_route(&req.method, &req.path, &req.query, &req.body) {
        Ok(call) => call,
        Err(RouteError::NotFound) => {
            return respond_json(stream, 404, &api::error_envelope(None, "unknown API path"));
        }
        Err(RouteError::MethodNotAllowed) => {
            let doc = api::error_envelope(None, "method not allowed");
            let body = doc.to_string_compact().into_bytes();
            return respond(stream, 405, "application/json", &body, "Allow: GET, POST\r\n");
        }
        Err(RouteError::BadRequest(msg)) => {
            return respond_json(stream, 400, &api::error_envelope(None, &msg));
        }
    };
    let (reply_tx, reply_rx) = mpsc::channel();
    let sent = tx
        .send(ApiRequest {
            call,
            reply: reply_tx,
        })
        .is_ok();
    let reply = if sent {
        reply_rx.recv_timeout(API_REPLY_TIMEOUT).ok()
    } else {
        None
    };
    match reply {
        Some((status, doc)) => respond_json(stream, status, &doc),
        None => respond_json(
            stream,
            503,
            &api::error_envelope(None, "engine loop is not serving the API"),
        ),
    }
}

fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    doc: &crate::util::json::Value,
) -> std::io::Result<()> {
    let body = doc.to_string_compact().into_bytes();
    respond(stream, status, "application/json", &body, "")
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "OK",
    }
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &[u8],
    extra_headers: &str,
) -> std::io::Result<()> {
    let mut r = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n{extra_headers}Connection: close\r\n\r\n",
        status_text(status),
        body.len()
    )
    .into_bytes();
    r.extend_from_slice(body);
    stream.write_all(&r)?;
    stream.flush()
}

/// Minimal HTTP client (tests, examples' self-check, smoke scripts).
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    http_request_with_headers(addr, method, path, &[], body)
}

/// [`http_request`] with extra request headers (auth, SSE resume).
pub fn http_request_with_headers(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    let extra: String = headers
        .iter()
        .map(|(k, v)| format!("{k}: {v}\r\n"))
        .collect();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    let text_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(buf.len());
    let head = String::from_utf8_lossy(&buf[..text_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    Ok((status, buf[text_end..].to_vec()))
}

/// Minimal GET client.
pub fn http_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    http_request(addr, "GET", path, b"")
}

/// Minimal POST client (command bodies).
pub fn http_post(
    addr: std::net::SocketAddr,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    http_request(addr, "POST", path, body)
}

/// Embedded single-file viewer: renders the v1 status + parallel queries
/// (unwrapping the versioned envelope) on a canvas.  Redraws are pushed:
/// the viewer subscribes to `GET /api/v1/events` (SSE) and re-renders
/// when progress arrives, with a slow safety-net poll instead of the old
/// 2-second busy poll.
const VIEWER_HTML: &str = r#"<!doctype html>
<html><head><meta charset="utf-8"><title>CHOPT viz</title>
<style>body{font-family:monospace;margin:16px}canvas{border:1px solid #ccc}</style>
</head><body>
<h2>CHOPT — parallel coordinates</h2>
<div>views: <a href="/api/v1/parallel">parallel</a>
 <a href="/api/v1/status">status</a>
 <a href="/api/v1/cluster?window=86400">cluster</a>
 <a href="/api/v1/curves?limit=20">curves</a>
 <a href="/api/v1/events">events (SSE)</a>
 <a href="/svg/parallel.svg">parallel.svg</a></div>
<div id="status"></div>
<canvas id="c" width="1000" height="440"></canvas>
<script>
// v1 responses wrap the document in {schema_version, data}; static
// tables may serve bare legacy documents on the unversioned paths —
// accept both, preferring v1.
const unwrap=j=>j&&j.data!==undefined?j.data:j;
async function getDoc(paths){
  for(const p of paths){
    try{const r=await fetch(p);if(r.ok)return unwrap(await r.json());}catch(e){}
  }
  return null;
}
async function draw(){
getDoc(['/api/v1/status','/api/status.json']).then(s=>{
  if(s)document.getElementById('status').textContent=
    't='+Math.round(s.t)+'s  events='+s.events_processed+'  best='+(s.best==null?'-':s.best.toFixed(2))+(s.done?'  [done]':'');
});
getDoc(['/api/v1/parallel','/api/parallel.json']).then(doc=>{
  if(!doc||!doc.axes)return;
  const cv=document.getElementById('c'),g=cv.getContext('2d');
  g.clearRect(0,0,cv.width,cv.height);
  const axes=doc.axes,lines=doc.lines;const m=60,w=cv.width-2*m,h=cv.height-80;
  const x=i=>m+w*i/(axes.length-1);
  const ranges=axes.map(a=>({lo:Infinity,hi:-Infinity}));
  const val=(l,a,i)=>i==axes.length-1?l.measure:(typeof l.values[a.name]==='number'?l.values[a.name]:null);
  lines.forEach(l=>axes.forEach((a,i)=>{const v=val(l,a,i);if(v!=null){ranges[i].lo=Math.min(ranges[i].lo,v);ranges[i].hi=Math.max(ranges[i].hi,v);}}));
  g.strokeStyle='#888';axes.forEach((a,i)=>{g.beginPath();g.moveTo(x(i),40);g.lineTo(x(i),40+h);g.stroke();g.fillText(a.name,x(i)-20,30);});
  g.strokeStyle='rgba(123,79,166,0.45)';
  lines.forEach(l=>{g.beginPath();let started=false;axes.forEach((a,i)=>{
    let v=val(l,a,i);const r=ranges[i];if(v==null||r.hi<=r.lo){v=r.lo||0}
    const y=40+h-(r.hi>r.lo?(v-r.lo)/(r.hi-r.lo):0.5)*h;
    if(!started){g.moveTo(x(i),y);started=true}else{g.lineTo(x(i),y)}});g.stroke();});
}).catch(()=>{});
}
draw();
// Push-driven redraw: progress events (SSE) coalesce into one draw per
// 500ms; polling is only the fallback when EventSource is unavailable
// or the stream endpoint is not served.
let pend=null;const kick=()=>{if(pend)return;pend=setTimeout(()=>{pend=null;draw()},500)};
let pushed=false;
if(window.EventSource){
  const es=new EventSource('/api/v1/events');
  es.onmessage=()=>{pushed=true;kick()};
}
setInterval(()=>{if(!pushed)draw()},2000);
setInterval(draw,30000);
</script></body></html>"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_routes_and_404() {
        let mut routes = Routes::new();
        routes.insert(
            "/api/test.json".into(),
            ("application/json".into(), b"{\"ok\":true}".to_vec()),
        );
        let server = VizServer::start(0, routes).unwrap();
        let addr = server.addr();
        let (status, body) = http_get(addr, "/api/test.json").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        let (status, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(status, 404);
        // Embedded viewer present at /.
        let (status, body) = http_get(addr, "/").unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("parallel coordinates"));
        // Live route update.
        server.put_route("/late", "text/plain", b"hello".to_vec());
        let (status, body) = http_get(addr, "/late").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
        server.stop();
    }

    #[test]
    fn static_routes_reject_non_get() {
        let server = VizServer::start(0, Routes::new()).unwrap();
        let addr = server.addr();
        let (status, _) = http_post(addr, "/", b"{}").unwrap();
        assert_eq!(status, 405, "POST to a static route must be a 405");
        server.stop();
    }

    #[test]
    fn bearer_check_maps_missing_vs_wrong() {
        let req = |auth: Option<&str>| Request {
            method: "POST".into(),
            path: "/api/v1/commands".into(),
            query: String::new(),
            body: Vec::new(),
            authorization: auth.map(|s| s.to_string()),
            last_event_id: None,
        };
        let token = Some("sekrit".to_string());
        // No token configured: everything passes.
        assert!(check_bearer(&req(None), &None).is_ok());
        // Missing or non-bearer credentials: 401.
        assert_eq!(
            check_bearer(&req(None), &token).unwrap_err().http_status(),
            401
        );
        assert_eq!(
            check_bearer(&req(Some("Basic abc")), &token).unwrap_err().http_status(),
            401
        );
        // Wrong token: 403.  Right token: pass.
        assert_eq!(
            check_bearer(&req(Some("Bearer nope")), &token).unwrap_err().http_status(),
            403
        );
        assert!(check_bearer(&req(Some("Bearer sekrit")), &token).is_ok());
    }

    #[test]
    fn sse_route_rejects_non_get() {
        let server = VizServer::start(0, Routes::new()).unwrap();
        server.serve_events(
            crate::viz::sse::EventFeed::new(8),
            Duration::from_millis(50),
        );
        let (status, _) = http_post(server.addr(), "/api/v1/events", b"").unwrap();
        assert_eq!(status, 405);
        server.stop();
    }

    #[test]
    fn concurrent_connections_are_served() {
        // Per-connection threads: several clients at once all complete.
        let mut routes = Routes::new();
        routes.insert("/x".into(), ("text/plain".into(), b"y".to_vec()));
        let server = VizServer::start(0, routes).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || http_get(addr, "/x").unwrap()))
            .collect();
        for h in handles {
            let (status, body) = h.join().unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, b"y");
        }
        assert!(server.requests.load(std::sync::atomic::Ordering::Relaxed) >= 8);
        server.stop();
    }
}
