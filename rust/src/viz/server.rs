//! Dependency-free HTTP server for the analytic tool.
//!
//! Serves the JSON exports and SVG renders over `GET`, plus an embedded
//! single-file HTML viewer that draws the parallel coordinates client-side
//! from `/api/parallel.json` (the same document `export::parallel_coords_doc`
//! produces).  This is the "web-based" half of §3.5 without a JS toolchain.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A route table: path → (content type, body).
pub type Routes = HashMap<String, (String, Vec<u8>)>;

/// The viz HTTP server.
pub struct VizServer {
    routes: Arc<Mutex<Routes>>,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub requests: Arc<AtomicU64>,
}

impl VizServer {
    /// Bind on 127.0.0.1:`port` (0 = ephemeral) and start serving.
    pub fn start(port: u16, mut routes: Routes) -> std::io::Result<VizServer> {
        routes
            .entry("/".to_string())
            .or_insert(("text/html".to_string(), VIEWER_HTML.as_bytes().to_vec()));
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let routes = Arc::new(Mutex::new(routes));
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let (r2, s2, q2) = (routes.clone(), stop.clone(), requests.clone());
        let handle = std::thread::spawn(move || {
            while !s2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        q2.fetch_add(1, Ordering::Relaxed);
                        let _ = handle_conn(stream, &r2);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(VizServer {
            routes,
            addr,
            stop,
            handle: Some(handle),
            requests,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Replace/add a route while running.
    pub fn put_route(&self, path: &str, content_type: &str, body: Vec<u8>) {
        self.routes
            .lock()
            .unwrap()
            .insert(path.to_string(), (content_type.to_string(), body));
    }

    /// Replace/add a JSON route while running (`serve --live` republishes
    /// the leaderboard/parallel/cluster documents through this on every
    /// engine advance).
    pub fn put_json(&self, path: &str, doc: &crate::util::json::Value) {
        self.put_route(path, "application/json", doc.to_string_compact().into_bytes());
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for VizServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(mut stream: TcpStream, routes: &Arc<Mutex<Routes>>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(500)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // Drain headers.
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
            break;
        }
    }
    let path = request_line
        .split_whitespace()
        .nth(1)
        .unwrap_or("/")
        .split('?')
        .next()
        .unwrap_or("/")
        .to_string();
    let routes = routes.lock().unwrap();
    let response = match routes.get(&path) {
        Some((ctype, body)) => {
            let mut r = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                body.len()
            )
            .into_bytes();
            r.extend_from_slice(body);
            r
        }
        None => {
            let body = b"404 not found";
            let mut r = format!(
                "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                body.len()
            )
            .into_bytes();
            r.extend_from_slice(body);
            r
        }
    };
    stream.write_all(&response)?;
    stream.flush()
}

/// Minimal GET client (tests + examples' self-check).
pub fn http_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    let text_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(buf.len());
    let head = String::from_utf8_lossy(&buf[..text_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    Ok((status, buf[text_end..].to_vec()))
}

/// Embedded single-file viewer: fetches /api/parallel.json and draws
/// parallel coordinates on a canvas.
const VIEWER_HTML: &str = r#"<!doctype html>
<html><head><meta charset="utf-8"><title>CHOPT viz</title>
<style>body{font-family:monospace;margin:16px}canvas{border:1px solid #ccc}</style>
</head><body>
<h2>CHOPT — parallel coordinates</h2>
<div>views: <a href="/api/parallel.json">parallel.json</a>
 <a href="/api/curves.json">curves.json</a>
 <a href="/svg/parallel.svg">parallel.svg</a></div>
<div id="status"></div>
<canvas id="c" width="1000" height="440"></canvas>
<script>
function draw(){
fetch('/api/status.json').then(r=>r.ok?r.json():null).then(s=>{
  if(s)document.getElementById('status').textContent=
    't='+Math.round(s.t)+'s  events='+s.events_processed+'  best='+(s.best==null?'-':s.best.toFixed(2))+(s.done?'  [done]':'');
}).catch(()=>{});
fetch('/api/parallel.json').then(r=>r.ok?r.json():null).then(doc=>{
  if(!doc)return;
  const cv=document.getElementById('c'),g=cv.getContext('2d');
  g.clearRect(0,0,cv.width,cv.height);
  const axes=doc.axes,lines=doc.lines;const m=60,w=cv.width-2*m,h=cv.height-80;
  const x=i=>m+w*i/(axes.length-1);
  const ranges=axes.map(a=>({lo:Infinity,hi:-Infinity}));
  const val=(l,a,i)=>i==axes.length-1?l.measure:(typeof l.values[a.name]==='number'?l.values[a.name]:null);
  lines.forEach(l=>axes.forEach((a,i)=>{const v=val(l,a,i);if(v!=null){ranges[i].lo=Math.min(ranges[i].lo,v);ranges[i].hi=Math.max(ranges[i].hi,v);}}));
  g.strokeStyle='#888';axes.forEach((a,i)=>{g.beginPath();g.moveTo(x(i),40);g.lineTo(x(i),40+h);g.stroke();g.fillText(a.name,x(i)-20,30);});
  g.strokeStyle='rgba(123,79,166,0.45)';
  lines.forEach(l=>{g.beginPath();let started=false;axes.forEach((a,i)=>{
    let v=val(l,a,i);const r=ranges[i];if(v==null||r.hi<=r.lo){v=r.lo||0}
    const y=40+h-(r.hi>r.lo?(v-r.lo)/(r.hi-r.lo):0.5)*h;
    if(!started){g.moveTo(x(i),y);started=true}else{g.lineTo(x(i),y)}});g.stroke();});
}).catch(()=>{});
}
draw();setInterval(draw,2000);
</script></body></html>"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_routes_and_404() {
        let mut routes = Routes::new();
        routes.insert(
            "/api/test.json".into(),
            ("application/json".into(), b"{\"ok\":true}".to_vec()),
        );
        let server = VizServer::start(0, routes).unwrap();
        let addr = server.addr();
        let (status, body) = http_get(addr, "/api/test.json").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        let (status, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(status, 404);
        // Embedded viewer present at /.
        let (status, body) = http_get(addr, "/").unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("parallel coordinates"));
        // Live route update.
        server.put_route("/late", "text/plain", b"hello".to_vec());
        let (status, body) = http_get(addr, "/late").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
        server.stop();
    }
}
