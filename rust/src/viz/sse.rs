//! Server-sent-events push for dashboards: the progress-stream feed
//! behind `GET /api/v1/events`.
//!
//! The viewer used to poll every v1 query on a timer whether anything
//! had happened or not.  The platform now publishes every progress
//! record (the same JSON objects the JSONL event log receives) into an
//! [`EventFeed`] — a bounded, sequence-numbered ring buffer — and each
//! SSE connection gets its own thread that tails the feed:
//!
//! * events are framed as `id: <seq>` + `data: <json>` blocks, so
//!   browsers' `EventSource` reconnect sends `Last-Event-ID` and the
//!   stream resumes after the last record the client saw;
//! * when the feed is idle a comment heartbeat (`: heartbeat`) is
//!   written at the configured cadence, so proxies and clients can tell
//!   "no events" from "dead server";
//! * the buffer is bounded: a slow client that reconnects past the
//!   retention window resumes from the oldest retained record and the
//!   frame notes how many were dropped.
//!
//! The feed is `Sync` (mutex + condvar) while the platform stays
//! single-threaded: publishing is a lock + push from the engine loop,
//! never an I/O wait on a consumer.

use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::Value as Json;

/// Default retained events for live runs (stored runs retain everything).
pub const DEFAULT_FEED_CAPACITY: usize = 65_536;

struct FeedInner {
    /// (sequence, serialized JSON line) — sequences start at 1 and never
    /// repeat; the front is the oldest retained record.
    events: VecDeque<(u64, String)>,
    next_seq: u64,
    /// Records evicted by the capacity bound over the feed's lifetime.
    dropped: u64,
}

/// Optional on-disk mirror of the feed: every published record appended
/// as one JSONL line *while the ring lock is held*, so line `k` of the
/// file is exactly sequence `k`.  This is what lets `?since=<seq>` (and
/// a `Last-Event-ID` resume that fell behind the window) replay records
/// the bounded ring already evicted.
struct HistoryLog {
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

/// The progress-event ring buffer SSE connections tail.
pub struct EventFeed {
    inner: Mutex<FeedInner>,
    cv: Condvar,
    capacity: usize,
    history: Option<HistoryLog>,
}

impl EventFeed {
    /// A feed retaining at most `capacity` records (older ones are
    /// evicted; reconnecting clients see the drop count).
    pub fn new(capacity: usize) -> Arc<EventFeed> {
        EventFeed::build(capacity, None)
    }

    /// A feed that also mirrors every record to a JSONL history log at
    /// `path` (truncated — feed sequences restart at 1 with the feed).
    /// SSE connections use it to serve `?since=` below the ring's
    /// retention window.
    pub fn with_history(capacity: usize, path: impl AsRef<Path>) -> std::io::Result<Arc<EventFeed>> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = std::fs::File::create(&path)?;
        Ok(EventFeed::build(
            capacity,
            Some(HistoryLog {
                path,
                file: Mutex::new(file),
            }),
        ))
    }

    fn build(capacity: usize, history: Option<HistoryLog>) -> Arc<EventFeed> {
        Arc::new(EventFeed {
            inner: Mutex::new(FeedInner {
                events: VecDeque::new(),
                next_seq: 1,
                dropped: 0,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
            history,
        })
    }

    /// Path of the history log, when one is attached.
    pub fn history_path(&self) -> Option<&Path> {
        self.history.as_ref().map(|h| h.path.as_path())
    }

    /// Publish one already-serialized JSON record; returns its sequence.
    pub fn publish(&self, line: String) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if let Some(h) = &self.history {
            // Written under the ring lock so line k == seq k.  A failed
            // write (disk full) degrades ?since= to the drop notice;
            // publishing itself never fails.
            let mut f = h.file.lock().unwrap();
            let _ = f.write_all(line.as_bytes());
            let _ = f.write_all(b"\n");
        }
        inner.events.push_back((seq, line));
        while inner.events.len() > self.capacity {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        drop(inner);
        self.cv.notify_all();
        seq
    }

    /// Replay records from the history log with sequence in
    /// `(after, oldest-retained)` — the gap the ring has already
    /// evicted.  At most `cap` records per call: callers loop,
    /// interleaving writes, instead of buffering an unbounded backlog.
    /// `None` when the feed has no history log attached.  Only fully
    /// written lines below the ring's oldest record are returned, so a
    /// concurrent publish can never surface a torn line.
    pub fn history_after(&self, after: u64, cap: usize) -> Option<Vec<(u64, String)>> {
        let history = self.history.as_ref()?;
        let oldest = {
            let inner = self.inner.lock().unwrap();
            inner.events.front().map(|&(s, _)| s).unwrap_or(inner.next_seq)
        };
        if after.saturating_add(1) >= oldest {
            return Some(Vec::new());
        }
        let file = match std::fs::File::open(&history.path) {
            Ok(f) => f,
            Err(_) => return Some(Vec::new()),
        };
        let mut out = Vec::new();
        let mut seq = 0u64;
        for line in std::io::BufReader::new(file).lines() {
            let Ok(line) = line else { break };
            seq += 1;
            if seq <= after {
                continue;
            }
            if seq >= oldest || out.len() >= cap {
                break;
            }
            out.push((seq, line));
        }
        Some(out)
    }

    /// Publish a JSON document (compact form — same bytes as the JSONL
    /// event log).
    pub fn publish_json(&self, doc: &Json) -> u64 {
        self.publish(doc.to_string_compact())
    }

    /// Sequence of the most recent record (0 = nothing published yet).
    pub fn last_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq - 1
    }

    /// Shared core of [`EventFeed::read_after`] / [`EventFeed::wait_after`]:
    /// records with sequence > `after` that are still retained, plus how
    /// many the cursor missed to eviction.  Saturating arithmetic —
    /// `after` arrives from the client-controlled `Last-Event-ID`
    /// header, so `u64::MAX` must not overflow (it simply sees nothing
    /// new and no drops).
    fn collect_after(inner: &FeedInner, after: u64) -> (u64, Vec<(u64, String)>) {
        let oldest = inner.events.front().map(|&(s, _)| s).unwrap_or(inner.next_seq);
        let missed = oldest.saturating_sub(after.saturating_add(1));
        let out = inner
            .events
            .iter()
            .filter(|&&(s, _)| s > after)
            .cloned()
            .collect();
        (missed, out)
    }

    /// Records with sequence > `after` that are still retained, plus how
    /// many the client missed to eviction (non-zero only when `after`
    /// fell behind the retention window).
    pub fn read_after(&self, after: u64) -> (u64, Vec<(u64, String)>) {
        EventFeed::collect_after(&self.inner.lock().unwrap(), after)
    }

    /// Like [`EventFeed::read_after`], but blocks up to `timeout` for at
    /// least one fresh record.  An empty result means the timeout passed
    /// with nothing new — the caller's heartbeat moment.
    pub fn wait_after(&self, after: u64, timeout: Duration) -> (u64, Vec<(u64, String)>) {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            // Cheap emptiness check before scanning the ring.
            if inner.next_seq > after.saturating_add(1) {
                let (missed, out) = EventFeed::collect_after(&inner, after);
                if !out.is_empty() || missed > 0 {
                    return (missed, out);
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return (0, Vec::new());
            }
            let (guard, _) = self.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_and_reads_are_ordered() {
        let feed = EventFeed::new(16);
        assert_eq!(feed.last_seq(), 0);
        assert_eq!(feed.publish("a".into()), 1);
        assert_eq!(feed.publish("b".into()), 2);
        let (missed, got) = feed.read_after(0);
        assert_eq!(missed, 0);
        assert_eq!(got, vec![(1, "a".to_string()), (2, "b".to_string())]);
        let (_, tail) = feed.read_after(1);
        assert_eq!(tail, vec![(2, "b".to_string())]);
        assert!(feed.read_after(2).1.is_empty());
    }

    #[test]
    fn capacity_evicts_and_reports_missed() {
        let feed = EventFeed::new(2);
        for s in ["a", "b", "c", "d"] {
            feed.publish(s.into());
        }
        // Only 3 and 4 retained; a client resuming after 1 missed one.
        let (missed, got) = feed.read_after(1);
        assert_eq!(missed, 1);
        assert_eq!(got.first().map(|&(s, _)| s), Some(3));
        assert_eq!(feed.last_seq(), 4);
        // A future/huge cursor (client-controlled Last-Event-ID) must
        // not overflow or mis-report drops — it just sees nothing new.
        let (missed, got) = feed.read_after(u64::MAX);
        assert_eq!((missed, got.len()), (0, 0));
        assert!(feed.wait_after(u64::MAX, Duration::from_millis(5)).1.is_empty());
    }

    #[test]
    fn history_log_replays_evicted_records() {
        let dir = std::env::temp_dir().join(format!("chopt-sse-hist-{}", std::process::id()));
        let path = dir.join("events.jsonl");
        let feed = EventFeed::with_history(2, &path).unwrap();
        assert_eq!(feed.history_path(), Some(path.as_path()));
        for s in ["a", "b", "c", "d", "e"] {
            feed.publish(s.into());
        }
        // Ring retains 4..5; the ring alone reports 3 missed from 0.
        let (missed, got) = feed.read_after(0);
        assert_eq!(missed, 3);
        assert_eq!(got.first().map(|&(s, _)| s), Some(4));
        // The history log covers the evicted gap exactly: (after, oldest).
        assert_eq!(
            feed.history_after(0, 100).unwrap(),
            vec![(1, "a".to_string()), (2, "b".to_string()), (3, "c".to_string())]
        );
        // The cap bounds each batch; the cursor loop picks up the rest.
        assert_eq!(feed.history_after(0, 1).unwrap(), vec![(1, "a".to_string())]);
        assert_eq!(feed.history_after(1, 1).unwrap(), vec![(2, "b".to_string())]);
        // At or past the ring's oldest record: nothing from history.
        assert!(feed.history_after(3, 100).unwrap().is_empty());
        assert!(feed.history_after(u64::MAX, 100).unwrap().is_empty());
        // Feeds without history report None (callers fall back to the
        // drop notice).
        assert!(EventFeed::new(2).history_after(0, 10).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_blocks_until_publish_or_timeout() {
        let feed = EventFeed::new(8);
        // Timeout path: nothing published.
        let t0 = Instant::now();
        let (_, got) = feed.wait_after(0, Duration::from_millis(30));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // Wake path: a publish from another thread releases the wait.
        let f2 = feed.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f2.publish("x".into());
        });
        let (_, got) = feed.wait_after(0, Duration::from_secs(5));
        assert_eq!(got.len(), 1);
        h.join().unwrap();
    }
}
