//! The versioned control-plane API: command + query `/api/v1`.
//!
//! The serving layer used to be a passive route table the engine loop
//! pushed full documents into on every tick.  This module replaces that
//! with a **pull-based** surface:
//!
//! * **Queries** — `GET /api/v1/{status,cluster,fair_share,studies,
//!   sessions,leaderboard,parallel,curves}` (plus per-study variants
//!   under `/api/v1/studies/<name>/`) are parsed into typed [`ApiQuery`]
//!   values and answered from a [`RunSource`]'s incremental documents at
//!   request time, instead of the loop re-rendering every document every
//!   tick whether anyone is watching or not.
//! * **Commands** — `POST /api/v1/commands` bodies parse into typed
//!   [`ApiCommand`] values which a [`CommandSink`] (the `SimEngine` /
//!   `StudyScheduler` loop) applies at tick boundaries (submit a study,
//!   pause/resume/stop a session or study, set quota/priority).
//!   Commands are recorded as replay inputs, so a command-steered run
//!   stays snapshot-restorable.
//! * **Envelope** — every response carries `schema_version`,
//!   `generated_at_event` (a *string*: event counts are u64), and the
//!   payload under `data` (or `error`).  All ids are strings throughout.
//!
//! The read side is deliberately its own trait so the same `/api/v1`
//! surface serves three run shapes behind one abstraction:
//!
//! * **live** — `Platform` / `MultiPlatform` answer from their
//!   incremental documents ([`RunSource`] + [`CommandSink`]),
//! * **stored** — `storage::StoredRun` rebuilds the identical documents
//!   from a run directory's snapshot (read-only: its [`CommandSink`]
//!   rejects every command),
//! * **replayed** — `storage::ReplaySource` scrubs a snapshot to any
//!   recorded event count (`?at_event=N` on any query).
//!
//! The legacy unversioned `/api/*.json` paths are **deprecated aliases**
//! onto the v1 handlers: they serve byte-identical v1 bodies.
//!
//! Threading: the HTTP server answers each connection on its own thread,
//! but the platform is single-threaded by design (`&mut` engine loop).
//! The bridge is a channel of [`ApiRequest`]s: connection threads enqueue
//! and block on a reply; the engine loop drains the [`ApiInbox`] between
//! advances — which is exactly the "commands apply at tick boundaries"
//! contract.  Auth (`--api-token`) and the SSE push stream
//! (`/api/v1/events`) are enforced/served by the HTTP layer itself, so
//! the engine loop never sees unauthorized commands and never blocks on
//! a slow stream consumer.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::util::json::Value as Json;

/// Schema version stamped into every envelope.
pub const SCHEMA_VERSION: f64 = 1.0;

/// A typed v1 query (the GET half of the surface).
#[derive(Debug, Clone, PartialEq)]
pub enum ApiQuery {
    /// One-object run status heartbeat.
    Status,
    /// Cluster utilization; `window` caps the serialized series to the
    /// last `window` virtual seconds.
    Cluster { window: Option<f64> },
    /// Multi-tenant fair-share accounting (multi-study runs only).
    FairShare,
    /// Study directory (multi-study runs only).
    Studies,
    /// Paginated session list.
    Sessions { limit: usize, offset: usize },
    /// Merged leaderboard, top `k`.
    Leaderboard { k: usize },
    /// Parallel-coordinates document.
    Parallel,
    /// Paginated per-session loss/measure curves ("Scalar plot view").
    Curves { limit: usize, offset: usize },
    /// Paginated session list of one study.
    StudySessions {
        study: String,
        limit: usize,
        offset: usize,
    },
    /// One study's leaderboard, top `k`.
    StudyLeaderboard { study: String, k: usize },
    /// One study's parallel-coordinates document.
    StudyParallel { study: String },
    /// Paginated curves of one study.
    StudyCurves {
        study: String,
        limit: usize,
        offset: usize,
    },
}

/// A typed v1 command (the POST half).  Session ids travel as strings.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiCommand {
    /// Submit a new study from a manifest-style spec (multi-study runs).
    /// The spec is kept as raw JSON and parsed by the platform so parse
    /// errors surface as 400s with the real message.
    SubmitStudy { spec: Json, at: Option<f64> },
    /// Submit a new CHOPT session from a Listing-1 config (single-study).
    Submit { config: Json, at: Option<f64> },
    /// Park a live session until an explicit resume.
    PauseSession { study: Option<String>, session: u64 },
    /// Revive a paused session (priority-queued if no GPU is free).
    ResumeSession { study: Option<String>, session: u64 },
    /// Kill a session outright.
    StopSession { study: Option<String>, session: u64 },
    /// Hold a study at zero GPUs until resumed.
    PauseStudy { study: String },
    ResumeStudy { study: String },
    /// Shut a study down (its sessions finish with horizon semantics).
    StopStudy { study: String },
    /// Change a study's guaranteed quota and/or fair-share weight.
    SetQuota {
        study: String,
        quota: Option<usize>,
        priority: Option<f64>,
    },
}

impl ApiCommand {
    /// Parse a `POST /api/v1/commands` body.
    pub fn from_json(doc: &Json) -> Result<ApiCommand, String> {
        let kind = doc
            .get("command")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "body must carry a string 'command' field".to_string())?;
        let study = || {
            doc.get("study")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| format!("'{kind}' needs a string 'study' field"))
        };
        let opt_study = doc
            .get("study")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string());
        // Session ids are string-encoded u64s (bare numbers accepted for
        // convenience but corrupt past 2^53) — the shared wire form.
        let session = || -> Result<u64, String> {
            match doc.get("session") {
                Some(v) => crate::nsml::SessionId::from_json(v)
                    .map(|sid| sid.0)
                    .ok_or_else(|| "'session' must be a string-encoded u64 id".to_string()),
                None => Err(format!("'{kind}' needs a 'session' field")),
            }
        };
        let at = doc.get("at").and_then(|v| v.as_f64());
        match kind {
            "submit_study" => Ok(ApiCommand::SubmitStudy {
                spec: doc
                    .get("study")
                    .cloned()
                    .ok_or_else(|| "'submit_study' needs a 'study' spec object".to_string())?,
                at,
            }),
            "submit" => Ok(ApiCommand::Submit {
                config: doc
                    .get("config")
                    .cloned()
                    .ok_or_else(|| "'submit' needs a 'config' object".to_string())?,
                at,
            }),
            "pause_session" => Ok(ApiCommand::PauseSession {
                study: opt_study,
                session: session()?,
            }),
            "resume_session" => Ok(ApiCommand::ResumeSession {
                study: opt_study,
                session: session()?,
            }),
            "stop_session" => Ok(ApiCommand::StopSession {
                study: opt_study,
                session: session()?,
            }),
            "pause_study" => Ok(ApiCommand::PauseStudy { study: study()? }),
            "resume_study" => Ok(ApiCommand::ResumeStudy { study: study()? }),
            "stop_study" => Ok(ApiCommand::StopStudy { study: study()? }),
            "set_quota" => {
                let quota = match doc.get("quota") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_usize()
                            .ok_or_else(|| "'quota' must be a non-negative integer".to_string())?,
                    ),
                };
                let priority = match doc.get("priority") {
                    None | Some(Json::Null) => None,
                    Some(v) => {
                        Some(v.as_f64().ok_or_else(|| "'priority' must be a number".to_string())?)
                    }
                };
                if quota.is_none() && priority.is_none() {
                    return Err("'set_quota' needs 'quota' and/or 'priority'".to_string());
                }
                Ok(ApiCommand::SetQuota {
                    study: study()?,
                    quota,
                    priority,
                })
            }
            other => Err(format!("unknown command '{other}'")),
        }
    }

    /// The command's wire name (acks echo it).
    pub fn name(&self) -> &'static str {
        match self {
            ApiCommand::SubmitStudy { .. } => "submit_study",
            ApiCommand::Submit { .. } => "submit",
            ApiCommand::PauseSession { .. } => "pause_session",
            ApiCommand::ResumeSession { .. } => "resume_session",
            ApiCommand::StopSession { .. } => "stop_session",
            ApiCommand::PauseStudy { .. } => "pause_study",
            ApiCommand::ResumeStudy { .. } => "resume_study",
            ApiCommand::StopStudy { .. } => "stop_study",
            ApiCommand::SetQuota { .. } => "set_quota",
        }
    }
}

/// Handler-side error: mapped to an HTTP status + error envelope.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Unknown resource (study, endpoint not served by this run shape).
    NotFound(String),
    /// The request was understood but invalid (bad param, rejected
    /// command, malformed embedded config).
    BadRequest(String),
    /// The command surface requires a bearer token and none was sent.
    Unauthorized(String),
    /// A bearer token was sent but it does not match `--api-token`.
    Forbidden(String),
}

impl ApiError {
    pub fn http_status(&self) -> u16 {
        match self {
            ApiError::NotFound(_) => 404,
            ApiError::BadRequest(_) => 400,
            ApiError::Unauthorized(_) => 401,
            ApiError::Forbidden(_) => 403,
        }
    }

    pub fn message(&self) -> &str {
        match self {
            ApiError::NotFound(m)
            | ApiError::BadRequest(m)
            | ApiError::Unauthorized(m)
            | ApiError::Forbidden(m) => m,
        }
    }
}

/// Route-parse outcome: a typed call, or an HTTP-level error.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiCall {
    Query(ApiQuery),
    /// A query scrubbed to a recorded event count (`?at_event=N`) —
    /// served by replay-capable sources ([`RunSource::query_at`]).
    QueryAt(ApiQuery, u64),
    Command(ApiCommand),
}

/// Route-level errors the server answers without consulting the platform.
#[derive(Debug, Clone, PartialEq)]
pub enum RouteError {
    /// Not an API path this version serves.
    NotFound,
    /// Known path, wrong method (GET on /commands, POST on a query).
    MethodNotAllowed,
    /// Bad query parameter or malformed command body.
    BadRequest(String),
}

/// Parse an HTTP request into a typed API call.  `query` is the raw
/// query string (no leading `?`); `body` is the request body.
///
/// Legacy `/api/*.json` paths parse to the same [`ApiQuery`] values as
/// their `/api/v1` counterparts — the deprecation story is "same handler,
/// same bytes, new name".
pub fn parse_route(
    method: &str,
    path: &str,
    query: &str,
    body: &[u8],
) -> Result<ApiCall, RouteError> {
    if path == "/api/v1/commands" {
        if method != "POST" {
            return Err(RouteError::MethodNotAllowed);
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| RouteError::BadRequest("body is not UTF-8".into()))?;
        let doc = crate::util::json::parse(text)
            .map_err(|e| RouteError::BadRequest(format!("malformed JSON body: {e}")))?;
        let cmd = ApiCommand::from_json(&doc).map_err(RouteError::BadRequest)?;
        return Ok(ApiCall::Command(cmd));
    }

    let q = match route_query(path, query)? {
        Some(q) => q,
        None => return Err(RouteError::NotFound),
    };
    if method != "GET" {
        return Err(RouteError::MethodNotAllowed);
    }
    // `?at_event=N` scrubs any query to a recorded event count (replay-
    // capable sources; others answer 400).
    match param_u64(query, "at_event")? {
        Some(at) => Ok(ApiCall::QueryAt(q, at)),
        None => Ok(ApiCall::Query(q)),
    }
}

/// Map a path (v1 or legacy alias) to a query, or `None` if unknown.
fn route_query(path: &str, query: &str) -> Result<Option<ApiQuery>, RouteError> {
    let k = || param_usize(query, "k", 10);
    let limit = || param_usize(query, "limit", usize::MAX);
    let offset = || param_usize(query, "offset", 0);
    let q = match path {
        "/api/v1/status" | "/api/status.json" => ApiQuery::Status,
        "/api/v1/cluster" | "/api/cluster.json" => ApiQuery::Cluster {
            window: param_f64(query, "window")?,
        },
        "/api/v1/fair_share" | "/api/fair_share.json" => ApiQuery::FairShare,
        "/api/v1/studies" => ApiQuery::Studies,
        "/api/v1/sessions" | "/api/sessions.json" => ApiQuery::Sessions {
            limit: limit()?,
            offset: offset()?,
        },
        "/api/v1/leaderboard" | "/api/leaderboard.json" => ApiQuery::Leaderboard { k: k()? },
        "/api/v1/parallel" | "/api/parallel.json" => ApiQuery::Parallel,
        "/api/v1/curves" | "/api/curves.json" => ApiQuery::Curves {
            limit: limit()?,
            offset: offset()?,
        },
        _ => {
            // /api/v1/studies/<name>/<view> and the legacy
            // /api/studies/<name>/<view>.json per-study routes.
            let rest = if let Some(r) = path.strip_prefix("/api/v1/studies/") {
                r
            } else if let Some(r) = path.strip_prefix("/api/studies/") {
                r
            } else {
                return Ok(None);
            };
            let Some((study, view)) = rest.split_once('/') else {
                return Ok(None);
            };
            if study.is_empty() || study.contains('/') {
                return Ok(None);
            }
            let study = study.to_string();
            match view {
                "sessions" | "sessions.json" => ApiQuery::StudySessions {
                    study,
                    limit: limit()?,
                    offset: offset()?,
                },
                "leaderboard" | "leaderboard.json" => {
                    ApiQuery::StudyLeaderboard { study, k: k()? }
                }
                "parallel" | "parallel.json" => ApiQuery::StudyParallel { study },
                "curves" | "curves.json" => ApiQuery::StudyCurves {
                    study,
                    limit: limit()?,
                    offset: offset()?,
                },
                _ => return Ok(None),
            }
        }
    };
    Ok(Some(q))
}

fn param<'q>(query: &'q str, name: &str) -> Option<&'q str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == name)
        .map(|(_, v)| v)
}

fn param_usize(query: &str, name: &str, default: usize) -> Result<usize, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(default),
        Some(v) => v.parse::<usize>().map_err(|_| {
            RouteError::BadRequest(format!("'{name}' must be a non-negative integer"))
        }),
    }
}

fn param_u64(query: &str, name: &str) -> Result<Option<u64>, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(None),
        Some(v) => v.parse::<u64>().map(Some).map_err(|_| {
            RouteError::BadRequest(format!("'{name}' must be a non-negative integer"))
        }),
    }
}

fn param_f64(query: &str, name: &str) -> Result<Option<f64>, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(None),
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|w| w.is_finite() && *w >= 0.0)
            .map(Some)
            .ok_or_else(|| {
                RouteError::BadRequest(format!("'{name}' must be a non-negative number"))
            }),
    }
}

/// The **read side** of the `/api/v1` surface: one trait, three
/// backends.  Implemented by `coordinator::Platform` (live single
/// study), `coordinator::MultiPlatform` (live multi-tenant),
/// `storage::StoredRun` (a run directory rebuilt into the same
/// incremental documents), and `storage::ReplaySource` (scrub-to-event
/// replay).  Endpoints that don't apply to a run shape return
/// [`ApiError::NotFound`].
pub trait RunSource {
    /// Monotone progress marker stamped into every envelope
    /// (`generated_at_event`) — the engine's processed-event count.
    fn generation(&self) -> u64;

    /// Answer a query from the (incremental) documents.
    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError>;

    /// Answer `q` as of recorded event count `at` (`?at_event=N`).
    /// Returns the effective generation (the replayed event count, which
    /// caps at the snapshot's end) alongside the document.  Only replay-
    /// capable sources override this; live runs cannot rewind.
    fn query_at(&self, _q: &ApiQuery, _at: u64) -> Result<(u64, Json), ApiError> {
        Err(ApiError::BadRequest(
            "this run source does not support ?at_event — serve a stored run to scrub".into(),
        ))
    }
}

/// The **command side** of the surface: applied by the engine loop
/// between advances, so effects land at tick boundaries; the returned
/// ack documents what was accepted (commands take effect at the *next*
/// event boundary).  Read-only sources (stored runs) reject every
/// command.
pub trait CommandSink {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError>;
}

/// Read + command halves together — what a *live* platform exposes and
/// what the [`ApiInbox`] serves.  Blanket-implemented, so implementing
/// the two halves is all a backend ever does.
pub trait PlatformApi: RunSource + CommandSink {}

impl<T: RunSource + CommandSink> PlatformApi for T {}

/// Wrap a payload in the uniform v1 envelope.
pub fn envelope(generation: u64, data: Json) -> Json {
    Json::obj()
        .with("schema_version", Json::Num(SCHEMA_VERSION))
        .with("api", Json::Str("v1".into()))
        .with("generated_at_event", Json::Str(generation.to_string()))
        .with("data", data)
}

/// The error-envelope twin of [`envelope`].
pub fn error_envelope(generation: Option<u64>, message: &str) -> Json {
    Json::obj()
        .with("schema_version", Json::Num(SCHEMA_VERSION))
        .with("api", Json::Str("v1".into()))
        .with(
            "generated_at_event",
            generation
                .map(|g| Json::Str(g.to_string()))
                .unwrap_or(Json::Null),
        )
        .with("error", Json::Str(message.to_string()))
}

/// One in-flight HTTP API request: the parsed call plus the reply slot
/// the connection thread blocks on.
pub struct ApiRequest {
    pub call: ApiCall,
    pub reply: mpsc::Sender<(u16, Json)>,
}

/// The engine-loop end of the API bridge (`VizServer::enable_api`).
pub struct ApiInbox {
    rx: mpsc::Receiver<ApiRequest>,
}

impl ApiInbox {
    pub(crate) fn new(rx: mpsc::Receiver<ApiRequest>) -> ApiInbox {
        ApiInbox { rx }
    }

    fn answer(req: ApiRequest, api: &mut impl PlatformApi) {
        // Scrubbed queries report the replayed event count as their
        // generation; everything else reports the source's current one.
        let outcome = match &req.call {
            ApiCall::Query(q) => api.query(q).map(|d| (api.generation(), d)),
            ApiCall::QueryAt(q, at) => api.query_at(q, *at),
            ApiCall::Command(c) => api.command(c).map(|d| (api.generation(), d)),
        };
        let (status, body) = match outcome {
            Ok((generation, data)) => (200, envelope(generation, data)),
            Err(e) => (
                e.http_status(),
                error_envelope(Some(api.generation()), e.message()),
            ),
        };
        // A vanished client (timeout, dropped connection) is not an error.
        let _ = req.reply.send((status, body));
    }

    /// Answer everything currently queued without blocking.  Returns the
    /// number of requests served.
    pub fn drain(&self, api: &mut impl PlatformApi) -> usize {
        let mut n = 0;
        while let Ok(req) = self.rx.try_recv() {
            Self::answer(req, api);
            n += 1;
        }
        n
    }

    /// Block up to `timeout` for one request and answer it.  Returns
    /// whether a request was served.
    pub fn serve_one(&self, api: &mut impl PlatformApi, timeout: Duration) -> bool {
        match self.rx.recv_timeout(timeout) {
            Ok(req) => {
                Self::answer(req, api);
                true
            }
            Err(_) => false,
        }
    }

    /// Serve requests for roughly `window` wall-clock time (the engine
    /// loop's between-advances breather — replaces a blind sleep).
    pub fn serve_for(&self, api: &mut impl PlatformApi, window: Duration) -> usize {
        let deadline = Instant::now() + window;
        let mut n = 0;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return n;
            }
            if self.serve_one(api, deadline - now) {
                n += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_and_legacy_paths_parse_to_the_same_query() {
        for (v1, legacy) in [
            ("/api/v1/status", "/api/status.json"),
            ("/api/v1/cluster", "/api/cluster.json"),
            ("/api/v1/fair_share", "/api/fair_share.json"),
            ("/api/v1/sessions", "/api/sessions.json"),
            ("/api/v1/leaderboard", "/api/leaderboard.json"),
            ("/api/v1/parallel", "/api/parallel.json"),
            ("/api/v1/curves", "/api/curves.json"),
            ("/api/v1/studies/alice/sessions", "/api/studies/alice/sessions.json"),
            (
                "/api/v1/studies/alice/leaderboard",
                "/api/studies/alice/leaderboard.json",
            ),
        ] {
            let a = parse_route("GET", v1, "", b"").unwrap();
            let b = parse_route("GET", legacy, "", b"").unwrap();
            assert_eq!(a, b, "{v1} vs {legacy}");
        }
    }

    #[test]
    fn query_params_parse_and_validate() {
        assert_eq!(
            parse_route("GET", "/api/v1/sessions", "limit=5&offset=10", b"").unwrap(),
            ApiCall::Query(ApiQuery::Sessions {
                limit: 5,
                offset: 10
            })
        );
        assert_eq!(
            parse_route("GET", "/api/v1/cluster", "window=3600", b"").unwrap(),
            ApiCall::Query(ApiQuery::Cluster {
                window: Some(3600.0)
            })
        );
        assert_eq!(
            parse_route("GET", "/api/v1/leaderboard", "k=3", b"").unwrap(),
            ApiCall::Query(ApiQuery::Leaderboard { k: 3 })
        );
        assert!(matches!(
            parse_route("GET", "/api/v1/sessions", "limit=abc", b""),
            Err(RouteError::BadRequest(_))
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/cluster", "window=-5", b""),
            Err(RouteError::BadRequest(_))
        ));
    }

    #[test]
    fn at_event_wraps_any_query_into_a_scrub_call() {
        assert_eq!(
            parse_route("GET", "/api/v1/status", "at_event=120", b"").unwrap(),
            ApiCall::QueryAt(ApiQuery::Status, 120)
        );
        assert_eq!(
            parse_route("GET", "/api/v1/curves", "limit=2&at_event=7", b"").unwrap(),
            ApiCall::QueryAt(ApiQuery::Curves { limit: 2, offset: 0 }, 7)
        );
        assert!(matches!(
            parse_route("GET", "/api/v1/status", "at_event=nope", b""),
            Err(RouteError::BadRequest(_))
        ));
    }

    #[test]
    fn methods_are_enforced() {
        assert!(matches!(
            parse_route("POST", "/api/v1/status", "", b""),
            Err(RouteError::MethodNotAllowed)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/commands", "", b""),
            Err(RouteError::MethodNotAllowed)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/nope", "", b""),
            Err(RouteError::NotFound)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/studies/a/unknown", "", b""),
            Err(RouteError::NotFound)
        ));
    }

    #[test]
    fn command_bodies_parse() {
        let pause = parse_route(
            "POST",
            "/api/v1/commands",
            "",
            br#"{"command": "pause_session", "study": "alice", "session": "18014398509481985"}"#,
        )
        .unwrap();
        // Session ids round-trip as strings past 2^53.
        assert_eq!(
            pause,
            ApiCall::Command(ApiCommand::PauseSession {
                study: Some("alice".into()),
                session: (1u64 << 54) + 1,
            })
        );
        let quota = parse_route(
            "POST",
            "/api/v1/commands",
            "",
            br#"{"command": "set_quota", "study": "bob", "priority": 2.5}"#,
        )
        .unwrap();
        assert_eq!(
            quota,
            ApiCall::Command(ApiCommand::SetQuota {
                study: "bob".into(),
                quota: None,
                priority: Some(2.5),
            })
        );
        for bad in [
            &b"not json"[..],
            br#"{"command": "warp"}"#,
            br#"{"command": "pause_session"}"#,
            br#"{"command": "set_quota", "study": "x"}"#,
        ] {
            assert!(
                matches!(
                    parse_route("POST", "/api/v1/commands", "", bad),
                    Err(RouteError::BadRequest(_))
                ),
                "{:?} must be a 400",
                String::from_utf8_lossy(bad)
            );
        }
    }

    #[test]
    fn envelope_shape() {
        let e = envelope(u64::MAX, Json::obj().with("x", Json::Num(1.0)));
        let text = e.to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("schema_version").unwrap().as_f64(), Some(1.0));
        // The generation survives as a string even past 2^53.
        assert_eq!(
            back.get("generated_at_event").unwrap().as_str(),
            Some(u64::MAX.to_string().as_str())
        );
        assert_eq!(back.path("data.x").unwrap().as_f64(), Some(1.0));
        let err = error_envelope(None, "nope");
        assert!(err.get("generated_at_event").unwrap().is_null());
        assert_eq!(err.get("error").unwrap().as_str(), Some("nope"));
    }
}
