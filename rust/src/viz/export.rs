//! Session results → JSON export (the contract between the coordinator
//! and any front end; the embedded HTML viewer consumes exactly this).

use crate::config::Order;
use crate::hparam::Space;
use crate::nsml::NsmlSession;
use crate::util::json::Value as Json;

/// Axes + lines document for parallel coordinates (Fig. 3):
/// every axis is a hyperparameter (plus the measure as the last axis);
/// every line is one NSML session.
pub fn parallel_coords_doc(
    space: &Space,
    sessions: &[NsmlSession],
    order: Order,
    run_label: &str,
) -> Json {
    let mut axes: Vec<Json> = space
        .defs
        .iter()
        .map(|d| {
            Json::obj()
                .with("name", Json::Str(d.name.clone()))
                .with("type", Json::Str(d.ptype.name().to_string()))
                .with("distribution", Json::Str(d.dist.name().to_string()))
        })
        .collect();
    axes.push(
        Json::obj()
            .with("name", Json::Str("measure".into()))
            .with("type", Json::Str("float".into()))
            .with("distribution", Json::Str("uniform".into())),
    );

    let lines: Vec<Json> = sessions
        .iter()
        .map(|s| {
            let mut values = Json::obj();
            for (k, v) in s.hparams.iter() {
                values.set(k, v.to_json());
            }
            Json::obj()
                .with("id", Json::Num(s.id.0 as f64))
                .with("values", values)
                .with(
                    "measure",
                    s.best_measure(order).map(Json::Num).unwrap_or(Json::Null),
                )
                .with("status", Json::Str(s.status.name().to_string()))
                .with("epochs", Json::Num(s.epochs as f64))
        })
        .collect();

    Json::obj()
        .with("label", Json::Str(run_label.to_string()))
        .with("axes", Json::Arr(axes))
        .with("lines", Json::Arr(lines))
}

/// Scalar-plot view: loss/measure curves per session ("Scalar plot view").
pub fn curves_doc(sessions: &[NsmlSession]) -> Json {
    let curves: Vec<Json> = sessions
        .iter()
        .map(|s| {
            Json::obj()
                .with("id", Json::Num(s.id.0 as f64))
                .with(
                    "epochs",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.epoch as f64)).collect()),
                )
                .with(
                    "measure",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.measure)).collect()),
                )
                .with(
                    "loss",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.loss)).collect()),
                )
        })
        .collect();
    Json::obj().with("curves", Json::Arr(curves))
}

/// Model summary table rows ("Model summary view"): precise values of the
/// selected sessions.
pub fn summary_doc(sessions: &[&NsmlSession], order: Order) -> Json {
    let rows: Vec<Json> = sessions
        .iter()
        .map(|s| {
            Json::obj()
                .with("id", Json::Num(s.id.0 as f64))
                .with("hparams", s.hparams.to_json())
                .with(
                    "best",
                    s.best_measure(order).map(Json::Num).unwrap_or(Json::Null),
                )
                .with("epochs", Json::Num(s.epochs as f64))
                .with("revivals", Json::Num(s.revivals as f64))
                .with("gpu_seconds", Json::Num(s.gpu_seconds))
        })
        .collect();
    Json::obj().with("rows", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChoptConfig;
    use crate::hparam::{Assignment, Value};
    use crate::nsml::SessionId;

    fn sessions() -> Vec<NsmlSession> {
        (0..3)
            .map(|i| {
                let mut hp = Assignment::new();
                hp.set("lr", Value::Float(0.01 * (i + 1) as f64));
                let mut s = NsmlSession::new(SessionId(i), hp, "m", 0.0);
                s.report(1, 50.0 + i as f64, 2.0);
                s.report(2, 55.0 + i as f64, 1.5);
                s
            })
            .collect()
    }

    #[test]
    fn parallel_doc_shape() {
        let cfg = ChoptConfig::from_json_str(crate::config::LISTING1_EXAMPLE).unwrap();
        let doc = parallel_coords_doc(&cfg.space, &sessions(), Order::Descending, "run-1");
        let axes = doc.get("axes").unwrap().as_arr().unwrap();
        assert_eq!(axes.len(), cfg.space.defs.len() + 1);
        assert_eq!(
            axes.last().unwrap().get("name").unwrap().as_str(),
            Some("measure")
        );
        let lines = doc.get("lines").unwrap().as_arr().unwrap();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].get("measure").unwrap().as_f64(), Some(57.0));
    }

    #[test]
    fn curves_doc_shape() {
        let doc = curves_doc(&sessions());
        let c = doc.get("curves").unwrap().idx(0).unwrap();
        assert_eq!(c.get("epochs").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(c.get("loss").unwrap().idx(1).unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn summary_doc_shape() {
        let ss = sessions();
        let refs: Vec<&NsmlSession> = ss.iter().collect();
        let doc = summary_doc(&refs, Order::Descending);
        assert_eq!(doc.get("rows").unwrap().as_arr().unwrap().len(), 3);
    }
}
