//! Real trainer: drives the AOT PJRT artifacts on synthetic data.
//!
//! One "epoch" = `steps_per_epoch` executions of the variant's
//! `train_step` HLO followed by one `eval_step` on a held-out batch.
//! Model state (params + momentum buffers) lives here per session as
//! host tensors, making PBT's weight copy a `Vec::clone` and dead-pool
//! GC a map removal.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::data::{CifarLike, SquadLike};
use crate::hparam::Assignment;
use crate::nsml::SessionId;
use crate::runtime::{HostTensor, Runtime};

use super::{EpochResult, Trainer};

struct ModelState {
    /// Params followed by velocities, in manifest order.
    state: Vec<HostTensor>,
    epochs: usize,
    steps: u64,
}

/// PJRT-backed trainer.
pub struct RealTrainer {
    rt: Runtime,
    states: HashMap<SessionId, ModelState>,
    ic_data: CifarLike,
    qa_data: SquadLike,
    pub steps_per_epoch: usize,
    pub seed: u64,
    /// Measured wall seconds per (variant) epoch, EMA — used by
    /// `epoch_seconds` so sim-time accounting matches reality.
    measured: HashMap<String, f64>,
}

impl RealTrainer {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>, seed: u64) -> Result<RealTrainer> {
        let rt = Runtime::new(artifacts_dir)?;
        let d = &rt.manifest.data;
        // Noise 1.6 makes the synthetic task hard enough that eval
        // accuracy discriminates hyperparameter configurations instead of
        // saturating at 100%.
        let ic_data = CifarLike::new(d.input_dim, d.classes, 1.6, seed);
        let qa_data = SquadLike::new(d.qa_vocab, d.qa_ctx_len, d.qa_qry_len, seed);
        Ok(RealTrainer {
            rt,
            states: HashMap::new(),
            ic_data,
            qa_data,
            steps_per_epoch: 8,
            seed,
            measured: HashMap::new(),
        })
    }

    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    fn variant<'a>(rt: &'a Runtime, model: &str) -> Result<crate::runtime::VariantSpec> {
        rt.manifest
            .variant(model)
            .cloned()
            .ok_or_else(|| anyhow!("unknown variant '{model}' (run `make artifacts`?)"))
    }

    fn init_state(&mut self, id: SessionId, model: &str) -> Result<()> {
        let v = Self::variant(&self.rt, model)?;
        let seed = (self.seed ^ id.0.wrapping_mul(0x9E37)) as i32 & 0x7FFF_FFFF;
        let out = self
            .rt
            .execute(&v.init, &[HostTensor::scalar_i32(seed)])?;
        self.states.insert(
            id,
            ModelState {
                state: out,
                epochs: 0,
                steps: 0,
            },
        );
        Ok(())
    }

    /// Run one train epoch; returns (mean train loss, mean train measure).
    fn train_epoch(
        &mut self,
        id: SessionId,
        v: &crate::runtime::VariantSpec,
        hp: &Assignment,
    ) -> Result<(f64, f64)> {
        let is_qa = v.task == "question_answering";
        let mut losses = Vec::new();
        let mut measures = Vec::new();
        for _ in 0..self.steps_per_epoch {
            let st = self
                .states
                .get(&id)
                .ok_or_else(|| anyhow!("no state for {id}"))?;
            let step = st.steps;
            let mut inputs: Vec<HostTensor> = Vec::new();
            if is_qa {
                let b = self.qa_data.train_batch(step, self.rt.manifest.data.qa_batch);
                inputs.push(HostTensor::I32(b.ctx, vec![b.batch, b.ctx_len]));
                inputs.push(HostTensor::I32(b.qry, vec![b.batch, b.qry_len]));
                inputs.push(HostTensor::I32(b.y_start, vec![b.batch]));
                inputs.push(HostTensor::I32(b.y_end, vec![b.batch]));
                inputs.push(HostTensor::scalar_f32(hp.f64("lr").unwrap_or(0.05) as f32));
                inputs.push(HostTensor::scalar_f32(
                    hp.f64("momentum").unwrap_or(0.9) as f32
                ));
                inputs.push(HostTensor::scalar_f32(
                    hp.f64("dropout").unwrap_or(0.0) as f32
                ));
                inputs.push(HostTensor::scalar_i32(
                    (step as i32) ^ (self.seed as i32 & 0x7FFF),
                ));
            } else {
                let b = self.ic_data.train_batch(step, self.rt.manifest.data.batch);
                inputs.push(HostTensor::F32(b.x, vec![b.batch, b.input_dim]));
                inputs.push(HostTensor::I32(b.y, vec![b.batch]));
                inputs.push(HostTensor::scalar_f32(hp.f64("lr").unwrap_or(0.05) as f32));
                inputs.push(HostTensor::scalar_f32(
                    hp.f64("momentum").unwrap_or(0.9) as f32
                ));
                inputs.push(HostTensor::scalar_f32(hp.f64("prob").unwrap_or(0.0) as f32));
                inputs.push(HostTensor::scalar_f32(hp.f64("sh").unwrap_or(0.4) as f32));
                inputs.push(HostTensor::scalar_i32(
                    (step as i32) ^ (self.seed as i32 & 0x7FFF),
                ));
            }
            let st = self.states.get(&id).unwrap();
            inputs.extend(st.state.iter().cloned());
            let out = self.rt.execute(&v.train, &inputs)?;
            let loss = out[0].f32_scalar().unwrap_or(f32::NAN) as f64;
            let measure = out[1].f32_scalar().unwrap_or(f32::NAN) as f64;
            losses.push(loss);
            measures.push(measure);
            let st = self.states.get_mut(&id).unwrap();
            st.state = out[2..].to_vec();
            st.steps += 1;
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        Ok((mean(&losses), mean(&measures)))
    }

    /// Evaluate on a held-out batch; returns (loss, measure).
    fn eval(&mut self, id: SessionId, v: &crate::runtime::VariantSpec) -> Result<(f64, f64)> {
        let is_qa = v.task == "question_answering";
        let st = self
            .states
            .get(&id)
            .ok_or_else(|| anyhow!("no state for {id}"))?;
        let n_params = self
            .rt
            .manifest
            .artifact(&v.eval)
            .map(|a| a.inputs.len() - if is_qa { 4 } else { 2 })
            .unwrap_or(st.state.len() / 2);
        let params = st.state[..n_params].to_vec();
        let step = st.epochs as u64;
        let mut inputs: Vec<HostTensor> = Vec::new();
        if is_qa {
            let b = self.qa_data.eval_batch(step, self.rt.manifest.data.qa_batch);
            inputs.push(HostTensor::I32(b.ctx, vec![b.batch, b.ctx_len]));
            inputs.push(HostTensor::I32(b.qry, vec![b.batch, b.qry_len]));
            inputs.push(HostTensor::I32(b.y_start, vec![b.batch]));
            inputs.push(HostTensor::I32(b.y_end, vec![b.batch]));
        } else {
            let b = self.ic_data.eval_batch(step, self.rt.manifest.data.batch);
            inputs.push(HostTensor::F32(b.x, vec![b.batch, b.input_dim]));
            inputs.push(HostTensor::I32(b.y, vec![b.batch]));
        }
        inputs.extend(params);
        let out = self.rt.execute(&v.eval, &inputs)?;
        Ok((
            out[0].f32_scalar().unwrap_or(f32::NAN) as f64,
            out[1].f32_scalar().unwrap_or(f32::NAN) as f64,
        ))
    }
}

impl Trainer for RealTrainer {
    fn train(
        &mut self,
        id: SessionId,
        model: &str,
        hparams: &Assignment,
        to_epoch: usize,
    ) -> Result<EpochResult> {
        let v = Self::variant(&self.rt, model)?;
        if !self.states.contains_key(&id) {
            self.init_state(id, model)?;
        }
        let from = self.states[&id].epochs;
        let mut last = (f64::NAN, f64::NAN);
        for e in from..to_epoch.max(from) {
            let t0 = std::time::Instant::now();
            let (train_loss, _train_measure) = self.train_epoch(id, &v, hparams)?;
            let st = self.states.get_mut(&id).unwrap();
            st.epochs = e + 1;
            let (_eval_loss, eval_measure) = self.eval(id, &v)?;
            last = (eval_measure, train_loss);
            let dt = t0.elapsed().as_secs_f64();
            let slot = self.measured.entry(model.to_string()).or_insert(dt);
            *slot = 0.8 * *slot + 0.2 * dt;
        }
        if to_epoch <= from {
            // No new work: report current eval.
            let (eval_loss, eval_measure) = self.eval(id, &v)?;
            last = (eval_measure, eval_loss);
        }
        Ok(EpochResult {
            // Measure reported as percent to match the surrogate scale.
            measure: last.0 * 100.0,
            loss: last.1,
        })
    }

    fn clone_state(&mut self, src: SessionId, dst: SessionId) -> Result<()> {
        let s = self
            .states
            .get(&src)
            .ok_or_else(|| anyhow!("clone_state: no state for {src}"))?;
        let copied = ModelState {
            state: s.state.clone(),
            epochs: s.epochs,
            steps: s.steps,
        };
        self.states.insert(dst, copied);
        Ok(())
    }

    fn drop_state(&mut self, id: SessionId) {
        self.states.remove(&id);
    }

    fn epochs_done(&self, id: SessionId) -> usize {
        self.states.get(&id).map(|s| s.epochs).unwrap_or(0)
    }

    fn epoch_seconds(&self, model: &str, _hparams: &Assignment) -> f64 {
        self.measured.get(model).copied().unwrap_or(1.0)
    }

    fn param_count(&self, model: &str, _hparams: &Assignment) -> u64 {
        self.rt
            .manifest
            .variant(model)
            .map(|v| v.param_count)
            .unwrap_or(0)
    }

    fn state_count(&self) -> usize {
        self.states.len()
    }
}

// Integration tests for the real trainer live in rust/tests/ (they need
// built artifacts); unit coverage here is limited to pure helpers.
