//! Discrete-event driver: runs CHOPT sessions (agents) + the master agent
//! + the shared cluster to completion in virtual time.
//!
//! This is the composition root for all simulator-backed experiments
//! (Tables 1–4, Figs 2/8/9): benches build a [`SimSetup`], call
//! [`run_sim`], and read the [`SimOutcome`].

use crate::cluster::{Cluster, ExternalLoadTrace};
use crate::config::ChoptConfig;
use crate::events::{EventQueue, SimTime};
use crate::nsml::SessionId;
use crate::trainer::Trainer;

use super::agent::{Agent, ScheduleReq};
use super::election::Election;
use super::master::{master_tick, MasterTickLog, StopAndGoPolicy};
use super::queue::SessionQueue;

/// Simulation events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// A training interval of (agent slot, session) completed.
    Interval { slot: usize, sid: SessionId },
    /// Periodic master-agent control tick.
    MasterTick,
}

/// Everything a simulated run needs.
pub struct SimSetup {
    pub cluster_gpus: usize,
    /// Configs to run; queued FIFO onto `agent_slots` agent slots.
    pub configs: Vec<ChoptConfig>,
    /// Virtual submit time per config (missing entries = 0 — submitted at
    /// simulation start).  Models users starting CHOPT sessions mid-trace.
    pub submit_times: Vec<SimTime>,
    pub agent_slots: usize,
    /// Optional non-CHOPT background load (None = dedicated cluster).
    pub trace: Option<ExternalLoadTrace>,
    pub policy: StopAndGoPolicy,
    /// Master control period in virtual seconds.
    pub master_period: SimTime,
    /// Hard stop for the simulation clock.
    pub horizon: SimTime,
    /// Failure injection: (virtual time, agent slot) pairs — the slot's
    /// agent crashes at that time (GPUs released, CHOPT session aborted),
    /// and if it held master-agent leadership the election fails over.
    pub failures: Vec<(SimTime, usize)>,
}

impl SimSetup {
    pub fn single(config: ChoptConfig, cluster_gpus: usize) -> SimSetup {
        SimSetup {
            cluster_gpus,
            configs: vec![config],
            submit_times: Vec::new(),
            agent_slots: 1,
            trace: None,
            policy: StopAndGoPolicy::default(),
            master_period: 60.0,
            horizon: 400.0 * 24.0 * 3600.0, // 400 virtual days
            failures: Vec::new(),
        }
    }
}

/// Results of a simulated run.
pub struct SimOutcome {
    /// All agents that ran (one per completed/active CHOPT session).
    pub agents: Vec<Agent>,
    pub cluster: Cluster,
    pub master_log: Vec<MasterTickLog>,
    pub election: Election,
    /// Final virtual time.
    pub end_time: SimTime,
    pub events_processed: u64,
}

impl SimOutcome {
    /// Best (agent idx, session, measure) across all agents.
    pub fn best(&self) -> Option<(usize, SessionId, f64)> {
        self.agents
            .iter()
            .enumerate()
            .filter_map(|(i, a)| a.best().map(|(sid, m)| (i, sid, m)))
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Total CHOPT GPU-hours consumed.
    pub fn gpu_hours(&self) -> f64 {
        self.cluster.chopt_gpu_hours(self.end_time)
    }
}

/// Run a simulation to completion (all configs done, or horizon).
///
/// `make_trainer(chopt_session_id)` builds a fresh trainer per CHOPT
/// session (surrogate for sim-scale runs, real PJRT for small ones).
pub fn run_sim(
    setup: SimSetup,
    mut make_trainer: impl FnMut(u64) -> Box<dyn Trainer>,
) -> SimOutcome {
    let mut cluster = Cluster::new(setup.cluster_gpus);
    let mut queue = SessionQueue::new();
    for (i, c) in setup.configs.into_iter().enumerate() {
        let at = setup.submit_times.get(i).copied().unwrap_or(0.0);
        queue.submit(c, at);
    }
    let n_slots = setup.agent_slots.max(1);
    let mut election = Election::new(n_slots);
    // Agent slots: None = idle. Completed agents are moved to `done`.
    let mut slots: Vec<Option<Agent>> = (0..n_slots).map(|_| None).collect();
    let mut done: Vec<Agent> = Vec::new();
    let mut master_log: Vec<MasterTickLog> = Vec::new();
    let mut evq: EventQueue<Ev> = EventQueue::new();
    let mut next_chopt_id: u64 = 0;

    // Helpers -------------------------------------------------------------
    let assign_idle =
        |slots: &mut Vec<Option<Agent>>,
         queue: &mut SessionQueue,
         next_chopt_id: &mut u64,
         make_trainer: &mut dyn FnMut(u64) -> Box<dyn Trainer>,
         cluster: &mut Cluster,
         now: SimTime,
         evq: &mut EventQueue<Ev>| {
            for (slot_idx, slot) in slots.iter_mut().enumerate() {
                if slot.is_none() {
                    if let Some(sub) = queue.pull_ready(now) {
                        *next_chopt_id += 1;
                        let id = *next_chopt_id;
                        let trainer = make_trainer(id);
                        let mut agent = Agent::new(id, sub.config, trainer);
                        let mut reqs: Vec<ScheduleReq> = Vec::new();
                        agent.fill(cluster, now, &mut reqs);
                        for r in reqs {
                            evq.schedule_in(
                                r.seconds,
                                Ev::Interval {
                                    slot: slot_idx,
                                    sid: r.session,
                                },
                            );
                        }
                        *slot = Some(agent);
                    }
                }
            }
        };

    // Bootstrap.
    assign_idle(
        &mut slots,
        &mut queue,
        &mut next_chopt_id,
        &mut make_trainer,
        &mut cluster,
        0.0,
        &mut evq,
    );
    evq.schedule_at(0.0, Ev::MasterTick);

    // Main loop ------------------------------------------------------------
    while let Some((t, ev)) = evq.pop() {
        if t > setup.horizon {
            break;
        }
        match ev {
            Ev::Interval { slot, sid } => {
                if let Some(agent) = slots[slot].as_mut() {
                    let mut reqs: Vec<ScheduleReq> = Vec::new();
                    agent.on_interval_done(sid, &mut cluster, t, &mut reqs);
                    for r in reqs {
                        evq.schedule_in(
                            r.seconds,
                            Ev::Interval {
                                slot,
                                sid: r.session,
                            },
                        );
                    }
                    if agent.finished {
                        done.push(slots[slot].take().unwrap());
                        assign_idle(
                            &mut slots,
                            &mut queue,
                            &mut next_chopt_id,
                            &mut make_trainer,
                            &mut cluster,
                            t,
                            &mut evq,
                        );
                    }
                }
            }
            Ev::MasterTick => {
                // Failure injection: crash scheduled agents first so the
                // election reflects reality before this tick's decisions.
                for &(at, slot_idx) in &setup.failures {
                    if at <= t && slot_idx < slots.len() {
                        if let Some(mut dead) = slots[slot_idx].take() {
                            dead.shutdown("agent_failure", &mut cluster, t);
                            done.push(dead);
                            election.fail(slot_idx);
                        }
                    }
                }
                // The elected leader runs Stop-and-Go (any agent could; the
                // election just decides who — in-process it's the policy
                // call below either way).
                let external = setup
                    .trace
                    .as_ref()
                    .map(|tr| tr.demand(t))
                    .unwrap_or(0);
                let bases: Vec<usize> = slots
                    .iter()
                    .flatten()
                    .filter(|a| !a.finished)
                    .map(|a| a.cfg.max_gpus)
                    .collect();
                let (targets, log) =
                    master_tick(&setup.policy, &mut cluster, external, &bases, t);
                master_log.push(log);
                let mut ti = 0;
                for slot_idx in 0..slots.len() {
                    let Some(agent) = slots[slot_idx].as_mut() else {
                        continue;
                    };
                    if agent.finished {
                        continue;
                    }
                    agent.check_termination(&mut cluster, t);
                    if agent.finished {
                        done.push(slots[slot_idx].take().unwrap());
                        continue;
                    }
                    let target = targets.get(ti).copied().unwrap_or(agent.cfg.max_gpus);
                    ti += 1;
                    let mut reqs: Vec<ScheduleReq> = Vec::new();
                    agent.set_gpu_target(target, &mut cluster, t, &mut reqs);
                    for r in reqs {
                        evq.schedule_in(
                            r.seconds,
                            Ev::Interval {
                                slot: slot_idx,
                                sid: r.session,
                            },
                        );
                    }
                }
                assign_idle(
                    &mut slots,
                    &mut queue,
                    &mut next_chopt_id,
                    &mut make_trainer,
                    &mut cluster,
                    t,
                    &mut evq,
                );
                let any_active = slots.iter().any(|s| s.is_some()) || !queue.is_empty();
                if any_active {
                    evq.schedule_in(setup.master_period, Ev::MasterTick);
                }
            }
        }
        let all_done = slots.iter().all(|s| s.is_none()) && queue.is_empty();
        if all_done {
            break;
        }
    }

    // Keep the elected-master abstraction honest: if slot 0's agent is
    // gone, fail it over (exercised further in tests).
    if slots.first().map(|s| s.is_none()).unwrap_or(false) {
        election.fail(0);
    }

    let end_time = evq.now();
    for slot in slots.iter_mut() {
        if let Some(mut a) = slot.take() {
            a.shutdown("horizon", &mut cluster, end_time);
            done.push(a);
        }
    }
    let events_processed = evq.processed();
    SimOutcome {
        agents: done,
        cluster,
        master_log,
        election,
        end_time,
        events_processed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChoptConfig;
    use crate::trainer::surrogate::SurrogateTrainer;

    fn small_cfg(tune: &str, step: i64, max_sessions: usize) -> ChoptConfig {
        let text = format!(
            r#"{{
              "h_params": {{
                "lr": {{"parameters": [0.01, 0.09], "distribution": "log_uniform",
                        "type": "float", "p_range": [0.001, 0.1]}},
                "momentum": {{"parameters": [0.5, 0.99], "distribution": "uniform",
                        "type": "float", "p_range": [0.1, 0.999]}}
              }},
              "measure": "test/accuracy",
              "order": "descending",
              "step": {step},
              "population": 4,
              "tune": {tune},
              "termination": {{"max_session_number": {max_sessions}}},
              "model": "surrogate:resnet",
              "max_epochs": 50,
              "max_gpus": 4,
              "seed": 11
            }}"#
        );
        ChoptConfig::from_json_str(&text).unwrap()
    }

    #[test]
    fn random_search_runs_to_completion() {
        let cfg = small_cfg("{\"random\": {}}", 10, 12);
        let out = run_sim(SimSetup::single(cfg, 8), |id| {
            Box::new(SurrogateTrainer::new(100 + id))
        });
        assert_eq!(out.agents.len(), 1);
        let a = &out.agents[0];
        assert!(a.finished);
        assert!(a.created >= 12, "created {}", a.created);
        let (_, _, best) = out.best().unwrap();
        assert!(best > 60.0, "best {best}");
        assert!(out.gpu_hours() > 0.0);
        // Pool invariants hold at the end.
        a.pools.check_invariants().unwrap();
    }

    #[test]
    fn pbt_runs_and_mutates() {
        let cfg = small_cfg(
            "{\"pbt\": {\"exploit\": \"truncation\", \"explore\": \"perturb\"}}",
            5,
            16,
        );
        let out = run_sim(SimSetup::single(cfg, 8), |id| {
            Box::new(SurrogateTrainer::new(200 + id))
        });
        let a = &out.agents[0];
        assert!(a.finished);
        let mutations = a
            .events
            .iter()
            .filter(|e| matches!(e, super::super::agent::AgentEvent::Mutated { .. }))
            .count();
        assert!(mutations > 0, "PBT should exploit at least once");
    }

    #[test]
    fn hyperband_completes_brackets() {
        let cfg = small_cfg(
            "{\"hyperband\": {\"max_resource\": 9, \"eta\": 3}}",
            3,
            1000,
        );
        let out = run_sim(SimSetup::single(cfg, 16), |id| {
            Box::new(SurrogateTrainer::new(300 + id))
        });
        let a = &out.agents[0];
        assert!(a.finished, "hyperband session should finish");
        // Hyperband R=9/eta=3 runs 2 brackets: 9+3+1 + 3+... sessions.
        assert!(a.created >= 9, "created {}", a.created);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let cfg = small_cfg("{\"random\": {}}", 10, 8);
            let out = run_sim(SimSetup::single(cfg, 4), |id| {
                Box::new(SurrogateTrainer::new(42 + id))
            });
            (
                out.best().map(|(_, _, m)| m),
                out.end_time,
                out.events_processed,
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn gpu_cap_respected() {
        let cfg = small_cfg("{\"random\": {}}", 5, 10);
        let out = run_sim(SimSetup::single(cfg, 2), |id| {
            Box::new(SurrogateTrainer::new(id))
        });
        // Peak CHOPT usage never exceeded the 2-GPU cluster.
        let peak = out
            .cluster
            .usage_chopt
            .series
            .iter()
            .map(|&(_, v)| v)
            .fold(0.0, f64::max);
        assert!(peak <= 2.0, "peak {peak}");
    }
}
