//! The CHOPT coordinator (paper §3.2–3.3) — the system contribution.
//!
//! * [`queue::SessionQueue`] — submitted CHOPT sessions wait for an agent.
//! * [`agent::Agent`] — runs one CHOPT session: tuner + trainer + the
//!   live/stop/dead pools, with `stop_ratio` routing on exit.
//! * [`election::Election`] — zookeeper-style master-agent failover.
//! * [`master`] — the Stop-and-Go policy: shift GPUs between CHOPT and
//!   non-CHOPT tenants by cluster utilization.
//! * [`engine`] — the re-entrant discrete-event state machine: `step` /
//!   `run_until` / online `submit` / snapshot-and-restore.
//! * [`platform`] — the live layer over an engine: structured progress
//!   events, periodic snapshots, and the view documents `serve --live`
//!   republishes.
//! * [`scheduler`] — the multi-tenant study scheduler: N studies (each
//!   its own config/tuner/RNG/pools) on one shared cluster with
//!   fair-share quotas and cross-study Stop-and-Go (pause-preemption of
//!   borrowers).
//! * [`driver`] — the batch wrapper ([`run_sim`]) used by every
//!   simulator-backed experiment.

pub mod agent;
pub mod driver;
pub mod election;
pub mod engine;
pub mod master;
pub mod platform;
pub mod pools;
pub mod queue;
pub mod scheduler;

pub use agent::{Agent, AgentEvent, ScheduleReq};
pub use driver::{run_sim, SimOutcome, SimSetup};
pub use election::Election;
pub use engine::{SimEngine, Step};
pub use master::{master_tick, MasterTickLog, StopAndGoPolicy};
pub use platform::{MultiPlatform, Platform};
pub use pools::{Pool, Pools};
pub use queue::{SessionQueue, Submission};
pub use scheduler::{
    MultiOutcome, StudyManifest, StudyResult, StudyScheduler, StudySpec, StudyState,
};
