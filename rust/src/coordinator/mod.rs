//! The CHOPT coordinator (paper §3.2–3.3) — the system contribution.
//!
//! * [`queue::SessionQueue`] — submitted CHOPT sessions wait for an agent.
//! * [`agent::Agent`] — runs one CHOPT session: tuner + trainer + the
//!   live/stop/dead pools, with `stop_ratio` routing on exit.
//! * [`election::Election`] — zookeeper-style master-agent failover.
//! * [`master`] — the Stop-and-Go policy: shift GPUs between CHOPT and
//!   non-CHOPT tenants by cluster utilization.
//! * [`driver`] — the discrete-event composition root used by every
//!   simulator-backed experiment.

pub mod agent;
pub mod driver;
pub mod election;
pub mod master;
pub mod pools;
pub mod queue;

pub use agent::{Agent, AgentEvent, ScheduleReq};
pub use driver::{run_sim, SimOutcome, SimSetup};
pub use election::Election;
pub use master::{master_tick, MasterTickLog, StopAndGoPolicy};
pub use pools::{Pool, Pools};
pub use queue::{SessionQueue, Submission};
