//! The live CHOPT platform: a long-lived coordinator wrapped around a
//! [`SimEngine`] (paper §3, §3.5).
//!
//! Where the engine is a pure state machine, the platform owns the
//! *observable* side of a run:
//!
//! * a structured progress stream — every agent pool transition
//!   (launch/early-stop/preempt/revive/mutate/evict/finish) is appended to
//!   a JSONL [`EventLog`] as it happens,
//! * periodic JSON snapshots of the engine (`snapshot.json`) from which a
//!   run can be **restored** and continued ([`Platform::restore`]),
//! * live view documents (leaderboard, sessions, parallel coordinates,
//!   cluster utilization, status) that `chopt serve --live` republishes to
//!   the viz HTTP server as the engine advances, and
//! * online [`Platform::submit`] — users joining the shared cluster while
//!   other sessions are mid-flight.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::ChoptConfig;
use crate::events::SimTime;
use crate::nsml::NsmlSession;
use crate::storage::{EventLog, SessionStore};
use crate::trainer::Trainer;
use crate::util::json::Value as Json;
use crate::viz::export;

use super::agent::AgentEvent;
use super::driver::{SimOutcome, SimSetup};
use super::engine::SimEngine;

/// A live run: engine + event log + snapshot cadence + view builders.
pub struct Platform<'t> {
    engine: SimEngine<'t>,
    event_log: Option<EventLog>,
    /// Per-agent count of [`AgentEvent`]s already drained to the log.
    cursors: HashMap<u64, usize>,
    snapshot_path: Option<PathBuf>,
    /// Virtual seconds between automatic snapshots.
    snapshot_every: SimTime,
    last_snapshot_t: SimTime,
    /// Done agents drained to completion — their event vectors can never
    /// grow again, so drains skip them (keeps the per-event drain in
    /// `drive_until` bounded by the active agent count, not run history).
    done_drained: usize,
    /// Progress events emitted over the platform's lifetime.
    pub progress_events: u64,
}

impl<'t> Platform<'t> {
    pub fn new(
        setup: SimSetup,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> Platform<'t> {
        Platform::from_engine(SimEngine::new(setup, make_trainer))
    }

    pub fn from_engine(engine: SimEngine<'t>) -> Platform<'t> {
        Platform {
            engine,
            event_log: None,
            cursors: HashMap::new(),
            snapshot_path: None,
            snapshot_every: 3600.0,
            last_snapshot_t: 0.0,
            done_drained: 0,
            progress_events: 0,
        }
    }

    /// Append structured progress events to a JSONL log at `path`.
    pub fn with_event_log(mut self, path: impl AsRef<Path>) -> std::io::Result<Platform<'t>> {
        self.event_log = Some(EventLog::open(path)?);
        Ok(self)
    }

    /// Write an engine snapshot to `path` every `every` virtual seconds
    /// (and once more at completion).
    pub fn with_snapshots(mut self, path: impl AsRef<Path>, every: SimTime) -> Platform<'t> {
        self.snapshot_path = Some(path.as_ref().to_path_buf());
        self.snapshot_every = every.max(1.0);
        self
    }

    pub fn engine(&self) -> &SimEngine<'t> {
        &self.engine
    }

    pub fn is_done(&self) -> bool {
        self.engine.is_done()
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    /// Submit a new CHOPT session to the live run (clamped to now).
    /// Returns `None` if the engine's horizon has already been reached.
    pub fn submit(&mut self, config: ChoptConfig, at: SimTime) -> Option<SimTime> {
        let at = self.engine.submit(config, at)?;
        self.log_json(
            Json::obj()
                .with("t", Json::Num(self.engine.now()))
                .with("ev", Json::Str("submitted".into()))
                .with("at", Json::Num(at)),
        );
        Some(at)
    }

    /// Advance the engine by `dt` virtual seconds, then drain progress
    /// events and maybe snapshot.  Returns events processed.  If the
    /// window is an idle gap (no event within `dt`), one event past the
    /// gap is processed so callers looping on `advance` always progress;
    /// a return of 0 therefore means the run is over.
    pub fn advance(&mut self, dt: SimTime) -> u64 {
        let mut n = self.drive_until(self.engine.now() + dt);
        if n == 0
            && !self.engine.is_done()
            && matches!(self.engine.step(), super::engine::Step::Advanced(_))
        {
            n += 1;
            self.drain_progress();
        }
        self.after_advance();
        n
    }

    /// Advance the engine to virtual time `t` (strict bound — see
    /// [`SimEngine::run_until`]).
    pub fn run_until(&mut self, t: SimTime) -> u64 {
        let n = self.drive_until(t);
        self.after_advance();
        n
    }

    /// Engine `run_until`, but when an event log is attached the progress
    /// stream is drained after *every* event so each JSONL record carries
    /// the virtual time the pool transition actually happened (not the
    /// advance-chunk boundary).
    fn drive_until(&mut self, t: SimTime) -> u64 {
        if self.event_log.is_none() {
            return self.engine.run_until(t);
        }
        let mut n = 0;
        while !self.engine.is_done() {
            match self.engine.next_event_time() {
                Some(next) if next <= t => {
                    if !matches!(self.engine.step(), super::engine::Step::Advanced(_)) {
                        break;
                    }
                    n += 1;
                    self.drain_progress();
                }
                _ => break,
            }
        }
        n
    }

    /// Drive the run to completion in `chunk`-sized virtual-time slices so
    /// progress/snapshot cadence is honored throughout.
    pub fn run_to_completion(&mut self, chunk: SimTime) -> u64 {
        let chunk = chunk.max(1.0);
        let mut n = 0;
        loop {
            let stepped = self.advance(chunk);
            n += stepped;
            if self.engine.is_done() || stepped == 0 {
                break;
            }
        }
        if self.snapshot_path.is_some() {
            let _ = self.snapshot_now();
        }
        n
    }

    /// Consume the platform into the batch outcome.  The engine's final
    /// shutdown can itself emit transitions (`Terminated("horizon")` on
    /// still-active agents), so those are drained from the outcome into
    /// the event log before it is handed back.
    pub fn into_outcome(mut self) -> SimOutcome {
        self.after_advance();
        let outcome = self.engine.into_outcome();
        let now = outcome.end_time;
        for agent in &outcome.agents {
            let seen = self.cursors.get(&agent.id).copied().unwrap_or(0);
            for ev in &agent.events[seen..] {
                self.progress_events += 1;
                if let Some(log) = &mut self.event_log {
                    let _ = log.append(&agent_event_json(agent.id, ev, now));
                }
            }
        }
        if let Some(log) = &mut self.event_log {
            let _ = log.flush();
        }
        outcome
    }

    // -- progress stream ---------------------------------------------------

    fn after_advance(&mut self) {
        self.drain_progress();
        if let Some(log) = &mut self.event_log {
            let _ = log.flush();
        }
        self.maybe_snapshot();
    }

    /// Append agent events that occurred since the last drain to the
    /// event log (one JSON object per pool transition).  When called once
    /// per engine step (see [`Platform::drive_until`]) `engine.now()` is
    /// exactly the virtual time the transitions happened.
    fn drain_progress(&mut self) {
        let now = self.engine.now();
        let mut fresh: Vec<Json> = Vec::new();
        // Newly-completed agents get one final drain; long-done ones are
        // skipped (their event vectors are immutable).
        let done = self.engine.done_agents();
        let newly_done = &done[self.done_drained.min(done.len())..];
        for agent in newly_done.iter().chain(self.engine.active_agents()) {
            let seen = self.cursors.get(&agent.id).copied().unwrap_or(0);
            for ev in &agent.events[seen..] {
                fresh.push(agent_event_json(agent.id, ev, now));
            }
            self.cursors.insert(agent.id, agent.events.len());
        }
        self.done_drained = done.len();
        self.progress_events += fresh.len() as u64;
        for doc in fresh {
            self.log_json(doc);
        }
    }

    fn log_json(&mut self, doc: Json) {
        if let Some(log) = &mut self.event_log {
            let _ = log.append(&doc);
        }
    }

    fn maybe_snapshot(&mut self) {
        if self.snapshot_path.is_none() {
            return;
        }
        let now = self.engine.now();
        if now - self.last_snapshot_t >= self.snapshot_every {
            let _ = self.snapshot_now();
        }
    }

    /// Write (and return) a snapshot right now.
    pub fn snapshot_now(&mut self) -> std::io::Result<Json> {
        let doc = self.engine.snapshot_json();
        if let Some(path) = &self.snapshot_path {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, doc.to_string_pretty())?;
        }
        self.last_snapshot_t = self.engine.now();
        Ok(doc)
    }

    /// Rebuild a platform from a snapshot file written by
    /// [`Platform::snapshot_now`].  `make_trainer` must be the factory the
    /// original run used (state is reproduced by deterministic replay).
    pub fn restore(
        path: impl AsRef<Path>,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> anyhow::Result<Platform<'t>> {
        let text = std::fs::read_to_string(path)?;
        let doc = crate::util::json::parse(&text)?;
        let engine = SimEngine::restore(&doc, make_trainer)?;
        let mut platform = Platform::from_engine(engine);
        // Events up to the snapshot were already logged by the original
        // run; start the cursors at the replayed state so a reattached
        // log only receives new transitions.
        for agent in platform.engine.all_agents() {
            platform.cursors.insert(agent.id, agent.events.len());
        }
        platform.done_drained = platform.engine.done_agents().len();
        platform.last_snapshot_t = platform.engine.now();
        Ok(platform)
    }

    // -- live views --------------------------------------------------------

    /// All NSML sessions across all agents, done agents first.
    pub fn sessions(&self) -> Vec<NsmlSession> {
        let mut out = Vec::new();
        for agent in self.engine.all_agents() {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            out.extend(ss.into_iter().cloned());
        }
        out
    }

    /// Live leaderboard rows (top `k` across every agent's sessions).
    pub fn leaderboard_doc(&self, k: usize) -> Json {
        let mut rows: Vec<Json> = Vec::new();
        for agent in self.engine.all_agents() {
            let order = agent.cfg.order;
            for &(sid, best) in agent.leaderboard.top(k) {
                let s = &agent.sessions[&sid];
                rows.push(
                    Json::obj()
                        .with("chopt", Json::Num(agent.id as f64))
                        .with("session", Json::Num(sid.0 as f64))
                        .with("best", Json::Num(best))
                        .with("epochs", Json::Num(s.epochs as f64))
                        .with("status", Json::Str(s.status.name().to_string()))
                        .with("order", Json::Str(order.name().to_string())),
                );
            }
        }
        // Cross-agent merge: best first under the first agent's order
        // (platform runs share a measure in practice).  NaN-safe.
        let descending = self.order() == crate::config::Order::Descending;
        rows.sort_by(|a, b| {
            let ma = a.get("best").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let mb = b.get("best").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            // NaN rows sink to the bottom regardless of order direction.
            match (ma.is_nan(), mb.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (false, false) if descending => mb.total_cmp(&ma),
                (false, false) => ma.total_cmp(&mb),
            }
        });
        rows.truncate(k);
        Json::obj()
            .with("t", Json::Num(self.engine.now()))
            .with("rows", Json::Arr(rows))
    }

    /// Sessions document in the `SessionStore` format `chopt serve` uses.
    pub fn sessions_doc(&self) -> Json {
        let mut store = SessionStore::new();
        for agent in self.engine.all_agents() {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            store.put_run(
                &format!("chopt-{}", agent.id),
                ss.into_iter().cloned().collect(),
            );
        }
        store.to_json()
    }

    /// The run's measure order (first agent's; platform runs share one).
    pub fn order(&self) -> crate::config::Order {
        self.engine
            .all_agents()
            .next()
            .map(|a| a.cfg.order)
            .unwrap_or(crate::config::Order::Descending)
    }

    /// Parallel-coordinates document over all sessions (axes from `space`).
    pub fn parallel_doc(&self, space: &crate::hparam::Space) -> Json {
        self.parallel_doc_from(space, &self.sessions())
    }

    /// Same, over a caller-held session list — lets a publish loop collect
    /// [`Platform::sessions`] once instead of deep-cloning per document.
    pub fn parallel_doc_from(
        &self,
        space: &crate::hparam::Space,
        sessions: &[NsmlSession],
    ) -> Json {
        export::parallel_coords_doc(space, sessions, self.order(), "live")
    }

    /// Cluster utilization view (live Fig. 8).
    pub fn cluster_doc(&self) -> Json {
        export::cluster_doc(self.engine.cluster(), self.engine.now())
    }

    /// One-object run status (the `/api/status.json` heartbeat).
    pub fn status_doc(&self) -> Json {
        let engine = &self.engine;
        let (live, stop, dead) = engine.active_agents().fold((0, 0, 0), |acc, a| {
            (
                acc.0 + a.pools.live_count(),
                acc.1 + a.pools.stop_count(),
                acc.2 + a.pools.dead_count(),
            )
        });
        Json::obj()
            .with("t", Json::Num(engine.now()))
            .with("events_processed", Json::Num(engine.events_processed() as f64))
            .with("done", Json::Bool(engine.is_done()))
            .with("queue_len", Json::Num(engine.queue_len() as f64))
            .with("active_agents", Json::Num(engine.active_agents().count() as f64))
            .with("done_agents", Json::Num(engine.done_agents().len() as f64))
            .with("pool_live", Json::Num(live as f64))
            .with("pool_stop", Json::Num(stop as f64))
            .with("pool_dead", Json::Num(dead as f64))
            .with(
                "best",
                engine
                    .best()
                    .map(|(_, _, m)| Json::Num(m))
                    .unwrap_or(Json::Null),
            )
            .with(
                "utilization",
                Json::Num(engine.cluster().utilization()),
            )
            .with("election_term", Json::Num(engine.election().term() as f64))
            .with("progress_events", Json::Num(self.progress_events as f64))
    }
}

/// One pool transition as a structured JSONL record.
fn agent_event_json(agent_id: u64, ev: &AgentEvent, now: SimTime) -> Json {
    let base = |name: &str| {
        Json::obj()
            .with("t", Json::Num(now))
            .with("chopt", Json::Num(agent_id as f64))
            .with("ev", Json::Str(name.to_string()))
    };
    match ev {
        AgentEvent::Launched(sid) => base("launched").with("session", Json::Num(sid.0 as f64)),
        AgentEvent::Revived(sid) => base("revived").with("session", Json::Num(sid.0 as f64)),
        AgentEvent::EarlyStopped(sid, pool) => base("early_stopped")
            .with("session", Json::Num(sid.0 as f64))
            .with("pool", Json::Str(format!("{pool:?}").to_lowercase())),
        AgentEvent::Preempted(sid, pool) => base("preempted")
            .with("session", Json::Num(sid.0 as f64))
            .with("pool", Json::Str(format!("{pool:?}").to_lowercase())),
        AgentEvent::Finished(sid) => base("finished").with("session", Json::Num(sid.0 as f64)),
        AgentEvent::Mutated { victim, source } => base("mutated")
            .with("session", Json::Num(victim.0 as f64))
            .with("source", Json::Num(source.0 as f64)),
        AgentEvent::Evicted(sid) => base("evicted").with("session", Json::Num(sid.0 as f64)),
        AgentEvent::Terminated(reason) => {
            base("terminated").with("reason", Json::Str(reason.to_string()))
        }
    }
}
