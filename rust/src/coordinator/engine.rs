//! Re-entrant discrete-event engine: the stateful core behind [`run_sim`].
//!
//! The original driver was a closed-world function — it consumed a
//! [`SimSetup`] and returned only after every session finished, so nothing
//! could observe a run in flight.  `SimEngine` lifts all loop state (event
//! queue, agent slots, done list, cluster, election, session queue, master
//! log, failure schedule) into struct fields and exposes incremental
//! drivers:
//!
//! * [`SimEngine::step`] — process exactly one event,
//! * [`SimEngine::run_until`] — advance virtual time to a bound,
//! * [`SimEngine::run_to_completion`] — the old batch behavior,
//! * [`SimEngine::submit`] — accept a *new* CHOPT session while running
//!   (the paper's platform story: users join a shared cluster any time),
//! * [`SimEngine::snapshot_json`] / [`SimEngine::restore`] — persist a run
//!   as JSON and rebuild it deterministically by replay.
//!
//! [`run_sim`] is now a thin wrapper: `new` → `run_to_completion` →
//! `into_outcome`, so every existing bench/test drives this engine.
//!
//! Determinism contract: given the same [`SimSetup`], the same trainer
//! factory, and the same `submit` calls (config + effective time), the
//! engine pops the identical event sequence regardless of how the run is
//! sliced into `step`/`run_until` calls.  Restore replays the recorded
//! inputs up to the snapshot's `events_processed` count, which reproduces
//! the exact engine state.
//!
//! [`run_sim`]: super::driver::run_sim

use crate::cluster::Cluster;
use crate::config::ChoptConfig;
use crate::events::{DirtySet, EventQueue, SimTime};
use crate::nsml::SessionId;
use crate::trainer::Trainer;
use crate::util::json::Value as Json;

use super::agent::{Agent, ScheduleReq};
use super::driver::{SimOutcome, SimSetup};
use super::election::Election;
use super::master::{master_tick, MasterTickLog};
use super::queue::SessionQueue;

/// Simulation events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// A training interval of (agent slot, session) completed.
    Interval { slot: usize, sid: SessionId },
    /// Periodic master-agent control tick.
    MasterTick,
    /// An online submission (index into `SimEngine::online`) arrives.
    Submit { idx: usize },
}

/// A failure-injection record.  `consumed` guards against the stale-failure
/// bug the batch driver had: without it, every master tick re-applied all
/// past failures, instantly crashing any fresh agent later assigned to the
/// same slot.
#[derive(Debug, Clone, Copy)]
struct Failure {
    at: SimTime,
    slot: usize,
    consumed: bool,
}

/// A CHOPT session submitted while the engine was live (vs. the setup's
/// initial batch).  Kept for snapshot/replay: `after_events` records how
/// many events the engine had processed when `submit` was called, so a
/// restore re-issues the submit at the same point — reproducing the exact
/// event-queue sequence numbers and therefore identical same-timestamp
/// tie-breaking.
#[derive(Debug, Clone)]
struct OnlineSubmission {
    config: ChoptConfig,
    at: SimTime,
    after_events: u64,
}

/// What one [`SimEngine::step`] call did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Step {
    /// Processed one event at this virtual time.
    Advanced(SimTime),
    /// Popped an event past the horizon; the engine halted.
    HorizonReached,
    /// Nothing to do (completed, horizon already reached, or queue empty).
    Idle,
}

/// The re-entrant simulation engine.  See the module docs.
pub struct SimEngine<'t> {
    cluster: Cluster,
    queue: SessionQueue,
    election: Election,
    /// Agent slots: `None` = idle.  Completed agents move to `done`.
    slots: Vec<Option<Agent>>,
    done: Vec<Agent>,
    master_log: Vec<MasterTickLog>,
    evq: EventQueue<Ev>,
    next_chopt_id: u64,
    /// The original inputs, retained whole: runtime parameters (policy,
    /// trace, periods) are read from here, and snapshots serialize it via
    /// [`SimSetup::to_json`] so the two encodings cannot drift.
    setup: SimSetup,
    /// Consumable runtime view of `setup.failures`.
    failures: Vec<Failure>,
    make_trainer: Box<dyn FnMut(u64) -> Box<dyn Trainer> + 't>,
    /// Online submissions in arrival order (snapshot/replay input).
    online: Vec<OnlineSubmission>,
    /// Scheduled-but-unprocessed `Ev::Submit` events.
    submits_pending: usize,
    /// Scheduled-but-unprocessed `Ev::MasterTick` events; when the chain
    /// dies (everything drained) a later submit re-arms it.
    ticks_pending: usize,
    /// All work drained (slots empty, queue empty, no pending submits).
    completed: bool,
    horizon_reached: bool,
    /// Slots whose agents may have appended [`super::agent::AgentEvent`]s
    /// since the last [`SimEngine::take_dirty_slots`] — lets the
    /// platform's progress drain visit only touched agents instead of
    /// scanning every slot after every processed event.
    dirty: DirtySet,
}

impl<'t> SimEngine<'t> {
    /// Build an engine from a setup: queue the initial submissions, fill
    /// idle slots at t=0, and arm the master-tick chain — exactly the
    /// bootstrap the batch driver performed.
    pub fn new(
        setup: SimSetup,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> SimEngine<'t> {
        let mut queue = SessionQueue::new();
        for (i, c) in setup.configs.iter().enumerate() {
            let at = setup.submit_times.get(i).copied().unwrap_or(0.0);
            queue.submit(c.clone(), at);
        }
        let n_slots = setup.agent_slots.max(1);
        let mut engine = SimEngine {
            cluster: Cluster::new(setup.cluster_gpus),
            queue,
            election: Election::new(n_slots),
            slots: (0..n_slots).map(|_| None).collect(),
            done: Vec::new(),
            master_log: Vec::new(),
            evq: EventQueue::new(),
            next_chopt_id: 0,
            failures: setup
                .failures
                .iter()
                .map(|&(at, slot)| Failure {
                    at,
                    slot,
                    consumed: false,
                })
                .collect(),
            setup,
            make_trainer: Box::new(make_trainer),
            online: Vec::new(),
            submits_pending: 0,
            ticks_pending: 0,
            completed: false,
            horizon_reached: false,
            dirty: DirtySet::with_len(n_slots),
        };
        engine.assign_idle(0.0);
        engine.evq.schedule_at(0.0, Ev::MasterTick);
        engine.ticks_pending += 1;
        engine
    }

    // -- observability -----------------------------------------------------

    /// Current virtual time (time of the last processed event).
    pub fn now(&self) -> SimTime {
        self.evq.now()
    }

    /// Number of events popped so far.
    pub fn events_processed(&self) -> u64 {
        self.evq.processed()
    }

    /// All work drained and no online submissions pending.
    pub fn is_done(&self) -> bool {
        self.completed || self.horizon_reached || self.evq.is_empty()
    }

    pub fn horizon_reached(&self) -> bool {
        self.horizon_reached
    }

    /// Queued (not yet assigned) CHOPT sessions.
    pub fn queue_len(&self) -> usize {
        self.queue.len() + self.submits_pending
    }

    /// Virtual time of the next pending event, if any.
    pub fn next_event_time(&self) -> Option<SimTime> {
        self.evq.peek_time()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn election(&self) -> &Election {
        &self.election
    }

    pub fn master_log(&self) -> &[MasterTickLog] {
        &self.master_log
    }

    /// Agents whose CHOPT sessions completed (or crashed).
    pub fn done_agents(&self) -> &[Agent] {
        &self.done
    }

    /// Agents currently occupying a slot.
    pub fn active_agents(&self) -> impl Iterator<Item = &Agent> {
        self.slots.iter().flatten()
    }

    /// Agent currently occupying `slot`, if any.
    pub fn agent_at(&self, slot: usize) -> Option<&Agent> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Drain the list of slots touched since the last call (progress-
    /// drain bookkeeping; see the `dirty` field).  Agents that moved to
    /// `done` are *not* listed — the platform tracks those through
    /// [`SimEngine::done_agents`] growth instead.
    pub fn take_dirty_slots(&mut self) -> Vec<usize> {
        self.dirty.take()
    }

    fn mark_dirty(&mut self, slot: usize) {
        self.dirty.mark(slot);
    }

    /// Every agent the engine ever created: completed first, then active.
    pub fn all_agents(&self) -> impl Iterator<Item = &Agent> {
        self.done.iter().chain(self.slots.iter().flatten())
    }

    /// Best (chopt id, session, measure) across all agents so far
    /// (NaN-safe — see [`super::driver::best_of`]).
    pub fn best(&self) -> Option<(u64, SessionId, f64)> {
        super::driver::best_of(self.all_agents().map(|a| (a.id, a)))
    }

    // -- drivers -----------------------------------------------------------

    /// Process exactly one event.
    pub fn step(&mut self) -> Step {
        if self.completed || self.horizon_reached {
            return Step::Idle;
        }
        let Some((t, ev)) = self.evq.pop() else {
            self.completed = true;
            return Step::Idle;
        };
        if t > self.setup.horizon {
            self.horizon_reached = true;
            return Step::HorizonReached;
        }
        self.dispatch(t, ev);
        if self.all_done() {
            self.completed = true;
        }
        Step::Advanced(t)
    }

    /// Process every event with timestamp `<= t`.  Returns the number of
    /// events processed.  Re-entrant: `run_until(a); run_until(b)` pops the
    /// same sequence as a single uninterrupted run.
    pub fn run_until(&mut self, t: SimTime) -> u64 {
        let mut n = 0;
        while !self.completed && !self.horizon_reached {
            match self.evq.peek_time() {
                Some(next) if next <= t => {
                    if !matches!(self.step(), Step::Advanced(_)) {
                        break;
                    }
                    n += 1;
                }
                _ => break,
            }
        }
        n
    }

    /// Drive until all sessions finish (or the horizon passes) — the
    /// original batch semantics.
    pub fn run_to_completion(&mut self) -> u64 {
        let mut n = 0;
        while matches!(self.step(), Step::Advanced(_)) {
            n += 1;
        }
        n
    }

    /// Submit a new CHOPT session while the engine is live.  `at` is
    /// clamped to the current virtual time; returns the effective submit
    /// time.  If the engine had already drained, the master-tick chain is
    /// re-armed so the new session gets scheduled.  Returns `None` once
    /// the horizon has been reached — the clock cannot advance past it,
    /// so the submission would silently never run.
    pub fn submit(&mut self, config: ChoptConfig, at: SimTime) -> Option<SimTime> {
        if self.horizon_reached {
            return None;
        }
        let at = at.max(self.evq.now());
        let idx = self.online.len();
        self.online.push(OnlineSubmission {
            config,
            at,
            after_events: self.evq.processed(),
        });
        self.evq.schedule_at(at, Ev::Submit { idx });
        self.submits_pending += 1;
        self.completed = false;
        Some(at)
    }

    // -- event dispatch ----------------------------------------------------

    fn all_done(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
            && self.queue.is_empty()
            && self.submits_pending == 0
    }

    fn schedule_reqs(&mut self, slot: usize, reqs: Vec<ScheduleReq>) {
        for r in reqs {
            self.evq.schedule_in(
                r.seconds,
                Ev::Interval {
                    slot,
                    sid: r.session,
                },
            );
        }
    }

    /// Fill idle slots from the session queue (same policy as the batch
    /// driver: FIFO, first idle slot wins).
    fn assign_idle(&mut self, now: SimTime) {
        for slot_idx in 0..self.slots.len() {
            if self.slots[slot_idx].is_none() {
                if let Some(sub) = self.queue.pull_ready(now) {
                    self.next_chopt_id += 1;
                    let id = self.next_chopt_id;
                    let trainer = (self.make_trainer)(id);
                    let mut agent = Agent::new(id, sub.config, trainer);
                    let mut reqs: Vec<ScheduleReq> = Vec::new();
                    agent.fill(&mut self.cluster, now, &mut reqs);
                    self.slots[slot_idx] = Some(agent);
                    self.mark_dirty(slot_idx);
                    self.schedule_reqs(slot_idx, reqs);
                }
            }
        }
    }

    fn dispatch(&mut self, t: SimTime, ev: Ev) {
        match ev {
            Ev::Interval { slot, sid } => self.on_interval(t, slot, sid),
            Ev::MasterTick => self.on_master_tick(t),
            Ev::Submit { idx } => self.on_submit(t, idx),
        }
    }

    fn on_interval(&mut self, t: SimTime, slot: usize, sid: SessionId) {
        if self.slots[slot].is_none() {
            return; // stale event: the slot's agent crashed or finished
        }
        self.mark_dirty(slot);
        let agent = self.slots[slot].as_mut().unwrap();
        let mut reqs: Vec<ScheduleReq> = Vec::new();
        agent.on_interval_done(sid, &mut self.cluster, t, &mut reqs);
        let finished = agent.finished;
        self.schedule_reqs(slot, reqs);
        if finished {
            self.done.push(self.slots[slot].take().unwrap());
            self.assign_idle(t);
        }
    }

    fn on_master_tick(&mut self, t: SimTime) {
        self.ticks_pending = self.ticks_pending.saturating_sub(1);
        // Failure injection: crash scheduled agents first so the election
        // reflects reality before this tick's decisions.  Each failure
        // fires exactly once (consumed), so an agent later assigned to the
        // same slot is not crashed by a stale record.
        for i in 0..self.failures.len() {
            let Failure { at, slot, consumed } = self.failures[i];
            if !consumed && at <= t {
                self.failures[i].consumed = true;
                if slot < self.slots.len() {
                    if let Some(mut dead) = self.slots[slot].take() {
                        dead.shutdown("agent_failure", &mut self.cluster, t);
                        self.done.push(dead);
                        self.election.fail(slot);
                    }
                }
            }
        }
        // The elected leader runs Stop-and-Go (any agent could; the
        // election just decides who — in-process it's the policy call
        // below either way).
        let external = self.setup.trace.as_ref().map(|tr| tr.demand(t)).unwrap_or(0);
        // Record *which slot* produced each `bases` entry, so each agent
        // reads its own target even if an earlier agent terminates during
        // the loop below.  (The batch driver kept a running index that
        // skipped terminated agents without consuming their target slot,
        // shifting every later agent onto its neighbor's target.)
        let active: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].as_ref().map(|a| !a.finished).unwrap_or(false))
            .collect();
        let bases: Vec<usize> = active
            .iter()
            .map(|&i| self.slots[i].as_ref().unwrap().cfg.max_gpus)
            .collect();
        let (targets, log) =
            master_tick(&self.setup.policy, &mut self.cluster, external, &bases, t);
        self.master_log.push(log);
        for (ti, &slot_idx) in active.iter().enumerate() {
            if self.slots[slot_idx].is_none() {
                continue;
            }
            self.mark_dirty(slot_idx);
            let agent = self.slots[slot_idx].as_mut().unwrap();
            agent.check_termination(&mut self.cluster, t);
            if agent.finished {
                self.done.push(self.slots[slot_idx].take().unwrap());
                continue;
            }
            let target = targets.get(ti).copied().unwrap_or(agent.cfg.max_gpus);
            let mut reqs: Vec<ScheduleReq> = Vec::new();
            agent.set_gpu_target(target, &mut self.cluster, t, &mut reqs);
            self.schedule_reqs(slot_idx, reqs);
        }
        self.assign_idle(t);
        let any_active = self.slots.iter().any(|s| s.is_some()) || !self.queue.is_empty();
        if any_active {
            self.evq.schedule_in(self.setup.master_period, Ev::MasterTick);
            self.ticks_pending += 1;
        }
    }

    fn on_submit(&mut self, t: SimTime, idx: usize) {
        self.submits_pending = self.submits_pending.saturating_sub(1);
        let config = self.online[idx].config.clone();
        self.queue.submit(config, t);
        // Re-arm the master-tick chain if it died (engine had drained);
        // the tick at `t` assigns the new session and resumes the cadence.
        if self.ticks_pending == 0 {
            self.evq.schedule_at(t, Ev::MasterTick);
            self.ticks_pending += 1;
        }
    }

    // -- finalization ------------------------------------------------------

    /// Consume the engine into the batch outcome: shut down any agents
    /// still running (horizon semantics) and fail slot 0's election entry
    /// if it is empty — identical to the batch driver's epilogue.
    pub fn into_outcome(mut self) -> SimOutcome {
        // Keep the elected-master abstraction honest: if slot 0's agent is
        // gone, fail it over (exercised further in tests).
        if self.slots.first().map(|s| s.is_none()).unwrap_or(false) {
            self.election.fail(0);
        }
        let end_time = self.evq.now();
        for slot in self.slots.iter_mut() {
            if let Some(mut a) = slot.take() {
                a.shutdown("horizon", &mut self.cluster, end_time);
                self.done.push(a);
            }
        }
        let events_processed = self.evq.processed();
        SimOutcome {
            agents: self.done,
            cluster: self.cluster,
            master_log: self.master_log,
            election: self.election,
            end_time,
            events_processed,
        }
    }

    // -- snapshot / restore ------------------------------------------------

    /// Serialize the run's replay inputs plus a progress summary.  A
    /// restore rebuilds the engine from the recorded inputs and replays the
    /// same number of events, reproducing the exact state (given the same
    /// trainer factory).
    pub fn snapshot_json(&self) -> Json {
        let online = Json::Arr(
            self.online
                .iter()
                .map(|o| {
                    Json::obj()
                        .with("at", Json::Num(o.at))
                        .with("after_events", Json::Num(o.after_events as f64))
                        .with("config", o.config.to_json())
                })
                .collect(),
        );
        let progress = Json::obj()
            .with("queue_len", Json::Num(self.queue_len() as f64))
            .with("active_agents", Json::Num(self.active_agents().count() as f64))
            .with("done_agents", Json::Num(self.done.len() as f64))
            .with(
                "best",
                self.best().map(|(_, _, m)| Json::Num(m)).unwrap_or(Json::Null),
            );
        Json::obj()
            .with("version", Json::Num(1.0))
            .with("t", Json::Num(self.evq.now()))
            .with("events_processed", Json::Num(self.evq.processed() as f64))
            .with("setup", self.setup.to_json())
            .with("online", online)
            .with("progress", progress)
    }

    /// Replay helper: step until `target` events have been processed.
    /// The past-horizon pop counts (it incremented `processed` in the
    /// original run too), so horizon-terminated snapshots restore cleanly.
    fn replay_to(&mut self, target: u64) -> anyhow::Result<()> {
        while self.events_processed() < target {
            match self.step() {
                Step::Advanced(_) | Step::HorizonReached => {}
                Step::Idle => anyhow::bail!(
                    "replay stalled at {} / {} events — snapshot does not match inputs",
                    self.events_processed(),
                    target
                ),
            }
        }
        Ok(())
    }

    /// Rebuild an engine from [`SimEngine::snapshot_json`] output by
    /// replaying the recorded inputs up to the snapshot's event count.
    /// Each online submission is re-issued at the event count where the
    /// original `submit` call happened, so the event queue assigns the
    /// same sequence numbers and same-timestamp ties break identically.
    /// `make_trainer` must be the factory the original run used (the
    /// trainers' internal state is reproduced by replay, not serialized).
    ///
    /// The replay runs **quiet**: integrator series retention is
    /// suspended until the target event count is reached (then reconciled
    /// once), so a restore does O(1) work per replayed event.  The
    /// trade-off is explicit: a restored engine's plotting series
    /// (`cluster_doc`'s live Fig. 8 view) starts at the snapshot point —
    /// the pre-snapshot utilization *curve* is not rebuilt, only its
    /// integral.  GPU-hour accounting stays exact, no doc rendering or
    /// event-log writes happen during replay (the platform layer attaches
    /// its log and reconciles cursors after the engine is rebuilt), and
    /// no simulation decision changes: the event sequence is
    /// bit-identical (verified by the snapshot-determinism tests).
    pub fn restore(
        doc: &Json,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> anyhow::Result<SimEngine<'t>> {
        let setup_doc = doc
            .get("setup")
            .ok_or_else(|| anyhow::anyhow!("snapshot missing 'setup'"))?;
        let setup = SimSetup::from_json(setup_doc)?;
        let target: u64 = doc
            .get("events_processed")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("snapshot missing 'events_processed'"))?
            as u64;
        let mut engine = SimEngine::new(setup, make_trainer);
        engine.cluster.set_series_retention(false);
        if let Some(online) = doc.get("online").and_then(|v| v.as_arr()) {
            for o in online {
                let at = o
                    .get("at")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("online submission missing 'at'"))?;
                let after_events = o
                    .get("after_events")
                    .and_then(|v| v.as_i64())
                    .unwrap_or(0) as u64;
                let cfg = ChoptConfig::from_json(
                    o.get("config")
                        .ok_or_else(|| anyhow::anyhow!("online submission missing 'config'"))?,
                )?;
                engine.replay_to(after_events.min(target))?;
                if engine.submit(cfg, at).is_none() {
                    anyhow::bail!(
                        "replay hit the horizon before a recorded submission at t={at}"
                    );
                }
            }
        }
        engine.replay_to(target)?;
        engine.cluster.set_series_retention(true);
        Ok(engine)
    }
}
