//! NSML leaderboard: ranks sessions by their best objective measure.

use crate::config::Order;

use super::session::{NsmlSession, SessionId};

/// A ranked view over sessions (paper §2.3: "comparison of performance
/// metrics between models via a leaderboard").
#[derive(Debug, Clone)]
pub struct Leaderboard {
    pub measure: String,
    pub order: Order,
    /// (session, best measure), best first.
    entries: Vec<(SessionId, f64)>,
}

impl Leaderboard {
    pub fn new(measure: &str, order: Order) -> Leaderboard {
        Leaderboard {
            measure: measure.to_string(),
            order,
            entries: Vec::new(),
        }
    }

    /// Rebuild from a session set.
    pub fn rebuild<'a>(&mut self, sessions: impl Iterator<Item = &'a NsmlSession>) {
        self.entries.clear();
        for s in sessions {
            if let Some(best) = s.best_measure(self.order) {
                self.entries.push((s.id, best));
            }
        }
        let order = self.order;
        self.entries.sort_by(|a, b| {
            if order.better(a.1, b.1) {
                std::cmp::Ordering::Less
            } else if order.better(b.1, a.1) {
                std::cmp::Ordering::Greater
            } else {
                a.0.cmp(&b.0) // deterministic tie-break
            }
        });
    }

    /// Incremental update for one session: O(log n) rank search plus one
    /// element move, instead of a full re-sort (the coordinator calls
    /// this on every reported interval — see perf_coordinator §Perf).
    pub fn update(&mut self, session: &NsmlSession) {
        let Some(best) = session.best_measure(self.order) else {
            return;
        };
        let order = self.order;
        let cmp = |a: &(SessionId, f64), b: &(SessionId, f64)| {
            if order.better(a.1, b.1) {
                std::cmp::Ordering::Less
            } else if order.better(b.1, a.1) {
                std::cmp::Ordering::Greater
            } else {
                a.0.cmp(&b.0)
            }
        };
        // Remove the stale entry (linear scan — ids are unsorted), then
        // binary-search the insertion point in the sorted-by-score list.
        if let Some(pos) = self.entries.iter().position(|(id, _)| *id == session.id) {
            self.entries.remove(pos);
        }
        let entry = (session.id, best);
        let idx = self
            .entries
            .binary_search_by(|probe| cmp(probe, &entry))
            .unwrap_or_else(|i| i);
        self.entries.insert(idx, entry);
    }

    pub fn remove(&mut self, id: SessionId) {
        self.entries.retain(|(sid, _)| *sid != id);
    }

    pub fn best(&self) -> Option<(SessionId, f64)> {
        self.entries.first().copied()
    }

    /// Top-k entries, best first.
    pub fn top(&self, k: usize) -> &[(SessionId, f64)] {
        &self.entries[..k.min(self.entries.len())]
    }

    /// Rank of a session (0 = best).
    pub fn rank(&self, id: SessionId) -> Option<usize> {
        self.entries.iter().position(|(sid, _)| *sid == id)
    }

    /// Is `id` in the bottom `frac` fraction? (PBT truncation exploit.)
    pub fn in_bottom_fraction(&self, id: SessionId, frac: f64) -> bool {
        match self.rank(id) {
            None => false,
            Some(r) => {
                let n = self.entries.len();
                n > 0 && (r as f64) >= (1.0 - frac) * n as f64
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hparam::Assignment;

    fn session(id: u64, measures: &[f64]) -> NsmlSession {
        let mut s = NsmlSession::new(SessionId(id), Assignment::new(), "m", 0.0);
        for (i, &m) in measures.iter().enumerate() {
            s.report(i + 1, m, 1.0);
        }
        s
    }

    #[test]
    fn ranks_descending() {
        let mut lb = Leaderboard::new("test/accuracy", Order::Descending);
        let sessions = vec![session(1, &[0.5]), session(2, &[0.9]), session(3, &[0.7])];
        lb.rebuild(sessions.iter());
        assert_eq!(lb.best(), Some((SessionId(2), 0.9)));
        assert_eq!(lb.rank(SessionId(1)), Some(2));
        assert_eq!(lb.top(2).len(), 2);
    }

    #[test]
    fn ranks_ascending_for_loss() {
        let mut lb = Leaderboard::new("test/loss", Order::Ascending);
        lb.rebuild(vec![session(1, &[2.0]), session(2, &[0.5])].iter());
        assert_eq!(lb.best(), Some((SessionId(2), 0.5)));
    }

    #[test]
    fn incremental_update_re_ranks() {
        let mut lb = Leaderboard::new("m", Order::Descending);
        lb.rebuild(vec![session(1, &[0.5]), session(2, &[0.6])].iter());
        let improved = session(1, &[0.5, 0.95]);
        lb.update(&improved);
        assert_eq!(lb.best(), Some((SessionId(1), 0.95)));
        lb.remove(SessionId(1));
        assert_eq!(lb.best(), Some((SessionId(2), 0.6)));
    }

    #[test]
    fn bottom_fraction() {
        let mut lb = Leaderboard::new("m", Order::Descending);
        let sessions: Vec<_> = (0..10)
            .map(|i| session(i as u64, &[i as f64 / 10.0]))
            .collect();
        lb.rebuild(sessions.iter());
        // Sessions 0 and 1 have the lowest scores -> bottom 20%.
        assert!(lb.in_bottom_fraction(SessionId(0), 0.2));
        assert!(lb.in_bottom_fraction(SessionId(1), 0.2));
        assert!(!lb.in_bottom_fraction(SessionId(9), 0.2));
        assert!(!lb.in_bottom_fraction(SessionId(5), 0.2));
    }

    #[test]
    fn sessions_without_history_excluded() {
        let mut lb = Leaderboard::new("m", Order::Descending);
        lb.rebuild(vec![session(1, &[])].iter());
        assert!(lb.is_empty());
    }
}
