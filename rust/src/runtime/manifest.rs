//! `artifacts/manifest.json` parsing: input/output specs per artifact,
//! parameter layouts per model variant, dataset dimensions.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Value as Json};

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("manifest io: {0}")]
    Io(#[from] std::io::Error),
    #[error("manifest json: {0}")]
    Json(#[from] json::JsonError),
    #[error("manifest schema: {0}")]
    Schema(String),
}

fn schema(msg: impl Into<String>) -> ManifestError {
    ManifestError::Schema(msg.into())
}

/// Shape+dtype of one artifact input.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32" | "u32" | "bf16"
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
    pub output_names: Vec<String>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

/// One model variant (train/eval/init artifact triple + metadata).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub task: String,
    pub blocks: usize,
    pub widen: usize,
    pub logical_depth: usize,
    pub param_count: u64,
    pub train: String,
    pub eval: String,
    pub init: String,
    pub hyperparams: Vec<String>,
    pub measure: String,
}

/// Dataset dimensions shared with python.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataDims {
    pub input_dim: usize,
    pub classes: usize,
    pub batch: usize,
    pub img_h: usize,
    pub img_w: usize,
    pub img_c: usize,
    pub qa_vocab: usize,
    pub qa_ctx_len: usize,
    pub qa_qry_len: usize,
    pub qa_batch: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub data: DataDims,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub variants: HashMap<String, VariantSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let doc = json::parse(&text)?;

        let img = doc
            .path("data.image")
            .ok_or_else(|| schema("missing data.image"))?;
        let qa = doc
            .path("data.qa")
            .ok_or_else(|| schema("missing data.qa"))?;
        let u = |j: &Json, k: &str| -> Result<usize, ManifestError> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| schema(format!("missing data field {k}")))
        };
        let data = DataDims {
            input_dim: u(img, "input_dim")?,
            classes: u(img, "classes")?,
            batch: u(img, "batch")?,
            img_h: u(img, "height")?,
            img_w: u(img, "width")?,
            img_c: u(img, "channels")?,
            qa_vocab: u(qa, "vocab")?,
            qa_ctx_len: u(qa, "ctx_len")?,
            qa_qry_len: u(qa, "qry_len")?,
            qa_batch: u(qa, "batch")?,
        };

        let mut artifacts = HashMap::new();
        for (name, aj) in doc
            .require("artifacts")?
            .as_obj()
            .ok_or_else(|| schema("artifacts must be an object"))?
        {
            let inputs = aj
                .require("inputs")?
                .as_arr()
                .ok_or_else(|| schema("inputs must be an array"))?
                .iter()
                .map(|t| -> Result<TensorSpec, ManifestError> {
                    Ok(TensorSpec {
                        name: t
                            .get("name")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| schema("input missing name"))?
                            .to_string(),
                        shape: t
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .ok_or_else(|| schema("input missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| schema("bad dim")))
                            .collect::<Result<Vec<_>, _>>()?,
                        dtype: t
                            .get("dtype")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| schema("input missing dtype"))?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            let output_names = aj
                .require("output_names")?
                .as_arr()
                .ok_or_else(|| schema("output_names must be an array"))?
                .iter()
                .map(|v| v.as_str().unwrap_or("").to_string())
                .collect::<Vec<_>>();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: aj
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| schema("artifact missing file"))?
                        .to_string(),
                    inputs,
                    n_outputs: aj
                        .get("n_outputs")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(output_names.len()),
                    output_names,
                },
            );
        }

        let mut variants = HashMap::new();
        for (name, vj) in doc
            .require("variants")?
            .as_obj()
            .ok_or_else(|| schema("variants must be an object"))?
        {
            variants.insert(
                name.clone(),
                VariantSpec {
                    name: name.clone(),
                    task: vj
                        .get("task")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    blocks: vj.get("blocks").and_then(|v| v.as_usize()).unwrap_or(1),
                    widen: vj.get("widen").and_then(|v| v.as_usize()).unwrap_or(1),
                    logical_depth: vj
                        .get("logical_depth")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(1),
                    param_count: vj
                        .get("param_count")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(0) as u64,
                    train: vj
                        .get("train")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| schema("variant missing train"))?
                        .to_string(),
                    eval: vj
                        .get("eval")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| schema("variant missing eval"))?
                        .to_string(),
                    init: vj
                        .get("init")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| schema("variant missing init"))?
                        .to_string(),
                    hyperparams: vj
                        .get("hyperparams")
                        .and_then(|v| v.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                                .collect()
                        })
                        .unwrap_or_default(),
                    measure: vj
                        .get("measure")
                        .and_then(|v| v.as_str())
                        .unwrap_or("test/accuracy")
                        .to_string(),
                },
            );
        }

        Ok(Manifest {
            dir,
            data,
            artifacts,
            variants,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn variant(&self, name: &str) -> Option<&VariantSpec> {
        self.variants.get(name)
    }

    pub fn artifact_path(&self, name: &str) -> Option<PathBuf> {
        self.artifacts.get(name).map(|a| self.dir.join(&a.file))
    }

    /// Default artifacts directory: $CHOPT_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("CHOPT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have run (they are the
    /// python->rust contract check).
    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn loads_and_has_variants() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(m.variants.contains_key("ic_d1_w1"));
        assert!(m.variants.contains_key("qa_bidaf"));
        let v = m.variant("ic_d1_w1").unwrap();
        assert!(m.artifacts.contains_key(&v.train));
        assert!(m.artifacts.contains_key(&v.eval));
        assert!(m.artifacts.contains_key(&v.init));
        assert!(v.param_count > 0);
    }

    #[test]
    fn train_artifact_io_contract() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let a = m.artifact("ic_d1_w1_train").unwrap();
        // x, y, 4 scalars, seed, then params+velocities.
        assert_eq!(a.inputs[0].name, "x");
        assert_eq!(a.inputs[0].shape, vec![m.data.batch, m.data.input_dim]);
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.input_index("lr"), Some(2));
        assert_eq!(a.output_names[0], "loss");
        assert_eq!(a.n_outputs, a.output_names.len());
        // train outputs = 2 metrics + full state.
        let state_inputs = a.inputs.len() - 7;
        assert_eq!(a.n_outputs, 2 + state_inputs);
        assert!(m.artifact_path("ic_d1_w1_train").unwrap().exists());
    }

    #[test]
    fn data_dims_consistent() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.data.input_dim, m.data.img_h * m.data.img_w * m.data.img_c);
        assert!(m.data.classes >= 2);
    }
}
