//! PJRT execution: compile HLO text once per artifact, execute many times.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Host-side tensor for marshalling into/out of PJRT literals.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn f32_scalar(&self) -> Option<f32> {
        self.as_f32().and_then(|d| d.first().copied())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        match self {
            HostTensor::F32(data, _) => {
                let l = xla::Literal::vec1(data);
                if dims.is_empty() {
                    // Rank-0: reshape a singleton vec to scalar shape.
                    Ok(l.reshape(&[])?)
                } else {
                    Ok(l.reshape(&dims)?)
                }
            }
            HostTensor::I32(data, _) => {
                let l = xla::Literal::vec1(data);
                if dims.is_empty() {
                    Ok(l.reshape(&[])?)
                } else {
                    Ok(l.reshape(&dims)?)
                }
            }
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
            other => Err(anyhow!("unsupported output element type {other:?}")),
        }
    }
}

/// A compiled-executable cache over one PJRT (CPU) client.
///
/// Not `Sync`: each agent thread builds its own `Runtime` (PJRT wraps raw
/// C pointers).  Compilation happens once per artifact per runtime.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions served (perf counters).
    pub executions: u64,
    /// Compilations performed.
    pub compilations: u64,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifacts directory.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir).context("loading manifest.json")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            executables: HashMap::new(),
            executions: 0,
            compilations: 0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `artifact` is compiled; returns its spec.
    pub fn prepare(&mut self, artifact: &str) -> Result<&ArtifactSpec> {
        if !self.executables.contains_key(artifact) {
            let path = self
                .manifest
                .artifact_path(artifact)
                .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {artifact}"))?;
            self.executables.insert(artifact.to_string(), exe);
            self.compilations += 1;
        }
        self.manifest
            .artifact(artifact)
            .ok_or_else(|| anyhow!("artifact '{artifact}' missing from manifest"))
    }

    /// Execute an artifact with host tensors; returns the untupled outputs.
    pub fn execute(&mut self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.prepare(artifact)?.clone();
        validate_inputs(&spec, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let exe = self.executables.get(artifact).expect("prepared above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {artifact}"))?;
        self.executions += 1;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = out.to_tuple().context("untupling result")?;
        let tensors = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        if tensors.len() != spec.n_outputs {
            return Err(anyhow!(
                "{artifact}: expected {} outputs, got {}",
                spec.n_outputs,
                tensors.len()
            ));
        }
        Ok(tensors)
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }
}

fn validate_inputs(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        return Err(anyhow!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        ));
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        check_one(i, t, s).with_context(|| format!("artifact {}", spec.name))?;
    }
    Ok(())
}

fn check_one(i: usize, t: &HostTensor, s: &TensorSpec) -> Result<()> {
    if t.shape() != s.shape.as_slice() {
        return Err(anyhow!(
            "input {i} ('{}'): shape {:?} != spec {:?}",
            s.name,
            t.shape(),
            s.shape
        ));
    }
    let ok = matches!(
        (t, s.dtype.as_str()),
        (HostTensor::F32(..), "f32") | (HostTensor::I32(..), "i32")
    );
    if !ok {
        return Err(anyhow!(
            "input {i} ('{}'): dtype mismatch (spec {})",
            s.name,
            s.dtype
        ));
    }
    if t.len() != s.elements() {
        return Err(anyhow!(
            "input {i} ('{}'): {} elements != {}",
            s.name,
            t.len(),
            s.elements()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::F32(vec![1.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        let s = HostTensor::scalar_i32(7);
        assert!(s.shape().is_empty());
    }

    #[test]
    fn validate_rejects_mismatches() {
        let spec = ArtifactSpec {
            name: "a".into(),
            file: "a.hlo.txt".into(),
            inputs: vec![TensorSpec {
                name: "x".into(),
                shape: vec![2, 2],
                dtype: "f32".into(),
            }],
            n_outputs: 1,
            output_names: vec!["y".into()],
        };
        let bad_shape = [HostTensor::F32(vec![0.0; 4], vec![4])];
        assert!(validate_inputs(&spec, &bad_shape).is_err());
        let bad_dtype = [HostTensor::I32(vec![0; 4], vec![2, 2])];
        assert!(validate_inputs(&spec, &bad_dtype).is_err());
        let bad_count: [HostTensor; 0] = [];
        assert!(validate_inputs(&spec, &bad_count).is_err());
        let good = [HostTensor::F32(vec![0.0; 4], vec![2, 2])];
        assert!(validate_inputs(&spec, &good).is_ok());
    }
}
