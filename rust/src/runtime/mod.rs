//! Runtime bridge: load AOT HLO-text artifacts and execute them via PJRT.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only thing that touches the resulting `artifacts/` directory at run
//! time.  HLO *text* is the interchange format (see python/compile/hlo.py
//! and /opt/xla-example/README.md: serialized protos from jax >= 0.5 are
//! rejected by xla_extension 0.5.1).
//!
//! Thread model: `PjRtClient` wraps raw C pointers and is used from the
//! thread that created it; each agent thread owns its own [`Runtime`].

mod manifest;
mod rt;

pub use manifest::{ArtifactSpec, DataDims, Manifest, TensorSpec, VariantSpec};
pub use rt::{HostTensor, Runtime};
