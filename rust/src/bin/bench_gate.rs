//! CI perf-regression gate over the `BENCH_*.json` trajectory files.
//!
//! For every baseline committed under `rust/benches/baselines/`, the gate
//! loads the freshly-produced `BENCH_<name>.json` (written by the perf
//! benches into the current directory, or `CHOPT_BENCH_DIR`) and compares
//! each metric *present in the baseline* against the fresh value:
//!
//! * `*_per_sec`, `*_speedup_x`  — higher is better; fail when the fresh
//!   value drops below `baseline * (1 - tolerance)`.
//! * `*_us`, `*_ms`, `*_ns`, `*_secs` — lower is better; fail when the
//!   fresh value rises above `baseline * (1 + tolerance)`.
//! * `*_total`, `*_count`, `*_pts`, `*_studies`, `*_owners` — expected
//!   stable (deterministic counters); fail when outside the symmetric
//!   tolerance band.
//! * anything else — reported, never enforced.
//!
//! Metrics in the fresh file but absent from the baseline are *skipped*
//! (reported, never failed), so baselines can be adopted incrementally —
//! wall-clock numbers are pinned only once a CI runner has actually
//! produced them.  Re-baseline intentionally with:
//!
//!     cp BENCH_<name>.json rust/benches/baselines/
//!
//! Exit code: 0 = all gated metrics within tolerance, 1 = regression (or
//! a baseline whose bench output is missing).
//!
//!     cargo run --release --bin bench_gate [-- --tolerance 0.2]

use std::path::Path;

use chopt::util::json::{self, Value as Json};

#[derive(Clone, Copy, PartialEq)]
enum Direction {
    HigherBetter,
    LowerBetter,
    Stable,
    Informational,
}

fn direction(key: &str) -> Direction {
    if key.ends_with("_per_sec") || key.ends_with("_speedup_x") {
        Direction::HigherBetter
    } else if key.ends_with("_us")
        || key.ends_with("_ms")
        || key.ends_with("_ns")
        || key.ends_with("_secs")
    {
        Direction::LowerBetter
    } else if key.ends_with("_total")
        || key.ends_with("_count")
        || key.ends_with("_pts")
        || key.ends_with("_studies")
        || key.ends_with("_owners")
    {
        Direction::Stable
    } else {
        Direction::Informational
    }
}

fn load(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)?;
    Ok(json::parse(&text)?)
}

fn main() {
    let mut baseline_dir = "rust/benches/baselines".to_string();
    let mut current_dir = std::env::var("CHOPT_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let mut tolerance = 0.20f64;
    let mut args = std::env::args().skip(1);
    // Flag values are required and validated: silently falling back to a
    // default tolerance would run the gate at a different band than the
    // CI workflow asked for, masking regressions.
    let value_of = |flag: &str, args: &mut dyn Iterator<Item = String>| -> String {
        args.next().unwrap_or_else(|| {
            eprintln!("bench_gate: {flag} requires a value");
            std::process::exit(2);
        })
    };
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--baseline-dir" => baseline_dir = value_of("--baseline-dir", &mut args),
            "--dir" => current_dir = value_of("--dir", &mut args),
            "--tolerance" => {
                let raw = value_of("--tolerance", &mut args);
                tolerance = raw.parse().unwrap_or_else(|_| {
                    eprintln!(
                        "bench_gate: --tolerance expects a fraction like 0.2, got '{raw}'"
                    );
                    std::process::exit(2);
                });
            }
            other => {
                eprintln!("bench_gate: unknown argument '{other}'");
                std::process::exit(2);
            }
        }
    }

    let mut baselines: Vec<std::path::PathBuf> = match std::fs::read_dir(&baseline_dir) {
        Ok(entries) => entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    baselines.sort();
    if baselines.is_empty() {
        println!(
            "bench_gate: no baselines under {baseline_dir} — nothing enforced.\n\
             Pin one from a fresh bench run: cp BENCH_<name>.json {baseline_dir}/"
        );
        return;
    }

    let mut failures = 0usize;
    let mut gated = 0usize;
    let mut skipped = 0usize;
    for base_path in &baselines {
        let file = base_path.file_name().unwrap().to_string_lossy().to_string();
        let base = match load(base_path) {
            Ok(doc) => doc,
            Err(e) => {
                eprintln!("FAIL {file}: unreadable baseline: {e}");
                failures += 1;
                continue;
            }
        };
        let cur_path = Path::new(&current_dir).join(&file);
        let cur = match load(&cur_path) {
            Ok(doc) => doc,
            Err(_) => {
                eprintln!(
                    "FAIL {file}: no fresh bench output at {} (did the bench run?)",
                    cur_path.display()
                );
                failures += 1;
                continue;
            }
        };
        let Some(metrics) = base.get("metrics").and_then(|m| m.as_obj()) else {
            eprintln!("FAIL {file}: baseline has no 'metrics' object");
            failures += 1;
            continue;
        };
        for (key, bv) in metrics {
            let Some(base_v) = bv.as_f64() else { continue };
            let cur_v = cur
                .get("metrics")
                .and_then(|m| m.get(key))
                .and_then(|v| v.as_f64());
            let Some(cur_v) = cur_v else {
                eprintln!("FAIL {file}: metric '{key}' missing from fresh output");
                failures += 1;
                continue;
            };
            let dir = direction(key);
            let ok = match dir {
                Direction::HigherBetter => cur_v >= base_v * (1.0 - tolerance),
                Direction::LowerBetter => cur_v <= base_v * (1.0 + tolerance),
                Direction::Stable => {
                    cur_v >= base_v * (1.0 - tolerance) && cur_v <= base_v * (1.0 + tolerance)
                }
                Direction::Informational => true,
            };
            let label = match dir {
                Direction::HigherBetter => "higher-better",
                Direction::LowerBetter => "lower-better",
                Direction::Stable => "stable",
                Direction::Informational => "info-only",
            };
            if dir == Direction::Informational {
                println!("  --  {file} {key}: {cur_v} (baseline {base_v}, {label})");
                continue;
            }
            gated += 1;
            if ok {
                println!("  ok  {file} {key}: {cur_v} vs baseline {base_v} ({label})");
            } else {
                eprintln!(
                    "FAIL {file} {key}: {cur_v} vs baseline {base_v} ({label}, \
                     tolerance {:.0}%)",
                    tolerance * 100.0
                );
                failures += 1;
            }
        }
        // Fresh metrics with no committed baseline are skipped, never
        // failed: wall-clock numbers can only be pinned from a CI
        // runner's own output, so a new bench metric surfaces here
        // until someone adopts a baseline for it.
        if let Some(fresh) = cur.get("metrics").and_then(|m| m.as_obj()) {
            for (key, fv) in fresh {
                if metrics.iter().any(|(k, _)| k == key) {
                    continue;
                }
                let Some(v) = fv.as_f64() else { continue };
                skipped += 1;
                println!("  skip {file} {key}: {v} (no committed baseline — not enforced)");
            }
        }
    }
    println!(
        "bench_gate: {gated} metric(s) gated across {} baseline file(s), \
         {skipped} skipped (no baseline), {failures} failure(s)",
        baselines.len()
    );
    if failures > 0 {
        eprintln!(
            "bench_gate: regression detected. Intentional change? Re-baseline with:\n\
             \tcp BENCH_<name>.json {baseline_dir}/"
        );
        std::process::exit(1);
    }
}
