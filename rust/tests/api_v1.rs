//! Integration tests for the versioned control-plane API: envelope
//! schema + string ids on every endpoint, pagination bounds, HTTP error
//! mapping (404/405/400/401/403), command round-trips (pause → parked at
//! the next event boundary → resume), retired legacy aliases answering
//! 410 Gone with a v1 pointer, engine-level command replay through
//! snapshots,
//! stored-vs-live byte parity per endpoint (`StoredRun`), `?at_event=`
//! replay scrubbing (`ReplaySource`), and the SSE push stream
//! (connect / heartbeat / `Last-Event-ID` resume over a real socket).

use std::io::{Read as _, Write as _};
use std::time::{Duration, Instant};

use chopt::config::ChoptConfig;
use chopt::coordinator::{
    AgentEvent, MultiPlatform, Platform, SimEngine, SimSetup, StopAndGoPolicy, StudyManifest,
};
use chopt::nsml::SessionId;
use chopt::storage::{ReplaySource, StoredRun};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::util::json::Value as Json;
use chopt::viz::api::{envelope, ApiInbox, ApiQuery, PlatformApi, RunSource};
use chopt::viz::server::{
    http_request, http_request_full, http_request_with_headers, Routes, VizServer,
};
use chopt::viz::sse::EventFeed;

fn cfg(seed: u64) -> ChoptConfig {
    let text = format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": 10,
          "population": 4,
          "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": 8}},
          "model": "surrogate:resnet",
          "max_epochs": 60,
          "max_gpus": 3,
          "seed": {seed}
        }}"#
    );
    ChoptConfig::from_json_str(&text).unwrap()
}

fn setup(seed: u64) -> SimSetup {
    SimSetup {
        cluster_gpus: 6,
        configs: vec![cfg(seed)],
        submit_times: Vec::new(),
        agent_slots: 1,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: Vec::new(),
        scenario: None,
        retry: chopt::coordinator::RetryPolicy::default(),
    }
}

fn surrogate(seed: u64) -> impl FnMut(u64) -> Box<dyn Trainer> {
    move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>
}

fn multi_manifest() -> StudyManifest {
    let study = |name: &str, extra: &str, seed: u64| {
        format!(
            r#"{{"name": "{name}", "quota": 4, {extra} "config": {{
              "h_params": {{
                "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                        "type": "float", "p_range": [0.001, 0.2]}}
              }},
              "measure": "test/accuracy", "order": "descending", "step": 10,
              "population": 4, "tune": {{"random": {{}}}},
              "termination": {{"max_session_number": 8}},
              "model": "surrogate:resnet", "max_epochs": 60, "max_gpus": 3,
              "seed": {seed}
            }}}}"#
        )
    };
    let text = format!(
        r#"{{"cluster_gpus": 12, "borrow": true, "studies": [{}, {}]}}"#,
        study("alice", r#""priority": 2,"#, 100),
        study("bob", "", 101)
    );
    StudyManifest::from_json_str(&text).unwrap()
}

fn multi_trainer(study: usize, id: u64) -> Box<dyn Trainer + Send> {
    Box::new(SurrogateTrainer::new(9_000 + 1_000 * study as u64 + id)) as Box<dyn Trainer + Send>
}

/// Issue one HTTP request against the server while serving the inbox
/// from this thread (the platform is single-threaded by design, so the
/// client must run on a helper thread).
fn call(
    addr: std::net::SocketAddr,
    inbox: &ApiInbox,
    api: &mut impl PlatformApi,
    method: &'static str,
    path: &str,
    body: &[u8],
) -> (u16, Json) {
    let path = path.to_string();
    let body = body.to_vec();
    let client = std::thread::spawn(move || http_request(addr, method, &path, &body).unwrap());
    let deadline = Instant::now() + Duration::from_secs(10);
    while !client.is_finished() && Instant::now() < deadline {
        inbox.serve_one(api, Duration::from_millis(20));
    }
    let (status, bytes) = client.join().unwrap();
    let doc = chopt::util::json::parse(&String::from_utf8(bytes).unwrap()).unwrap();
    (status, doc)
}

fn get(
    addr: std::net::SocketAddr,
    inbox: &ApiInbox,
    api: &mut impl PlatformApi,
    path: &str,
) -> (u16, Json) {
    call(addr, inbox, api, "GET", path, b"")
}

/// Every 200 must carry the v1 envelope; returns the data payload.
fn expect_enveloped(status: u16, doc: &Json, what: &str) -> Json {
    assert_eq!(status, 200, "{what}: {doc}");
    assert_eq!(
        doc.get("schema_version").and_then(|v| v.as_f64()),
        Some(1.0),
        "{what} missing schema_version"
    );
    let gen = doc
        .get("generated_at_event")
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("{what}: generated_at_event must be a string"));
    gen.parse::<u64>().expect("generated_at_event parses as u64");
    doc.get("data").unwrap_or_else(|| panic!("{what} missing data")).clone()
}

#[test]
fn v1_single_study_surface_envelope_and_string_ids() {
    let mut platform = Platform::new(setup(7), surrogate(7));
    platform.run_until(5_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/status");
    let status_doc = expect_enveloped(s, &doc, "status");
    assert_eq!(status_doc.get("done").and_then(|v| v.as_bool()), Some(false));

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/cluster?window=3600");
    let cluster = expect_enveloped(s, &doc, "cluster");
    assert_eq!(cluster.get("window").and_then(|v| v.as_f64()), Some(3600.0));
    assert!(!cluster.get("series_chopt").unwrap().as_arr().unwrap().is_empty());

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/leaderboard?k=5");
    let lb = expect_enveloped(s, &doc, "leaderboard");
    let rows = lb.get("rows").unwrap().as_arr().unwrap();
    assert!(!rows.is_empty());
    for r in rows {
        let sid = r.get("session").and_then(|v| v.as_str()).expect("string id");
        sid.parse::<u64>().unwrap();
        r.get("chopt").and_then(|v| v.as_str()).expect("string id");
    }

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/sessions");
    let sessions = expect_enveloped(s, &doc, "sessions");
    let total = sessions.get("total").and_then(|v| v.as_usize()).unwrap();
    assert!(total > 0);
    for row in sessions.get("sessions").unwrap().as_arr().unwrap() {
        row.get("id").and_then(|v| v.as_str()).expect("string id");
        row.get("chopt").and_then(|v| v.as_str()).expect("string id");
    }

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/parallel");
    let par = expect_enveloped(s, &doc, "parallel");
    for line in par.get("lines").unwrap().as_arr().unwrap() {
        line.get("id").and_then(|v| v.as_str()).expect("string id");
    }

    // Multi-study endpoints don't exist on a single-study server.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/fair_share");
    assert_eq!(s, 404, "{doc}");
    assert!(doc.get("error").is_some());

    server.stop();
}

#[test]
fn v1_pagination_bounds() {
    let mut platform = Platform::new(setup(11), surrogate(11));
    platform.run_until(8_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/sessions");
    let all = expect_enveloped(s, &doc, "sessions");
    let total = all.get("total").and_then(|v| v.as_usize()).unwrap();
    assert!(total >= 2, "need a few sessions to page over");
    assert_eq!(
        all.get("sessions").unwrap().as_arr().unwrap().len(),
        total,
        "no limit → every session"
    );

    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/sessions?limit=1&offset=1");
    let page = expect_enveloped(s, &doc, "page");
    assert_eq!(page.get("returned").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(page.get("total").and_then(|v| v.as_usize()), Some(total));
    // The page is a window into the same ordering.
    assert_eq!(
        page.get("sessions").unwrap().idx(0).unwrap().get("id"),
        all.get("sessions").unwrap().idx(1).unwrap().get("id")
    );

    // Out-of-range offset → empty page, not an error.
    let (s, doc) = get(
        addr,
        &inbox,
        &mut platform,
        &format!("/api/v1/sessions?offset={}", total + 50),
    );
    let empty = expect_enveloped(s, &doc, "past-the-end page");
    assert_eq!(empty.get("returned").and_then(|v| v.as_usize()), Some(0));
    assert!(empty.get("sessions").unwrap().as_arr().unwrap().is_empty());

    // limit=0 → empty page as well.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/sessions?limit=0");
    let zero = expect_enveloped(s, &doc, "limit-0 page");
    assert_eq!(zero.get("returned").and_then(|v| v.as_usize()), Some(0));

    // Bad parameter → 400 with an error envelope.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/sessions?limit=abc");
    assert_eq!(s, 400);
    assert!(doc.get("error").is_some());

    server.stop();
}

#[test]
fn v1_http_error_mapping() {
    let mut platform = Platform::new(setup(13), surrogate(13));
    platform.run_until(1_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    // Unknown API path → 404.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/nope");
    assert_eq!(s, 404, "{doc}");

    // Wrong method on a query → 405; GET on /commands → 405.
    let (s, _) = call(addr, &inbox, &mut platform, "POST", "/api/v1/status", b"{}");
    assert_eq!(s, 405);
    let (s, _) = call(addr, &inbox, &mut platform, "GET", "/api/v1/commands", b"");
    assert_eq!(s, 405);

    // Malformed / unknown command bodies → 400 with an error envelope.
    for body in [
        &b"not json"[..],
        br#"{"command": "warp_time"}"#,
        br#"{"command": "pause_session"}"#,
    ] {
        let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", body);
        assert_eq!(s, 400, "{doc}");
        assert!(doc.get("error").is_some());
    }

    // A well-formed command naming a nonexistent session → 400 too.
    let (s, doc) = call(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        br#"{"command": "pause_session", "session": "424242"}"#,
    );
    assert_eq!(s, 400, "{doc}");

    server.stop();
}

#[test]
fn v1_command_round_trip_pause_resume_session() {
    let mut platform = Platform::new(setup(17), surrogate(17));
    platform.run_until(3_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    let sid = platform
        .engine()
        .active_agents()
        .next()
        .unwrap()
        .pools
        .live()[0];
    let status_of = |p: &Platform, sid: SessionId| {
        p.sessions_ref()
            .iter()
            .find(|s| s.id == sid)
            .map(|s| s.status.name().to_string())
            .unwrap()
    };
    assert_eq!(status_of(&platform, sid), "running");

    // POST pause → accepted; the session parks at the next event
    // boundary the engine processes.
    let body = format!(r#"{{"command": "pause_session", "session": "{}"}}"#, sid.0);
    let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", body.as_bytes());
    let ack = expect_enveloped(s, &doc, "pause ack");
    assert_eq!(ack.get("applied").and_then(|v| v.as_bool()), Some(true));

    platform.advance(120.0);
    assert_eq!(status_of(&platform, sid), "stopped", "pause must park the session");
    let agent = platform.engine().active_agents().next().unwrap();
    assert!(agent.pools.is_parked(sid), "user pause parks (no auto-revival)");

    // The paused session survives further progress without reviving.
    platform.advance(600.0);
    assert_eq!(status_of(&platform, sid), "stopped");

    // POST resume → revived with priority.  The freed GPU may have been
    // refilled with a fresh trial in the meantime, so the revival lands
    // as soon as a GPU frees up — advance until it leaves the stop pool.
    // (It may even train to completion within one advance window, so
    // "running or finished" is the revival evidence, plus the Revived
    // event itself.)
    let body = format!(r#"{{"command": "resume_session", "session": "{}"}}"#, sid.0);
    let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", body.as_bytes());
    expect_enveloped(s, &doc, "resume ack");
    let mut tries = 0;
    while status_of(&platform, sid) == "stopped" && tries < 50 {
        platform.advance(600.0);
        tries += 1;
    }
    assert!(
        matches!(status_of(&platform, sid).as_str(), "running" | "finished"),
        "resume must revive the session (status: {})",
        status_of(&platform, sid)
    );
    let revived = platform.engine().all_agents().any(|a| {
        a.events
            .iter()
            .any(|e| matches!(e, AgentEvent::Revived(s) if *s == sid))
    });
    assert!(revived, "a Revived event must be recorded for the session");

    // And the observable surface reflects the progress.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/status");
    expect_enveloped(s, &doc, "status");

    server.stop();
}

#[test]
fn legacy_aliases_answer_410_with_v1_pointer() {
    let mut platform = Platform::new(setup(19), surrogate(19));
    platform.run_until(4_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    for (legacy, v1) in [
        ("/api/status.json", "/api/v1/status"),
        ("/api/cluster.json", "/api/v1/cluster"),
        ("/api/leaderboard.json", "/api/v1/leaderboard"),
        ("/api/sessions.json", "/api/v1/sessions"),
        ("/api/parallel.json", "/api/v1/parallel"),
        ("/api/studies/alice/sessions.json", "/api/v1/studies/alice/sessions"),
    ] {
        // 410s are answered by the HTTP layer without consulting the
        // platform, so a plain threaded request suffices.
        let legacy_path = legacy.to_string();
        let client = std::thread::spawn(move || {
            http_request_full(addr, "GET", &legacy_path, &[], b"").unwrap()
        });
        let (status, head, body) = client.join().unwrap();
        assert_eq!(status, 410, "{legacy} must be Gone");
        assert!(
            head.contains(&format!("Link: <{v1}>; rel=\"successor-version\"")),
            "{legacy} must point at {v1} via Link; head:\n{head}"
        );
        let doc = chopt::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
        let msg = doc
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        assert!(msg.contains(v1), "error body must name the v1 path: {msg}");
        // The replacement still serves.
        let (s, _) = get(addr, &inbox, &mut platform, v1);
        assert_eq!(s, 200, "{v1}");
    }
    server.stop();
}

#[test]
fn v1_multi_study_surface_and_commands() {
    let mut platform = MultiPlatform::new(multi_manifest(), multi_trainer);
    platform.run_until(2_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    // The test re-GETs the same paths across advances; the gauge keeps
    // the response cache from answering with a previous tick's bytes.
    platform.set_generation_gauge(inbox.generation_gauge());
    let addr = server.addr();

    // Directory + fair-share carry priority/paused fields.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies");
    let studies = expect_enveloped(s, &doc, "studies");
    assert_eq!(studies.get("count").and_then(|v| v.as_usize()), Some(2));
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/fair_share");
    let fair = expect_enveloped(s, &doc, "fair_share");
    let rows = fair.get("studies").unwrap().as_arr().unwrap();
    let row = |name: &str| {
        rows.iter()
            .find(|r| r.get("study").and_then(|v| v.as_str()) == Some(name))
            .unwrap()
            .clone()
    };
    assert_eq!(row("alice").get("priority").and_then(|v| v.as_f64()), Some(2.0));
    assert_eq!(row("alice").get("paused").and_then(|v| v.as_bool()), Some(false));

    // Per-study queries.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies/alice/sessions?limit=3");
    let page = expect_enveloped(s, &doc, "study sessions");
    assert!(page.get("total").and_then(|v| v.as_usize()).unwrap() > 0);
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies/alice/leaderboard?k=3");
    expect_enveloped(s, &doc, "study leaderboard");
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies/alice/parallel");
    let par = expect_enveloped(s, &doc, "study parallel");
    assert!(!par.get("lines").unwrap().as_arr().unwrap().is_empty());
    let (s, _) = get(addr, &inbox, &mut platform, "/api/v1/studies/nobody/sessions");
    assert_eq!(s, 404);

    // Command: reweight bob, observable after the next tick.
    let (s, doc) = call(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        br#"{"command": "set_quota", "study": "bob", "priority": 3.5}"#,
    );
    expect_enveloped(s, &doc, "set_quota ack");
    platform.advance(120.0);
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/fair_share");
    let fair = expect_enveloped(s, &doc, "fair_share after set_quota");
    let bob = fair
        .get("studies")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|r| r.get("study").and_then(|v| v.as_str()) == Some("bob"))
        .unwrap()
        .clone();
    assert_eq!(bob.get("priority").and_then(|v| v.as_f64()), Some(3.5));

    // Command: pause then resume alice, observable through held GPUs.
    let (s, doc) = call(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        br#"{"command": "pause_study", "study": "alice"}"#,
    );
    expect_enveloped(s, &doc, "pause ack");
    platform.advance(120.0);
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/fair_share");
    let fair = expect_enveloped(s, &doc, "fair_share paused");
    let alice = fair
        .get("studies")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|r| r.get("study").and_then(|v| v.as_str()) == Some("alice"))
        .unwrap()
        .clone();
    assert_eq!(alice.get("paused").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(alice.get("held").and_then(|v| v.as_i64()), Some(0));

    let (s, doc) = call(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        br#"{"command": "resume_study", "study": "alice"}"#,
    );
    expect_enveloped(s, &doc, "resume ack");
    platform.advance(200.0);
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/fair_share");
    let fair = expect_enveloped(s, &doc, "fair_share resumed");
    let alice = fair
        .get("studies")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|r| r.get("study").and_then(|v| v.as_str()) == Some("alice"))
        .unwrap()
        .clone();
    assert_eq!(alice.get("paused").and_then(|v| v.as_bool()), Some(false));
    assert!(alice.get("held").and_then(|v| v.as_i64()).unwrap() > 0);

    // Command: submit a new study from a manifest body; it appears in
    // the directory and runs.
    let spec = format!(
        r#"{{"command": "submit_study", "study": {{"name": "carol", "quota": 2, "config": {{
            "h_params": {{
              "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                      "type": "float", "p_range": [0.001, 0.2]}}
            }},
            "measure": "test/accuracy", "order": "descending", "step": 10,
            "population": 4, "tune": {{"random": {{}}}},
            "termination": {{"max_session_number": 4}},
            "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
            "seed": 300
        }}}}}}"#
    );
    let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", spec.as_bytes());
    expect_enveloped(s, &doc, "submit_study ack");
    platform.advance(200.0);
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies");
    let studies = expect_enveloped(s, &doc, "studies after submit");
    assert_eq!(studies.get("count").and_then(|v| v.as_usize()), Some(3));
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/studies/carol/sessions");
    let carol = expect_enveloped(s, &doc, "carol sessions");
    assert!(carol.get("total").and_then(|v| v.as_usize()).unwrap() > 0);

    // Oversubscribed submit is refused with a 400.
    let (s, doc) = call(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        br#"{"command": "submit_study", "study": {"name": "greedy", "quota": 99, "config": {
            "h_params": {}, "measure": "m", "order": "descending",
            "tune": {"random": {}}}}}"#,
    );
    assert_eq!(s, 400, "{doc}");

    server.stop();
}

/// Engine-level command replay: pause/resume inputs are part of the
/// snapshot, so a restored engine replays them and matches the original.
#[test]
fn engine_session_commands_replay_through_snapshot() {
    let drive = |engine: &mut SimEngine| {
        engine.run_until(3_000.0);
        let sid = engine.active_agents().next().unwrap().pools.live()[0];
        engine.pause_session(sid, 3_000.0).unwrap();
        engine.run_until(5_000.0);
        engine.resume_session(sid, 5_000.0).unwrap();
        engine.run_until(7_000.0);
    };
    let mut reference = SimEngine::new(setup(23), surrogate(23));
    drive(&mut reference);
    reference.run_to_completion();

    let mut original = SimEngine::new(setup(23), surrogate(23));
    drive(&mut original);
    let snap = original.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = SimEngine::restore(&snap, surrogate(23)).unwrap();
    assert_eq!(restored.now(), original.now());
    assert_eq!(restored.events_processed(), original.events_processed());
    restored.run_to_completion();
    original.run_to_completion();

    let key = |out: &chopt::coordinator::SimOutcome| {
        (
            out.best().map(|(_, _, m)| format!("{m:.12}")),
            out.end_time,
            out.events_processed,
        )
    };
    let a = key(&reference.into_outcome());
    let b = key(&original.into_outcome());
    let c = key(&restored.into_outcome());
    assert_eq!(a, b, "commands must not break determinism");
    assert_eq!(b, c, "restored run must replay the recorded commands");
}

// -- the unified RunSource surface: stored, replayed, pushed, authed ----

/// `call` with extra request headers (auth tests).
fn call_headers(
    addr: std::net::SocketAddr,
    inbox: &ApiInbox,
    api: &mut impl PlatformApi,
    method: &'static str,
    path: &str,
    headers: Vec<(String, String)>,
    body: &[u8],
) -> (u16, Json) {
    let path = path.to_string();
    let body = body.to_vec();
    let client = std::thread::spawn(move || {
        let hdrs: Vec<(&str, &str)> = headers
            .iter()
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        http_request_with_headers(addr, method, &path, &hdrs, &body).unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while !client.is_finished() && Instant::now() < deadline {
        inbox.serve_one(api, Duration::from_millis(20));
    }
    let (status, bytes) = client.join().unwrap();
    let doc = chopt::util::json::parse(&String::from_utf8(bytes).unwrap()).unwrap();
    (status, doc)
}

fn temp_run_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("chopt-api-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every documented single-study query, in both default and parameterized
/// forms — the per-endpoint parity checklist.
fn single_queries() -> Vec<ApiQuery> {
    vec![
        ApiQuery::Status,
        ApiQuery::Cluster { window: None },
        ApiQuery::Cluster {
            window: Some(3_600.0),
        },
        ApiQuery::Sessions {
            limit: usize::MAX,
            offset: 0,
        },
        ApiQuery::Sessions { limit: 2, offset: 1 },
        ApiQuery::Leaderboard { k: 5 },
        ApiQuery::Parallel,
        ApiQuery::Curves {
            limit: usize::MAX,
            offset: 0,
        },
        ApiQuery::Curves { limit: 3, offset: 2 },
    ]
}

/// The acceptance criterion pin: a run directory served through
/// `StoredRun` answers every documented v1 query with bytes identical to
/// the same run served live — envelope included.
#[test]
fn stored_run_serves_live_identical_bytes_per_endpoint() {
    let dir = temp_run_dir("parity");
    let snap_path = dir.join("snapshot.json");
    let seed = 61u64;
    let mut platform = Platform::new(setup(seed), surrogate(seed))
        .with_event_log(dir.join("events.jsonl"))
        .unwrap()
        .with_snapshots(&snap_path, 2_000.0);
    platform.run_until(6_000.0);
    platform.snapshot_now().unwrap();

    let stored = StoredRun::open_with(
        &dir,
        move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>,
        chopt::trainer::surrogate::default_multi_factory,
    )
    .unwrap();
    assert!(!stored.is_multi());
    assert_eq!(stored.generation(), platform.generation());

    for q in single_queries() {
        let live = envelope(platform.generation(), platform.query(&q).unwrap());
        let replayed = envelope(stored.generation(), stored.query(&q).unwrap());
        assert_eq!(
            live.to_string_compact(),
            replayed.to_string_compact(),
            "stored body diverged from live for {q:?}"
        );
    }

    // The recorded progress stream is exposed (ordered by virtual time)
    // for SSE replay.
    let lines = stored.event_lines();
    assert!(!lines.is_empty(), "single-run events.jsonl must surface");
    let ts: Vec<f64> = lines
        .iter()
        .map(|l| {
            chopt::util::json::parse(l)
                .unwrap()
                .get("t")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        })
        .collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "event replay must be time-ordered");

    // And the same bytes arrive over a real socket: serve the StoredRun
    // through the HTTP bridge and compare one endpoint end to end.
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();
    let mut source = stored;
    let (s, doc) = get(addr, &inbox, &mut source, "/api/v1/status");
    assert_eq!(s, 200);
    assert_eq!(
        doc.to_string_compact(),
        envelope(platform.generation(), platform.query(&ApiQuery::Status).unwrap())
            .to_string_compact(),
        "HTTP-served stored status must be byte-identical to live"
    );

    // Stored runs are read-only: commands are refused with an envelope
    // error naming the live alternative.
    let (s, doc) = call(
        addr,
        &inbox,
        &mut source,
        "POST",
        "/api/v1/commands",
        br#"{"command": "stop_session", "session": "4294967297"}"#,
    );
    assert_eq!(s, 400, "{doc}");
    let err = doc.get("error").and_then(|v| v.as_str()).unwrap();
    assert!(err.contains("read-only"), "{err}");

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Multi-study parity: the same checklist over a multi run directory.
#[test]
fn stored_multi_run_serves_live_identical_bytes() {
    let dir = temp_run_dir("parity-multi");
    let snap_path = dir.join("snapshot.json");
    let mut platform = MultiPlatform::new(multi_manifest(), multi_trainer)
        .with_event_logs(&dir)
        .unwrap()
        .with_snapshots(&snap_path, 2_000.0);
    platform.run_until(5_000.0);
    platform.snapshot_now().unwrap();

    let stored = StoredRun::open_with(
        &dir,
        chopt::trainer::surrogate::default_factory,
        multi_trainer,
    )
    .unwrap();
    assert!(stored.is_multi());
    assert_eq!(stored.generation(), platform.generation());

    let queries = vec![
        ApiQuery::Status,
        ApiQuery::Cluster { window: None },
        ApiQuery::Cluster {
            window: Some(1_800.0),
        },
        ApiQuery::FairShare,
        ApiQuery::Studies,
        ApiQuery::StudySessions {
            study: "alice".into(),
            limit: usize::MAX,
            offset: 0,
        },
        ApiQuery::StudyLeaderboard {
            study: "alice".into(),
            k: 5,
        },
        ApiQuery::StudyParallel {
            study: "alice".into(),
        },
        ApiQuery::StudyCurves {
            study: "bob".into(),
            limit: 4,
            offset: 0,
        },
    ];
    for q in queries {
        let live = envelope(platform.generation(), platform.query(&q).unwrap());
        let replayed = envelope(stored.generation(), stored.query(&q).unwrap());
        assert_eq!(
            live.to_string_compact(),
            replayed.to_string_compact(),
            "stored body diverged from live for {q:?}"
        );
    }

    // The merged replay stream is time-ordered and study-labelled.
    let lines = stored.event_lines();
    assert!(!lines.is_empty());
    let docs: Vec<Json> = lines
        .iter()
        .map(|l| chopt::util::json::parse(l).unwrap())
        .collect();
    assert!(docs
        .windows(2)
        .all(|w| w[0].get("t").unwrap().as_f64() <= w[1].get("t").unwrap().as_f64()));
    assert!(docs
        .iter()
        .all(|d| d.get("study").and_then(|v| v.as_str()).is_some()));

    // Scrubbing is single-study only — a clear 400, not a panic.
    let err = stored
        .query_at(&ApiQuery::Status, 10)
        .expect_err("multi scrub must be refused");
    assert_eq!(err.http_status(), 400);

    let _ = std::fs::remove_dir_all(&dir);
}

/// `?at_event=N` replay scrubbing is deterministic: the same position
/// yields the same bytes no matter the scrub order, positions cap at the
/// snapshot's end, and the envelope reports the replayed event count.
#[test]
fn at_event_scrubbing_is_deterministic() {
    let seed = 67u64;
    let mut engine = SimEngine::new(setup(seed), surrogate(seed));
    engine.run_until(6_000.0);
    let snap =
        chopt::util::json::parse(&engine.snapshot_json().to_string_pretty()).unwrap();

    let rs = ReplaySource::new(snap.clone(), move |id| {
        Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>
    })
    .unwrap();
    let target = rs.target();
    assert_eq!(target, engine.events_processed());
    let mid = target / 2;

    let (g1, status_mid) = rs.query_at(&ApiQuery::Status, mid).unwrap();
    assert_eq!(g1, mid);
    assert_eq!(
        status_mid.get("events_processed").and_then(|v| v.as_i64()),
        Some(mid as i64),
        "scrubbed status must reflect the replayed position"
    );
    let (_, sessions_mid) = rs
        .query_at(&ApiQuery::Sessions { limit: usize::MAX, offset: 0 }, mid)
        .unwrap();

    // Scrub forward to the end, then back: bytes identical to the first
    // visit (replay determinism).
    let (g_end, status_end) = rs.query_at(&ApiQuery::Status, target + 999).unwrap();
    assert_eq!(g_end, target, "positions cap at the snapshot end");
    assert_ne!(
        status_mid.to_string_compact(),
        status_end.to_string_compact(),
        "different positions must observe different states"
    );
    let (_, status_mid2) = rs.query_at(&ApiQuery::Status, mid).unwrap();
    let (_, sessions_mid2) = rs
        .query_at(&ApiQuery::Sessions { limit: usize::MAX, offset: 0 }, mid)
        .unwrap();
    assert_eq!(status_mid.to_string_compact(), status_mid2.to_string_compact());
    assert_eq!(
        sessions_mid.to_string_compact(),
        sessions_mid2.to_string_compact()
    );

    // End-to-end over HTTP through a StoredRun: the envelope's
    // generated_at_event is the scrub position.
    let dir = temp_run_dir("scrub");
    std::fs::write(dir.join("snapshot.json"), snap.to_string_pretty()).unwrap();
    let mut stored = StoredRun::open_with(
        &dir,
        move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>,
        chopt::trainer::surrogate::default_multi_factory,
    )
    .unwrap();
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();
    let (s, doc) = get(
        addr,
        &inbox,
        &mut stored,
        &format!("/api/v1/status?at_event={mid}"),
    );
    assert_eq!(s, 200, "{doc}");
    assert_eq!(
        doc.get("generated_at_event").and_then(|v| v.as_str()),
        Some(mid.to_string().as_str())
    );
    assert_eq!(
        doc.get("data").unwrap().to_string_compact(),
        status_mid.to_string_compact(),
        "HTTP scrub must serve the same bytes as the direct ReplaySource"
    );
    // A live server cannot rewind: at_event there is a 400.
    let mut live = Platform::new(setup(seed), surrogate(seed));
    live.run_until(1_000.0);
    let (s, doc) = get(addr, &inbox, &mut live, "/api/v1/status?at_event=1");
    assert_eq!(s, 400, "{doc}");

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Raw SSE client: sends the request (optionally with Last-Event-ID) and
/// reads until every needle appears or the deadline passes.
fn read_sse(
    addr: std::net::SocketAddr,
    last_event_id: Option<u64>,
    needles: &[&str],
    deadline: Duration,
) -> String {
    read_sse_at(addr, "/api/v1/events", last_event_id, needles, deadline)
}

/// [`read_sse`] against an explicit path (`?since=` tests).
fn read_sse_at(
    addr: std::net::SocketAddr,
    path: &str,
    last_event_id: Option<u64>,
    needles: &[&str],
    deadline: Duration,
) -> String {
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let extra = last_event_id
        .map(|id| format!("Last-Event-ID: {id}\r\n"))
        .unwrap_or_default();
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: localhost\r\nAccept: text/event-stream\r\n{extra}Connection: close\r\n\r\n"
    )
    .unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .unwrap();
    let end = Instant::now() + deadline;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        let text = String::from_utf8_lossy(&buf);
        if needles.iter().all(|n| text.contains(n)) || Instant::now() >= end {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => break,
        }
    }
    String::from_utf8_lossy(&buf).to_string()
}

/// The acceptance criterion pin for push: `GET /api/v1/events` streams
/// real progress events plus heartbeats over a real socket, and a
/// reconnect with `Last-Event-ID` resumes after the cursor.
#[test]
fn sse_stream_pushes_progress_heartbeats_and_resumes() {
    // Real progress: the platform publishes its event stream into the feed.
    let feed = EventFeed::new(4_096);
    let mut platform = Platform::new(setup(71), surrogate(71)).with_progress_feed(feed.clone());
    platform.run_until(2_000.0);
    assert!(
        feed.last_seq() >= 2,
        "the run must publish progress events (got {})",
        feed.last_seq()
    );

    let server = VizServer::start(0, Routes::new()).unwrap();
    server.serve_events(feed.clone(), Duration::from_millis(80));
    let addr = server.addr();

    // Fresh connect: SSE headers, the first recorded event, and a
    // heartbeat once the feed idles.
    let text = read_sse(
        addr,
        None,
        &["text/event-stream", "id: 1\ndata: ", ": heartbeat"],
        Duration::from_secs(10),
    );
    assert!(text.contains("text/event-stream"), "{text}");
    assert!(text.contains("id: 1\ndata: "), "{text}");
    assert!(
        text.contains(r#""ev""#),
        "frames must carry the progress JSON records: {text}"
    );
    assert!(text.contains(": heartbeat"), "{text}");

    // Reconnect with Last-Event-ID: the stream resumes after the cursor
    // instead of replaying from the start.
    let text = read_sse(addr, Some(1), &["id: 2\ndata: "], Duration::from_secs(10));
    assert!(text.contains("id: 2\ndata: "), "{text}");
    assert!(
        !text.contains("id: 1\ndata: "),
        "resumed stream must not replay event 1: {text}"
    );

    // A fresh progress event published mid-stream is pushed to an open
    // connection (no polling involved).
    let before = feed.last_seq();
    let opened = std::thread::spawn(move || {
        read_sse(
            addr,
            Some(before),
            &[&format!("id: {}\ndata: ", before + 1)],
            Duration::from_secs(10),
        )
    });
    std::thread::sleep(Duration::from_millis(150));
    feed.publish_json(&Json::obj().with("ev", Json::Str("poke".into())));
    let text = opened.join().unwrap();
    assert!(
        text.contains(&format!("id: {}\ndata: ", before + 1)),
        "published event must be pushed to the open stream: {text}"
    );

    server.stop();
}

/// Command auth: with a token configured, the read side stays open while
/// POST /api/v1/commands answers 401 (missing credentials) / 403 (wrong
/// token) in the envelope error format, and the right token goes
/// through to the engine loop.
#[test]
fn command_surface_enforces_bearer_token() {
    let mut platform = Platform::new(setup(73), surrogate(73));
    platform.run_until(3_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    server.set_api_token(Some("sekrit".into()));
    let inbox = server.enable_api();
    let addr = server.addr();

    // Reads are open — no credentials needed.
    let (s, doc) = get(addr, &inbox, &mut platform, "/api/v1/status");
    expect_enveloped(s, &doc, "status without credentials");

    let sid = platform
        .engine()
        .active_agents()
        .next()
        .unwrap()
        .pools
        .live()[0];
    let body = format!(r#"{{"command": "pause_session", "session": "{}"}}"#, sid.0);

    // Missing credentials → 401, envelope-shaped.
    let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", body.as_bytes());
    assert_eq!(s, 401, "{doc}");
    assert_eq!(doc.get("schema_version").and_then(|v| v.as_f64()), Some(1.0));
    assert!(doc.get("error").and_then(|v| v.as_str()).unwrap().contains("Bearer"));

    // Wrong token → 403.
    let (s, doc) = call_headers(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        vec![("Authorization".into(), "Bearer wrong".into())],
        body.as_bytes(),
    );
    assert_eq!(s, 403, "{doc}");
    assert!(doc.get("error").is_some());

    // Right token → the command reaches the engine and is acked.
    let (s, doc) = call_headers(
        addr,
        &inbox,
        &mut platform,
        "POST",
        "/api/v1/commands",
        vec![("Authorization".into(), "Bearer sekrit".into())],
        body.as_bytes(),
    );
    let ack = expect_enveloped(s, &doc, "authorized pause");
    assert_eq!(ack.get("applied").and_then(|v| v.as_bool()), Some(true));

    server.stop();
}

// -- read-side scale: response cache, ETag/304, SSE history replay -----

/// `call` returning the raw response head as well (ETag / X-Cache
/// assertions) while pumping the inbox from this thread.
fn call_full(
    addr: std::net::SocketAddr,
    inbox: &ApiInbox,
    api: &mut impl PlatformApi,
    method: &'static str,
    path: &str,
    headers: Vec<(String, String)>,
    body: &[u8],
) -> (u16, String, Vec<u8>) {
    let path = path.to_string();
    let body = body.to_vec();
    let client = std::thread::spawn(move || {
        let hdrs: Vec<(&str, &str)> = headers
            .iter()
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        http_request_full(addr, method, &path, &hdrs, &body).unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while !client.is_finished() && Instant::now() < deadline {
        inbox.serve_one(api, Duration::from_millis(20));
    }
    client.join().unwrap()
}

fn header_value(head: &str, name: &str) -> Option<String> {
    head.lines().find_map(|l| {
        let (k, v) = l.split_once(':')?;
        if k.eq_ignore_ascii_case(name) {
            Some(v.trim().to_string())
        } else {
            None
        }
    })
}

/// The tentpole acceptance pin: at a fixed generation every v1 query is
/// served from the response cache after first touch, with bodies
/// byte-identical to the freshly rendered ones; a command bumps the
/// epoch and an engine tick bumps the generation, and either implicitly
/// drops the whole read surface out of cache — no stale bytes, ever.
#[test]
fn v1_read_cache_serves_identical_bytes_and_tracks_generation() {
    let mut platform = Platform::new(setup(83), surrogate(83));
    platform.run_until(4_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    platform.set_generation_gauge(inbox.generation_gauge());
    let addr = server.addr();

    let paths = [
        "/api/v1/status",
        "/api/v1/cluster?window=3600",
        "/api/v1/sessions",
        "/api/v1/sessions?limit=2&offset=1",
        "/api/v1/leaderboard?k=5",
        "/api/v1/parallel",
        "/api/v1/curves?limit=3&offset=0",
    ];
    for path in paths {
        let (s1, h1, b1) = call_full(addr, &inbox, &mut platform, "GET", path, vec![], b"");
        let (s2, h2, b2) = call_full(addr, &inbox, &mut platform, "GET", path, vec![], b"");
        assert_eq!((s1, s2), (200, 200), "{path}");
        assert_eq!(
            header_value(&h1, "X-Cache").as_deref(),
            Some("miss"),
            "{path}: first GET renders"
        );
        assert_eq!(
            header_value(&h2, "X-Cache").as_deref(),
            Some("hit"),
            "{path}: repeat GET at a fixed generation must be cache-resident"
        );
        assert_eq!(
            b1, b2,
            "{path}: cached body must be byte-identical to the rendered one"
        );
        assert_eq!(header_value(&h1, "ETag"), header_value(&h2, "ETag"), "{path}");
        assert_eq!(
            header_value(&h2, "Cache-Control").as_deref(),
            Some("no-cache"),
            "{path}: clients must revalidate, not reuse blindly"
        );
    }

    let (_, h0, b0) = call_full(addr, &inbox, &mut platform, "GET", "/api/v1/status", vec![], b"");
    assert_eq!(header_value(&h0, "X-Cache").as_deref(), Some("hit"));
    let gen_of = |bytes: &[u8]| {
        chopt::util::json::parse(&String::from_utf8(bytes.to_vec()).unwrap())
            .unwrap()
            .get("generated_at_event")
            .and_then(|v| v.as_str())
            .unwrap()
            .parse::<u64>()
            .unwrap()
    };
    let gen0 = gen_of(&b0);

    // An accepted command bumps the command epoch: the next GET misses
    // even before the engine ticks (set_quota-style mutations don't
    // advance the event counter, so the epoch is what catches them).
    let sid = platform.engine().active_agents().next().unwrap().pools.live()[0];
    let body = format!(r#"{{"command": "pause_session", "session": "{}"}}"#, sid.0);
    let (s, doc) = call(addr, &inbox, &mut platform, "POST", "/api/v1/commands", body.as_bytes());
    expect_enveloped(s, &doc, "pause ack");
    let (s, h1, _) = call_full(addr, &inbox, &mut platform, "GET", "/api/v1/status", vec![], b"");
    assert_eq!(s, 200);
    assert_eq!(
        header_value(&h1, "X-Cache").as_deref(),
        Some("miss"),
        "a successful command must drop the read surface out of cache"
    );

    // An engine tick bumps the generation: miss again, fresh body.
    platform.advance(600.0);
    let (s, h2, b2) = call_full(addr, &inbox, &mut platform, "GET", "/api/v1/status", vec![], b"");
    assert_eq!(s, 200);
    assert_eq!(
        header_value(&h2, "X-Cache").as_deref(),
        Some("miss"),
        "a new generation must not reuse the previous tick's bytes"
    );
    let gen2 = gen_of(&b2);
    assert!(gen2 > gen0, "generation must move forward ({gen0} -> {gen2})");

    server.stop();
}

/// Multi-study endpoints go through the same cache: miss → hit with
/// byte-identical bodies on every documented path.
#[test]
fn v1_read_cache_covers_multi_study_endpoints() {
    let mut platform = MultiPlatform::new(multi_manifest(), multi_trainer);
    platform.run_until(2_500.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    platform.set_generation_gauge(inbox.generation_gauge());
    let addr = server.addr();

    for path in [
        "/api/v1/status",
        "/api/v1/cluster?window=1800",
        "/api/v1/fair_share",
        "/api/v1/studies",
        "/api/v1/studies/alice/sessions?limit=3",
        "/api/v1/studies/alice/leaderboard?k=3",
        "/api/v1/studies/alice/parallel",
        "/api/v1/studies/bob/curves?limit=2&offset=0",
    ] {
        let (s1, h1, b1) = call_full(addr, &inbox, &mut platform, "GET", path, vec![], b"");
        let (s2, h2, b2) = call_full(addr, &inbox, &mut platform, "GET", path, vec![], b"");
        assert_eq!((s1, s2), (200, 200), "{path}");
        assert_eq!(header_value(&h1, "X-Cache").as_deref(), Some("miss"), "{path}");
        assert_eq!(header_value(&h2, "X-Cache").as_deref(), Some("hit"), "{path}");
        assert_eq!(b1, b2, "{path}: cached bytes diverged");
    }
    // Errors are never cached: an unknown study misses every time.
    let (s, h, _) = call_full(
        addr,
        &inbox,
        &mut platform,
        "GET",
        "/api/v1/studies/nobody/sessions",
        vec![],
        b"",
    );
    assert_eq!(s, 404);
    assert!(header_value(&h, "X-Cache").is_none(), "errors must not carry cache headers");

    server.stop();
}

/// ETag round-trip: a 200 carries a strong validator, If-None-Match on
/// the same entity answers a bodyless 304 (no re-render, no copy), and
/// after an engine tick the stale validator gets a fresh 200 with a new
/// ETag.
#[test]
fn v1_etag_if_none_match_round_trip() {
    let mut platform = Platform::new(setup(89), surrogate(89));
    platform.run_until(3_000.0);
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    platform.set_generation_gauge(inbox.generation_gauge());
    let addr = server.addr();
    let path = "/api/v1/leaderboard?k=3";

    let (s, head, body) = call_full(addr, &inbox, &mut platform, "GET", path, vec![], b"");
    assert_eq!(s, 200);
    let etag = header_value(&head, "ETag").expect("200 queries carry an ETag");
    assert!(
        etag.starts_with('"') && etag.ends_with('"') && !etag.starts_with("W/"),
        "ETag must be a strong validator: {etag}"
    );
    assert!(!body.is_empty());

    // Same entity → 304, empty body, validator echoed.
    let (s, head304, body304) = call_full(
        addr,
        &inbox,
        &mut platform,
        "GET",
        path,
        vec![("If-None-Match".into(), etag.clone())],
        b"",
    );
    assert_eq!(s, 304, "{head304}");
    assert!(body304.is_empty(), "304 must not carry a body");
    assert_eq!(header_value(&head304, "ETag"), Some(etag.clone()));

    // The engine ticks → new generation → the old validator is stale.
    platform.advance(2_000.0);
    let (s, head2, body2) = call_full(
        addr,
        &inbox,
        &mut platform,
        "GET",
        path,
        vec![("If-None-Match".into(), etag.clone())],
        b"",
    );
    assert_eq!(s, 200, "stale validator must re-render");
    assert!(!body2.is_empty());
    let etag2 = header_value(&head2, "ETag").unwrap();
    assert_ne!(etag, etag2, "a new generation must mint a new ETag");

    server.stop();
}

/// `?at_event=` scrub results are pinned cache entries: distinct targets
/// never share an entry, repeats hit with identical bytes, and the whole
/// fixed-generation stored surface is cache-resident after first touch.
#[test]
fn at_event_scrub_cache_entries_are_pinned_and_distinct() {
    let seed = 97u64;
    let mut engine = SimEngine::new(setup(seed), surrogate(seed));
    engine.run_until(6_000.0);
    let target = engine.events_processed();
    assert!(target >= 4, "need a few events to scrub over (got {target})");
    let snap = chopt::util::json::parse(&engine.snapshot_json().to_string_pretty()).unwrap();
    let dir = temp_run_dir("scrub-cache");
    std::fs::write(dir.join("snapshot.json"), snap.to_string_pretty()).unwrap();
    let mut stored = StoredRun::open_with(
        &dir,
        move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>,
        chopt::trainer::surrogate::default_multi_factory,
    )
    .unwrap();
    let server = VizServer::start(0, Routes::new()).unwrap();
    let inbox = server.enable_api();
    let addr = server.addr();

    let (m1, m2) = (target / 2, target / 4);
    assert_ne!(m1, m2);
    let p1 = format!("/api/v1/status?at_event={m1}");
    let p2 = format!("/api/v1/status?at_event={m2}");

    let (s, h1a, b1a) = call_full(addr, &inbox, &mut stored, "GET", &p1, vec![], b"");
    assert_eq!(s, 200);
    assert_eq!(header_value(&h1a, "X-Cache").as_deref(), Some("miss"));
    let (s, h1b, b1b) = call_full(addr, &inbox, &mut stored, "GET", &p1, vec![], b"");
    assert_eq!(s, 200);
    assert_eq!(
        header_value(&h1b, "X-Cache").as_deref(),
        Some("hit"),
        "a repeated scrub target must not replay again"
    );
    assert_eq!(b1a, b1b, "pinned entry bytes diverged");

    // A different target is a different entity: own entry, own ETag.
    let (s, h2, b2) = call_full(addr, &inbox, &mut stored, "GET", &p2, vec![], b"");
    assert_eq!(s, 200);
    assert_eq!(
        header_value(&h2, "X-Cache").as_deref(),
        Some("miss"),
        "distinct at_event targets must never share a cache entry"
    );
    assert_ne!(b1a, b2, "different positions must observe different states");
    assert_ne!(header_value(&h1a, "ETag"), header_value(&h2, "ETag"));

    // Conditional scrub: 304 against the pinned validator.
    let etag = header_value(&h1a, "ETag").unwrap();
    let (s, _, body) = call_full(
        addr,
        &inbox,
        &mut stored,
        "GET",
        &p1,
        vec![("If-None-Match".into(), etag)],
        b"",
    );
    assert_eq!(s, 304);
    assert!(body.is_empty());

    // Stored runs have a fixed generation: the plain read surface is
    // cache-resident after one touch, no gauge wiring involved.
    let (_, ha, ba) = call_full(addr, &inbox, &mut stored, "GET", "/api/v1/status", vec![], b"");
    let (_, hb, bb) = call_full(addr, &inbox, &mut stored, "GET", "/api/v1/status", vec![], b"");
    assert_eq!(header_value(&ha, "X-Cache").as_deref(), Some("miss"));
    assert_eq!(header_value(&hb, "X-Cache").as_deref(), Some("hit"));
    assert_eq!(ba, bb);

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `?since=<seq>` (and a `Last-Event-ID` resume that fell behind the
/// ring) replays the recorded history log before switching to the live
/// feed — no dropped-events notice when the history covers the gap.
#[test]
fn sse_since_replays_history_below_the_ring_window() {
    let dir = temp_run_dir("sse-hist");
    // Tiny ring: after six publishes only 5..6 are retained in memory.
    let feed = EventFeed::with_history(2, dir.join("events.jsonl")).unwrap();
    for i in 1..=6 {
        feed.publish(format!(r#"{{"ev":"e{i}"}}"#));
    }
    assert_eq!(feed.last_seq(), 6);
    let server = VizServer::start(0, Routes::new()).unwrap();
    server.serve_events(feed.clone(), Duration::from_millis(80));
    let addr = server.addr();

    // ?since=0 tails the full recorded stream from disk, then the ring.
    let text = read_sse_at(
        addr,
        "/api/v1/events?since=0",
        None,
        &["id: 6\ndata: "],
        Duration::from_secs(10),
    );
    for i in 1..=6 {
        assert!(
            text.contains(&format!("id: {i}\ndata: ")),
            "history replay must cover seq {i}: {text}"
        );
    }
    assert!(
        !text.contains("dropped"),
        "history covers the gap — no drop notice expected: {text}"
    );

    // Last-Event-ID below the retention window reuses the same path.
    let text = read_sse(addr, Some(2), &["id: 6\ndata: "], Duration::from_secs(10));
    for i in 3..=6 {
        assert!(text.contains(&format!("id: {i}\ndata: ")), "{text}");
    }
    assert!(!text.contains("id: 2\ndata: "), "resume must start after the cursor: {text}");
    assert!(!text.contains("dropped"), "{text}");

    // An explicit ?since= wins over the reconnect header.
    let text = read_sse_at(
        addr,
        "/api/v1/events?since=5",
        Some(0),
        &["id: 6\ndata: "],
        Duration::from_secs(10),
    );
    assert!(text.contains("id: 6\ndata: "), "{text}");
    assert!(!text.contains("id: 5\ndata: "), "?since must override Last-Event-ID: {text}");

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
