//! Integration tests over the full coordinator stack (surrogate-backed):
//! multi-session scheduling, Stop-and-Go under external load, pool
//! invariants across a whole run, and property tests on the composed
//! system.

use chopt::cluster::ExternalLoadTrace;
use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, AgentEvent, RetryPolicy, SimSetup, StopAndGoPolicy};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::util::proptest::{check, Config as PropConfig};

fn cfg(tune: &str, step: i64, max_sessions: usize, max_gpus: usize, seed: u64) -> ChoptConfig {
    let text = format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}},
            "momentum": {{"parameters": [0.5, 0.99], "distribution": "uniform",
                    "type": "float", "p_range": [0.1, 0.999]}},
            "depth": {{"parameters": [20, 140], "distribution": "uniform",
                    "type": "int", "p_range": [20, 140]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": {step},
          "population": 4,
          "tune": {tune},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "surrogate:resnet",
          "max_epochs": 60,
          "max_gpus": {max_gpus},
          "seed": {seed}
        }}"#
    );
    ChoptConfig::from_json_str(&text).unwrap()
}

fn surrogate(seed: u64) -> impl FnMut(u64) -> Box<dyn Trainer> {
    move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>
}

#[test]
fn two_chopt_sessions_share_cluster_via_queue() {
    let setup = SimSetup {
        cluster_gpus: 6,
        configs: vec![
            cfg("{\"random\": {}}", 10, 8, 3, 1),
            cfg(
                "{\"pbt\": {\"exploit\": \"truncation\", \"explore\": \"perturb\"}}",
                10,
                10,
                3,
                2,
            ),
        ],
        submit_times: Vec::new(),
        agent_slots: 2,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: Vec::new(),
        scenario: None,
        retry: RetryPolicy::default(),
    };
    let out = run_sim(setup, surrogate(7));
    assert_eq!(out.agents.len(), 2);
    for a in &out.agents {
        assert!(a.finished, "agent {} unfinished", a.id);
        a.pools.check_invariants().unwrap();
        assert!(a.best().is_some());
    }
    // Cluster never oversubscribed.
    let peak = out
        .cluster
        .usage_total
        .series
        .iter()
        .map(|&(_, v)| v)
        .fold(0.0, f64::max);
    assert!(peak <= 6.0);
}

#[test]
fn queued_sessions_wait_for_free_slot() {
    // 3 configs, 1 agent slot: they must run sequentially, all finishing.
    let setup = SimSetup {
        cluster_gpus: 4,
        configs: vec![
            cfg("{\"random\": {}}", 10, 5, 4, 3),
            cfg("{\"random\": {}}", 10, 5, 4, 4),
            cfg("{\"random\": {}}", 10, 5, 4, 5),
        ],
        submit_times: Vec::new(),
        agent_slots: 1,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: Vec::new(),
        scenario: None,
        retry: RetryPolicy::default(),
    };
    let out = run_sim(setup, surrogate(9));
    assert_eq!(out.agents.len(), 3);
    assert!(out.agents.iter().all(|a| a.finished));
    // Distinct CHOPT ids assigned in order.
    let mut ids: Vec<u64> = out.agents.iter().map(|a| a.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3]);
}

#[test]
fn stop_and_go_preempts_under_external_surge() {
    // Small cluster + fig8 trace: during zone D the external demand
    // forces preemptions; during zone C CHOPT gets bonus GPUs.
    let horizon = 40_000.0;
    let setup = SimSetup {
        cluster_gpus: 8,
        configs: vec![cfg("{\"random\": {}}", 5, 200, 4, 6)],
        submit_times: Vec::new(),
        agent_slots: 1,
        trace: Some(ExternalLoadTrace::fig8(8, horizon, 11)),
        policy: StopAndGoPolicy::default(),
        master_period: 120.0,
        horizon,
        failures: Vec::new(),
        scenario: None,
        retry: RetryPolicy::default(),
    };
    let out = run_sim(setup, surrogate(20));
    let a = &out.agents[0];
    let preemptions = a
        .events
        .iter()
        .filter(|e| matches!(e, AgentEvent::Preempted(..)))
        .count();
    let revivals = a
        .events
        .iter()
        .filter(|e| matches!(e, AgentEvent::Revived(_)))
        .count();
    assert!(preemptions > 0, "zone D must preempt something");
    assert!(revivals > 0, "freed GPUs must revive stopped sessions");
    a.pools.check_invariants().unwrap();
    // CHOPT allocation must exceed its base limit at some point (zone C
    // bonus) — the Fig. 8 effect.
    let peak_chopt = out
        .cluster
        .usage_chopt
        .series
        .iter()
        .map(|&(_, v)| v)
        .fold(0.0, f64::max);
    assert!(peak_chopt > 4.0, "bonus GPUs never granted: peak {peak_chopt}");
}

#[test]
fn dead_pool_reclaims_trainer_state() {
    let c = cfg("{\"random\": {}}", 3, 40, 4, 12);
    let out = run_sim(SimSetup::single(c, 4), surrogate(31));
    let a = &out.agents[0];
    assert!(a.pools.dead_count() > 0, "with stop_ratio 0.5 some must die");
    // Dead sessions must have no trainer state left.
    assert_eq!(
        a.trainer.state_count(),
        a.created - a.pools.dead_count(),
        "state_count must equal non-dead sessions"
    );
}

#[test]
fn performance_threshold_terminates_early() {
    let mut c = cfg("{\"random\": {}}", 10, 100000, 4, 13);
    c.termination.max_session_number = None;
    c.termination.performance_threshold = Some(70.0);
    let out = run_sim(SimSetup::single(c, 4), surrogate(32));
    let a = &out.agents[0];
    assert!(a.finished);
    assert!(
        a.events
            .iter()
            .any(|e| matches!(e, AgentEvent::Terminated("performance_threshold"))),
        "events: {:?}",
        a.events.last()
    );
    let (_, best) = a.best().unwrap();
    assert!(best >= 70.0);
}

#[test]
fn time_termination_bounds_virtual_clock() {
    let mut c = cfg("{\"random\": {}}", 10, 1000000, 2, 14);
    c.termination.max_session_number = None;
    c.termination.time_hours = Some(5.0);
    let out = run_sim(SimSetup::single(c, 2), surrogate(33));
    assert!(out.agents[0].finished);
    // One master period of slack allowed.
    assert!(out.end_time <= 5.0 * 3600.0 + 120.0, "end {}", out.end_time);
}

#[test]
fn election_term_advances() {
    let c = cfg("{\"random\": {}}", 10, 4, 2, 15);
    let out = run_sim(SimSetup::single(c, 2), surrogate(34));
    assert!(out.election.term() >= 1);
}

#[test]
fn master_agent_failure_fails_over_and_quarantines_past_budget() {
    // Two agent slots; slot 0 (the initial master) crashes mid-run with a
    // zero-attempt retry budget, so the crash quarantines it immediately.
    // The election must fail over (term bump), the quarantined agent's
    // GPUs must be released (work parked, not silently lost), and the
    // surviving CHOPT session must still finish.
    let setup = SimSetup {
        cluster_gpus: 6,
        configs: vec![
            cfg("{\"random\": {}}", 5, 5000, 3, 1), // long-runner (slot 0)
            cfg("{\"random\": {}}", 10, 12, 3, 2),
        ],
        submit_times: Vec::new(),
        agent_slots: 2,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: vec![(20_000.0, 0)],
        scenario: None,
        retry: RetryPolicy {
            max_attempts: 0,
            ..RetryPolicy::default()
        },
    };
    let out = run_sim(setup, surrogate(55));
    assert!(
        out.election.term() >= 2,
        "leadership must have changed hands: term {}",
        out.election.term()
    );
    assert!(!out.election.is_leader(0), "slot 0 must not lead after crash");
    // The crashed agent was quarantined; the other finished normally.
    let crashed = out
        .agents
        .iter()
        .find(|a| a.events.contains(&AgentEvent::Terminated("quarantined")))
        .expect("one agent must have been quarantined");
    assert!(crashed.finished_at.is_some());
    let survivor = out
        .agents
        .iter()
        .find(|a| !a.events.contains(&AgentEvent::Terminated("quarantined")))
        .expect("one agent must survive");
    assert!(survivor.finished);
    assert!(survivor.best().is_some());
    // All GPUs returned to the cluster at the end.
    assert_eq!(out.cluster.held_by_chopt(), 0, "crashed agent leaked GPUs");
}

#[test]
fn crashed_agent_recovers_and_finishes() {
    // Default retry budget: an injected crash pauses the agent's live
    // sessions into the stop pool, the slot backs off, and the agent
    // restarts and runs its study to completion — no work lost, no
    // `agent_failure` abort.
    let setup = SimSetup {
        cluster_gpus: 6,
        configs: vec![
            cfg("{\"random\": {}}", 10, 12, 3, 1),
            cfg("{\"random\": {}}", 10, 12, 3, 2),
        ],
        submit_times: Vec::new(),
        agent_slots: 2,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: vec![(2_000.0, 0)],
        scenario: None,
        retry: RetryPolicy::default(),
    };
    let out = run_sim(setup, surrogate(56));
    assert_eq!(out.agents.len(), 2);
    for a in &out.agents {
        assert!(a.finished, "agent {} must finish after recovery", a.id);
        assert!(
            !a.events.iter().any(|e| matches!(
                e,
                AgentEvent::Terminated("agent_failure") | AgentEvent::Terminated("quarantined")
            )),
            "no agent may be aborted under the retry budget"
        );
        a.pools.check_invariants().unwrap();
    }
    assert_eq!(out.cluster.held_by_chopt(), 0);
}

/// Property: for random configs and cluster sizes, the composed system
/// terminates, never oversubscribes GPUs, keeps pool exclusivity, and
/// the best measure stays in the surrogate's physical range.
#[test]
fn prop_sim_safety() {
    check(
        "sim-safety",
        PropConfig {
            cases: 12,
            max_size: 24,
            seed: 0xBEEF,
        },
        |rng, size| {
            let gpus = 1 + rng.index(8);
            let max_sessions = 2 + rng.index(size.max(2));
            let step = [3, 7, 10, -1][rng.index(4)];
            let tune = ["{\"random\": {}}",
                "{\"pbt\": {\"exploit\": \"truncation\", \"explore\": \"perturb\"}}"]
                [rng.index(2)];
            let c = cfg(tune, step, max_sessions, 1 + rng.index(4), rng.next_u64() % 1000);
            let out = run_sim(SimSetup::single(c, gpus), surrogate(rng.next_u64()));
            let a = &out.agents[0];
            a.pools.check_invariants()?;
            let peak = out
                .cluster
                .usage_total
                .series
                .iter()
                .map(|&(_, v)| v)
                .fold(0.0, f64::max);
            if peak > gpus as f64 {
                return Err(format!("oversubscribed: peak {peak} > {gpus}"));
            }
            if let Some((_, best)) = a.best() {
                if !(0.0..=100.0).contains(&best) {
                    return Err(format!("measure out of range: {best}"));
                }
            }
            if !a.finished {
                return Err("agent did not finish".into());
            }
            Ok(())
        },
    );
}
