//! Property test for the sharded control plane contract: `--shards N`
//! is purely a topology knob.  For every seed × shard count × queue
//! submission order we run the same borrow-free multi-study workload
//! (four manifest studies plus two studies admitted mid-run through the
//! submission queue) behind a [`FanoutSource`] and assert the merged
//! observables are **bit-identical** to a single-scheduler run driven
//! with the same admission splits:
//!
//! * the per-study `events-<name>.jsonl` logs (raw file bytes),
//! * the merged `fair_share` / `studies` documents and every per-study
//!   `/api/v1` document (compact JSON bytes),
//! * the `status` document after zeroing `events_processed` — the one
//!   documented divergence (master-tick events replicate per shard, so
//!   the merged count is a sum),
//! * the merged SSE feed (byte-equal across shard counts — its
//!   canonical `(t, slot)` order is shard-count-invariant),
//! * a composite snapshot restored by replay, and `?at_event=`
//!   scrubbing to the final barrier mark.
//!
//! The single scheduler is the specification; the fan-out's partition /
//! ledger / merge machinery must be indistinguishable from it
//! everywhere a dashboard can look.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use chopt::coordinator::{MultiPlatform, StudyManifest, StudySpec};
use chopt::trainer::surrogate::default_multi_factory;
use chopt::util::json::{parse, Value as Json};
use chopt::viz::api::{ApiQuery, RunSource};
use chopt::viz::fanout::{FanoutConfig, FanoutSource, TrainerFactory};
use chopt::viz::sse::EventFeed;

const CHUNK: f64 = 2_000.0;

fn study_json(name: &str, quota: usize, seed: u64) -> String {
    format!(
        r#"{{"name": "{name}", "quota": {quota}, "config": {{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}}
          }},
          "measure": "test/accuracy", "order": "descending", "step": 10,
          "population": 3, "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": 6}},
          "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
          "seed": {seed}
        }}}}"#
    )
}

/// Four tenants on 12 GPUs (hard isolation — sharding requires
/// `borrow: false`), leaving 4 GPUs of quota headroom for the two
/// studies submitted mid-run.
fn manifest(seed: u64) -> StudyManifest {
    let studies: Vec<String> = (0..4)
        .map(|i| study_json(&format!("s{i}"), 2, seed + i as u64))
        .collect();
    StudyManifest::from_json_str(&format!(
        r#"{{"cluster_gpus": 12, "borrow": false, "studies": [{}]}}"#,
        studies.join(",")
    ))
    .unwrap()
}

/// Two mid-run submissions at distinct times, early enough that every
/// shard still holds active manifest studies (a submission landing on a
/// fully-drained shard activates at its submission time instead of the
/// next master tick — the documented rearm edge this test stays clear
/// of).  Sorted by submission time.
fn submissions(seed: u64) -> Vec<(f64, StudySpec)> {
    [(60.0, "late0", seed + 40), (240.0, "late1", seed + 41)]
        .iter()
        .enumerate()
        .map(|(i, &(at, name, s))| {
            let spec = StudySpec::from_json(&parse(&study_json(name, 2, s)).unwrap(), 4 + i)
                .unwrap();
            (at, spec)
        })
        .collect()
}

fn factory() -> TrainerFactory {
    Arc::new(default_multi_factory)
}

fn temp_dir(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "chopt-shard-det-{}-{tag}-{seed}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every observable output of one run, for exact cross-topology
/// comparison.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    names: Vec<String>,
    logs: Vec<(String, String)>,
    fair_share: String,
    studies: String,
    /// `status` with `events_processed` zeroed (the documented
    /// sum-vs-count divergence).
    status: String,
    /// Per study: leaderboard, sessions page, parallel, curves page.
    per_study: Vec<(String, Vec<String>)>,
    end_time: String,
}

fn fingerprint<S: RunSource>(src: &S, names: &[String], dir: &Path, end: f64) -> Fingerprint {
    let doc = |q: &ApiQuery| src.query(q).unwrap().to_string_compact();
    let mut status = src.query(&ApiQuery::Status).unwrap();
    status.set("events_processed", Json::Num(0.0));
    // Sharded status docs append control-plane gauges
    // (submission_queue depth / quota_ledger reservations) that a
    // single scheduler has no analog for; neutralize on both sides —
    // `set` appends missing keys at the end, so the bytes still match.
    status.set("submission_queue", Json::Null);
    status.set("quota_ledger", Json::Null);
    let per_study = names
        .iter()
        .map(|n| {
            let docs = vec![
                doc(&ApiQuery::StudyLeaderboard { study: n.clone(), k: 10 }),
                doc(&ApiQuery::StudySessions { study: n.clone(), limit: 100, offset: 0 }),
                doc(&ApiQuery::StudyParallel { study: n.clone() }),
                doc(&ApiQuery::StudyCurves { study: n.clone(), limit: 100, offset: 0 }),
            ];
            (n.clone(), docs)
        })
        .collect();
    let logs = names
        .iter()
        .map(|n| {
            let body = std::fs::read_to_string(dir.join(format!("events-{n}.jsonl")))
                .unwrap_or_default();
            (n.clone(), body)
        })
        .collect();
    Fingerprint {
        names: names.to_vec(),
        logs,
        fair_share: doc(&ApiQuery::FairShare),
        studies: doc(&ApiQuery::Studies),
        status: status.to_string_compact(),
        per_study,
        end_time: format!("{end:.9}"),
    }
}

/// The single-scheduler specification run: the same chunked drive as
/// `FanoutSource::run_until`, splitting each chunk at every pending
/// submission time so the study is admitted *exactly* at its requested
/// time — the admission rule both topologies share.
fn single_run(seed: u64) -> (Fingerprint, PathBuf) {
    let dir = temp_dir("single", seed);
    let mut p = MultiPlatform::new(manifest(seed), |study, id| default_multi_factory(study, id))
        .with_event_logs(&dir)
        .unwrap();
    let mut subs = submissions(seed);
    loop {
        let target = p.now() + CHUNK;
        let mut n = 0;
        while subs.first().is_some_and(|&(at, _)| at <= target) {
            let (at, spec) = subs.remove(0);
            p.run_until(at);
            assert!(
                p.submit_study(spec, at).is_some(),
                "reference submission rejected (seed={seed})"
            );
            n += 1;
        }
        n += p.run_until(target);
        if (p.is_done() && subs.is_empty()) || n == 0 {
            break;
        }
    }
    assert!(p.is_done(), "reference run did not finish (seed={seed})");
    let names: Vec<String> = p
        .scheduler()
        .studies()
        .iter()
        .map(|s| s.name().to_string())
        .collect();
    assert_eq!(names, ["s0", "s1", "s2", "s3", "late0", "late1"]);
    let fp = fingerprint(&p, &names, &dir, p.now());
    (fp, dir)
}

/// One sharded run: submissions enqueue up-front (optionally in
/// reversed order — admission order must be a function of submission
/// *time*, not enqueue order), then the fan-out drives to completion.
fn sharded_run(seed: u64, shards: usize, reverse: bool) -> (Fingerprint, Vec<String>, FanoutSource, PathBuf) {
    let dir = temp_dir(&format!("fan{shards}{}", if reverse { "r" } else { "f" }), seed);
    let feed = EventFeed::new(1 << 16);
    let mut fan = FanoutSource::new(
        manifest(seed),
        factory(),
        FanoutConfig {
            shards,
            log_dir: Some(dir.clone()),
            feed: Some(feed.clone()),
            ..FanoutConfig::default()
        },
    )
    .unwrap();
    let mut subs = submissions(seed);
    if reverse {
        subs.reverse();
    }
    for (at, spec) in subs {
        fan.enqueue(spec, at);
    }
    fan.run_to_completion(CHUNK);
    assert!(fan.is_done(), "sharded run did not finish (seed={seed} shards={shards})");
    let (_, _, admitted, _, rejected) = fan.queue_stats();
    assert_eq!((admitted, rejected), (2, 0), "seed={seed} shards={shards}");
    let names = fan.study_names().to_vec();
    let fp = fingerprint(&fan, &names, &dir, fan.now());
    let feed_lines: Vec<String> = feed.read_after(0).1.into_iter().map(|(_, l)| l).collect();
    (fp, feed_lines, fan, dir)
}

/// The property: across seeds, shard counts, and submission orders,
/// the merged run matches the single-scheduler run byte for byte, the
/// merged SSE feed is shard-count-invariant, and composite snapshots
/// restore + scrub to the same documents.
#[test]
fn sharded_runs_are_bit_identical_across_seeds_shards_and_order() {
    for seed in [100_u64, 777] {
        let (reference, ref_dir) = single_run(seed);
        assert!(
            reference.logs.iter().all(|(_, body)| !body.is_empty()),
            "every study must produce a non-empty event log (seed={seed})"
        );
        let mut canonical_feed: Option<Vec<String>> = None;
        for shards in [1usize, 2, 4] {
            for reverse in [false, true] {
                let (fp, feed, fan, dir) = sharded_run(seed, shards, reverse);
                assert_eq!(
                    reference, fp,
                    "sharded run diverged (seed={seed} shards={shards} reverse={reverse})"
                );
                match &canonical_feed {
                    None => canonical_feed = Some(feed),
                    Some(c) => assert_eq!(
                        c, &feed,
                        "merged SSE feed diverged (seed={seed} shards={shards} reverse={reverse})"
                    ),
                }

                // Composite snapshot: restore-by-replay rebuilds the
                // same merged documents at the same generation.
                let snap = fan.snapshot_json();
                let back = FanoutSource::restore_doc(
                    &snap,
                    factory(),
                    FanoutConfig { shards, ..FanoutConfig::default() },
                )
                .unwrap();
                assert_eq!(back.generation(), fan.generation());
                assert_eq!(back.study_names(), fan.study_names());
                for q in [ApiQuery::FairShare, ApiQuery::Studies] {
                    assert_eq!(
                        back.query(&q).unwrap().to_string_compact(),
                        fan.query(&q).unwrap().to_string_compact(),
                        "{q:?} diverged after restore (seed={seed} shards={shards})"
                    );
                }

                // ?at_event= scrubbing rounds down to the last barrier
                // mark, which reproduces the live document.
                let (last, _) = *fan.barrier_marks().last().unwrap();
                let (eff, doc) = fan.query_at(&ApiQuery::FairShare, last + 7).unwrap();
                assert_eq!(eff, last);
                assert_eq!(doc.to_string_compact(), fp.fair_share);

                let _ = std::fs::remove_dir_all(&dir);
            }
        }
        let _ = std::fs::remove_dir_all(&ref_dir);
    }
}
