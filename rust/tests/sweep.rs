//! Property tests for the sweep harness contract:
//!
//! 1. **Pool-size invariance** — the worker-pool size is purely a
//!    wall-clock knob: every byte the sweep writes (`sweep.json` and
//!    every file in every cell directory) is identical across
//!    cell-worker counts {1, 4}.
//! 2. **Standalone equivalence** — a sweep cell is exactly the
//!    deterministic multi-study run `chopt multi` would produce from
//!    the same (manifest, scenario, seed): per-study event logs and
//!    the final snapshot are bit-identical to an independently driven
//!    `MultiPlatform` over the cell's resolved manifest.
//! 3. **Resume soundness** — after deleting half the cell directories,
//!    `--resume` recomputes exactly the missing cells and reproduces a
//!    byte-identical `sweep.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use chopt::coordinator::MultiPlatform;
use chopt::sweep::runner::take_submissions;
use chopt::sweep::{run_sweep, SweepOptions, SweepSpec};
use chopt::trainer::surrogate::default_multi_factory;
use chopt::util::json::parse;

fn study_json(name: &str, quota: usize, seed: u64) -> String {
    format!(
        r#"{{"name": "{name}", "quota": {quota}, "config": {{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}}
          }},
          "measure": "test/accuracy", "order": "descending", "step": 10,
          "population": 2, "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": 4}},
          "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
          "seed": {seed}
        }}}}"#
    )
}

/// 2 scenarios (calm / external-load storm with a mid-run submission)
/// × 1 tuner × 2 policies (borrow on / off) — 4 cells on a 4-GPU
/// cluster with quota headroom for the submitted study.
fn spec() -> SweepSpec {
    let storm = format!(
        r#"{{"sources": [{{"kind": "diurnal", "total_gpus": 2, "base": 0.5,
                          "amp": 0.5, "period": 86400, "jitter": 0.0, "seed": 5}}],
            "submissions": [{{"submit_at": 120, "study": {}}}]}}"#,
        study_json("late", 1, 30)
    );
    let doc = parse(&format!(
        r#"{{
            "base_manifest": {{"cluster_gpus": 4, "studies": [{}, {}]}},
            "seed": "7",
            "target_measure": 0.2,
            "axes": {{
                "scenarios": [{{"name": "calm", "scenario": null}},
                              {{"name": "storm", "scenario": {storm}}}],
                "tuners": [{{"name": "random", "tune": {{"random": {{}}}}}}],
                "policies": [{{"name": "borrow", "borrow": true}},
                             {{"name": "strict", "borrow": false}}]
            }}
        }}"#,
        study_json("s0", 1, 11),
        study_json("s1", 1, 12),
    ))
    .unwrap();
    SweepSpec::from_json(&doc, None).unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("chopt-sweep-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Every file under `dir`, keyed by relative path — the byte-level
/// fingerprint the invariance properties compare.
fn tree_bytes(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap().flatten() {
            let path = entry.path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

#[test]
fn sweep_bytes_invariant_across_worker_counts() {
    let spec = spec();
    let a = temp_dir("w1");
    let b = temp_dir("w4");
    let one = run_sweep(&spec, &a, &SweepOptions { workers: 1, ..SweepOptions::default() })
        .unwrap();
    let four = run_sweep(&spec, &b, &SweepOptions { workers: 4, ..SweepOptions::default() })
        .unwrap();
    assert_eq!(one.cells_total, 4);
    assert_eq!(one.cells_run.len(), 4);
    assert_eq!(four.cells_run.len(), 4);
    assert_eq!(
        one.artifact.to_string_compact(),
        four.artifact.to_string_compact()
    );
    let ta = tree_bytes(&a);
    assert_eq!(ta, tree_bytes(&b), "worker-pool size changed sweep output bytes");
    assert!(ta.contains_key("sweep.json"));
    // The storm cells admit the scenario-submitted study, so their cell
    // directories carry its event log too.
    assert!(ta.contains_key("cells/storm-random-borrow/events-late.jsonl"));
    let _ = std::fs::remove_dir_all(&a);
    let _ = std::fs::remove_dir_all(&b);
}

/// Drive `plan.manifest()` exactly the way `chopt multi` does — chunked
/// advances split at each scenario-submission time — and compare the
/// run's bytes with the sweep cell's.
#[test]
fn sweep_cell_matches_standalone_multi_run() {
    let spec = spec();
    let out = temp_dir("cells");
    run_sweep(&spec, &out, &SweepOptions::default()).unwrap();

    for plan in spec.cells().unwrap() {
        let solo = temp_dir(&format!("solo-{}", plan.id));
        std::fs::create_dir_all(&solo).unwrap();
        let mut manifest = plan.manifest().unwrap();
        let mut subs = take_submissions(&mut manifest).unwrap();
        let mut p = MultiPlatform::new(manifest, default_multi_factory)
            .with_event_logs(&solo)
            .unwrap()
            .with_snapshots(solo.join("snapshot.json"), spec.snapshot_every);
        loop {
            let target = p.now() + spec.chunk;
            let mut n = 0;
            while subs.first().map(|&(at, _)| at <= target).unwrap_or(false) {
                let (at, s) = subs.remove(0);
                n += p.run_until(at);
                assert!(p.submit_study(s, at).is_some(), "cell {}", plan.id);
                n += 1;
            }
            n += p.advance((target - p.now()).max(0.0));
            if n == 0 && !subs.is_empty() {
                let (at, s) = subs.remove(0);
                n += p.run_until(at);
                assert!(p.submit_study(s, at).is_some(), "cell {}", plan.id);
                n += 1;
            }
            if (p.is_done() && subs.is_empty()) || n == 0 {
                break;
            }
        }
        assert!(p.is_done(), "standalone run stalled (cell {})", plan.id);
        p.snapshot_now().unwrap();

        let cell_dir = out.join("cells").join(&plan.id);
        for name in p.scheduler().studies().iter().map(|s| s.name().to_string()) {
            let log = format!("events-{name}.jsonl");
            assert_eq!(
                std::fs::read(solo.join(&log)).unwrap(),
                std::fs::read(cell_dir.join(&log)).unwrap(),
                "event log {log} diverged (cell {})",
                plan.id
            );
        }
        assert_eq!(
            std::fs::read(solo.join("snapshot.json")).unwrap(),
            std::fs::read(cell_dir.join("snapshot.json")).unwrap(),
            "snapshot diverged (cell {})",
            plan.id
        );
        let _ = std::fs::remove_dir_all(&solo);
    }
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn resume_recomputes_only_missing_cells_byte_identically() {
    let spec = spec();
    let out = temp_dir("resume");
    let first = run_sweep(&spec, &out, &SweepOptions::default()).unwrap();
    assert_eq!(first.cells_run.len(), 4);
    let baseline = tree_bytes(&out);

    // Knock out half the grid (one per scenario) and the artifact.
    let gone = ["calm-random-strict", "storm-random-borrow"];
    for id in gone {
        std::fs::remove_dir_all(out.join("cells").join(id)).unwrap();
    }
    std::fs::remove_file(out.join("sweep.json")).unwrap();

    let second = run_sweep(
        &spec,
        &out,
        &SweepOptions { resume: true, ..SweepOptions::default() },
    )
    .unwrap();
    assert_eq!(second.cells_run, gone.to_vec());
    assert_eq!(
        second.cells_skipped,
        vec!["calm-random-borrow".to_string(), "storm-random-strict".to_string()]
    );
    assert_eq!(
        baseline,
        tree_bytes(&out),
        "resume did not reproduce the original sweep bytes"
    );

    // A third resume with nothing missing runs zero cells.
    let third = run_sweep(
        &spec,
        &out,
        &SweepOptions { resume: true, ..SweepOptions::default() },
    )
    .unwrap();
    assert!(third.cells_run.is_empty());
    assert_eq!(third.cells_skipped.len(), 4);
    let _ = std::fs::remove_dir_all(&out);
}
