//! The paper's qualitative phenomena, reproduced at test scale:
//!
//! * Fig. 2 — naive early stopping biases the search toward shallow
//!   models (deep models get pruned before they take off).
//! * Table 4 — step size trades GPU-time for final quality.
//! * Fig. 9 — a session revived from the stop pool can end competitive.

use chopt::config::ChoptConfig;
use chopt::coordinator::{run_sim, SimSetup};
use chopt::nsml::SessionStatus;
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;

fn cfg(step: i64, max_sessions: usize, seed: u64) -> ChoptConfig {
    let text = format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.02, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}},
            "depth": {{"parameters": [20, 140], "distribution": "uniform",
                    "type": "int", "p_range": [20, 140]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": {step},
          "population": 6,
          "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "surrogate:resnet",
          "max_epochs": 200,
          "max_gpus": 6,
          "seed": {seed}
        }}"#
    );
    ChoptConfig::from_json_str(&text).unwrap()
}

fn surrogate(seed: u64) -> impl FnMut(u64) -> Box<dyn Trainer> {
    move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>
}

/// Mean depth of sessions that survived past a given epoch, vs all.
#[test]
fn early_stopping_biases_against_depth_fig2() {
    let out = run_sim(SimSetup::single(cfg(7, 60, 1), 6), surrogate(5));
    let a = &out.agents[0];
    let all: Vec<(i64, bool)> = a
        .sessions
        .values()
        .map(|s| {
            let depth = s.hparams.i64("depth").unwrap_or(20);
            let survived = s.epochs > 21; // lived past 3 ES checks
            (depth, survived)
        })
        .collect();
    let mean = |xs: &[i64]| xs.iter().sum::<i64>() as f64 / xs.len().max(1) as f64;
    let survived: Vec<i64> = all.iter().filter(|&&(_, s)| s).map(|&(d, _)| d).collect();
    let killed: Vec<i64> = all.iter().filter(|&&(_, s)| !s).map(|&(d, _)| d).collect();
    assert!(
        survived.len() >= 3 && killed.len() >= 3,
        "need both groups: {} survived {} killed",
        survived.len(),
        killed.len()
    );
    assert!(
        mean(&survived) + 10.0 < mean(&killed),
        "ES should kill deeper models early: survived depth {:.0} vs killed {:.0}",
        mean(&survived),
        mean(&killed)
    );
}

#[test]
fn step_size_trades_gpu_time_for_quality_table4() {
    // No ES vs small step: no-ES must consume far more GPU time and find
    // at-least-as-good models.
    let no_es = run_sim(SimSetup::single(cfg(-1, 25, 2), 6), surrogate(8));
    let small = run_sim(SimSetup::single(cfg(3, 25, 2), 6), surrogate(8));
    let (gpu_no_es, gpu_small) = (no_es.gpu_hours(), small.gpu_hours());
    assert!(
        gpu_no_es > 3.0 * gpu_small,
        "no-ES {gpu_no_es:.1}h should dwarf small-step {gpu_small:.1}h"
    );
    let best_no_es = no_es.best().unwrap().2;
    let best_small = small.best().unwrap().2;
    assert!(
        best_no_es + 0.3 >= best_small,
        "no-ES should not lose: {best_no_es} vs {best_small}"
    );
}

#[test]
fn revived_sessions_can_finish_competitively_fig9() {
    // Small GPU cap + high stop ratio: sessions bounce through the stop
    // pool and some revived ones finish with competitive accuracy.
    let mut c = cfg(7, 40, 3);
    c.stop_ratio = 0.9;
    let out = run_sim(SimSetup::single(c, 6), surrogate(12));
    let a = &out.agents[0];
    let revived_best = a
        .sessions
        .values()
        .filter(|s| s.revivals > 0)
        .filter_map(|s| s.best_measure(chopt::config::Order::Descending))
        .fold(f64::NEG_INFINITY, f64::max);
    let overall_best = a.best().map(|(_, m)| m).unwrap();
    assert!(
        revived_best.is_finite(),
        "at least one session must be revived"
    );
    assert!(
        revived_best > overall_best - 8.0,
        "revived best {revived_best:.2} should be competitive with {overall_best:.2}"
    );
}

#[test]
fn finished_sessions_trained_to_budget() {
    let out = run_sim(SimSetup::single(cfg(10, 20, 4), 6), surrogate(21));
    let a = &out.agents[0];
    for s in a.sessions.values() {
        if s.status == SessionStatus::Finished && s.revivals == 0 && s.parent.is_none() {
            // Finished sessions reached max_epochs (unless terminated by
            // the CHOPT session shutdown sweep at the end).
            assert!(s.epochs <= 200);
        }
        // Nothing ever exceeds the budget.
        assert!(s.epochs <= 200, "session {} overtrained: {}", s.id, s.epochs);
    }
}
