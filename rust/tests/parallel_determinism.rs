//! Property test for the parallel-stepping contract: `--step-threads`
//! is purely a wall-clock knob.  For every seed × manifest shape we
//! run the same multi-study workload at 1, 2, and 8 step threads with
//! event logging and periodic snapshots attached, then assert the
//! observable outputs are **bit-identical** to the serial run:
//!
//! * the per-study `events-<name>.jsonl` logs (raw file bytes),
//! * a mid-run and a final scheduler snapshot (compact JSON bytes),
//! * every study leaderboard document and the fair-share document
//!   (compact JSON bytes),
//! * the final per-study agent state (sessions, best, finish time,
//!   and the full in-memory event stream).
//!
//! Serial stepping is the specification; the windowed parallel path in
//! `StudyScheduler::parallel_window` must be indistinguishable from it
//! everywhere a user (or the control plane) can look.

use chopt::coordinator::{MultiPlatform, StudyAgent, StudyManifest};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;

fn config_json(step: i64, max_sessions: usize, max_gpus: usize, seed: u64) -> String {
    format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}},
            "momentum": {{"parameters": [0.5, 0.99], "distribution": "uniform",
                    "type": "float", "p_range": [0.1, 0.999]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": {step},
          "population": 4,
          "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "surrogate:resnet",
          "max_epochs": 60,
          "max_gpus": {max_gpus},
          "seed": {seed}
        }}"#
    )
}

/// Four tenants on 8 GPUs: three PBT-style studies with different
/// session budgets plus one no-early-stop study, so windows mix
/// interval cadences and studies finish at different times.
fn manifest(borrow: bool, seed: u64) -> StudyManifest {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": {borrow}, "studies": [
            {{"name": "s0", "quota": 2, "config": {}}},
            {{"name": "s1", "quota": 2, "config": {}}},
            {{"name": "s2", "quota": 2, "config": {}}},
            {{"name": "s3", "quota": 2, "config": {}}}
        ]}}"#,
        config_json(10, 6, 2, seed),
        config_json(10, 8, 2, seed + 1),
        config_json(-1, 4, 2, seed + 2),
        config_json(10, 6, 2, seed + 3)
    );
    StudyManifest::from_json_str(&text).unwrap()
}

fn factory(seed: u64) -> impl FnMut(usize, u64) -> Box<dyn Trainer + Send> {
    move |study, id| {
        Box::new(SurrogateTrainer::new(
            (seed.wrapping_mul(1_000) + 97 * study as u64) ^ id,
        )) as Box<dyn Trainer + Send>
    }
}

/// Everything that characterizes one study's final agent, stringified
/// so [`Fingerprint`] stays `PartialEq + Debug`.
fn agent_key(a: &StudyAgent) -> String {
    format!(
        "created={} sessions={} best={:?} finished_at={:?} events={:?}",
        a.created,
        a.sessions.len(),
        a.best().map(|(sid, m)| (sid.0, format!("{m:.12}"))),
        a.finished_at,
        a.events,
    )
}

/// Every observable output of one run, for exact cross-thread-count
/// comparison.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    logs: Vec<(String, String)>,
    mid_snapshot: String,
    final_snapshot: String,
    mid_leaderboards: Vec<String>,
    final_leaderboards: Vec<String>,
    fair_share: String,
    agents: Vec<(String, String)>,
    end_time: String,
    events_processed: u64,
}

fn run(borrow: bool, seed: u64, threads: usize) -> Fingerprint {
    run_manifest(manifest(borrow, seed), seed, threads, if borrow { "b" } else { "nb" })
}

fn run_manifest(m: StudyManifest, seed: u64, threads: usize, tag: &str) -> Fingerprint {
    let dir = std::env::temp_dir().join(format!(
        "chopt-par-det-{}-{tag}-{seed}-{threads}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let snap_path = dir.join("snapshot.json");

    let mut platform = MultiPlatform::new(m, factory(seed))
        .with_event_logs(&dir)
        .unwrap()
        .with_snapshots(&snap_path, 2_000.0);
    platform.set_step_threads(threads);

    platform.run_until(6_000.0);
    let mid_snapshot = platform.snapshot_now().unwrap().to_string_compact();
    let names: Vec<String> = platform
        .scheduler()
        .studies()
        .iter()
        .map(|s| s.name().to_string())
        .collect();
    let mid_leaderboards = names
        .iter()
        .map(|n| platform.study_leaderboard_doc(n, 10).to_string_compact())
        .collect();

    platform.run_to_completion(1_000.0);
    let final_snapshot = platform.snapshot_now().unwrap().to_string_compact();
    let final_leaderboards = names
        .iter()
        .map(|n| platform.study_leaderboard_doc(n, 10).to_string_compact())
        .collect();
    let fair_share = platform.fair_share_doc().to_string_compact();

    let outcome = platform.into_outcome();
    let agents = outcome
        .studies
        .iter()
        .map(|s| {
            let key = s.agent.as_ref().map(agent_key).unwrap_or_default();
            (s.name.clone(), key)
        })
        .collect();
    let logs = names
        .iter()
        .map(|n| {
            let path = dir.join(format!("events-{n}.jsonl"));
            (n.clone(), std::fs::read_to_string(path).unwrap_or_default())
        })
        .collect();

    let fp = Fingerprint {
        logs,
        mid_snapshot,
        final_snapshot,
        mid_leaderboards,
        final_leaderboards,
        fair_share,
        agents,
        end_time: format!("{:.9}", outcome.end_time),
        events_processed: outcome.events_processed,
    };
    let _ = std::fs::remove_dir_all(&dir);
    fp
}

/// The property: across seeds, borrow modes, and thread counts, every
/// observable output matches the serial run byte for byte.
#[test]
fn parallel_stepping_is_bit_identical_across_seeds_and_threads() {
    for (borrow, seed) in [(false, 100_u64), (true, 777), (false, 424_242)] {
        let serial = run(borrow, seed, 1);
        assert!(
            serial.events_processed > 100,
            "workload too small to exercise windows (borrow={borrow} seed={seed})"
        );
        assert!(
            serial.logs.iter().all(|(_, body)| !body.is_empty()),
            "every study must produce a non-empty event log (borrow={borrow} seed={seed})"
        );
        for threads in [2, 8] {
            let parallel = run(borrow, seed, threads);
            assert_eq!(
                serial, parallel,
                "parallel run diverged (borrow={borrow} seed={seed} threads={threads})"
            );
        }
    }
}

/// Four tenants under adversarial weather: composed demand sources plus
/// a correlated reclaim wave, so the window heuristic must cope with
/// scenario-bearing ticks (routed through the serial tick path),
/// crash/backoff recovery, and demand-squeezed fair shares.
fn weather_manifest(seed: u64) -> StudyManifest {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": true,
            "scenario": {{"sources": [
              {{"kind": "diurnal", "total_gpus": 8, "base": 0.15, "amp": 0.15,
                "period": 15000, "jitter": 0.05, "seed": "{seed}"}},
              {{"kind": "flash_crowd", "total_gpus": 8, "spike": 0.4,
                "first_at": 4000, "every": 0, "duration": 1200, "seed": "{seed}"}},
              {{"kind": "spot_reclaim", "slots": 4, "wave_size": 2,
                "first_at": 3000, "every": 0, "waves": 1, "seed": "{seed}"}}
            ]}},
            "studies": [
              {{"name": "s0", "quota": 2, "config": {}}},
              {{"name": "s1", "quota": 2, "config": {}}},
              {{"name": "s2", "quota": 2, "config": {}}},
              {{"name": "s3", "quota": 2, "config": {}}}
            ]}}"#,
        config_json(10, 6, 2, seed),
        config_json(10, 8, 2, seed + 1),
        config_json(-1, 4, 2, seed + 2),
        config_json(10, 6, 2, seed + 3)
    );
    StudyManifest::from_json_str(&text).unwrap()
}

/// The same bit-identity property with a composed scenario attached:
/// `--step-threads` stays a pure wall-clock knob even while the cluster
/// weather is crashing agents and squeezing the fair share.
#[test]
fn parallel_stepping_is_bit_identical_under_scenario_weather() {
    for seed in [100_u64, 777] {
        let serial = run_manifest(weather_manifest(seed), seed, 1, "wx");
        assert!(
            serial.events_processed > 100,
            "weather workload too small to exercise windows (seed={seed})"
        );
        for threads in [2, 8] {
            let parallel = run_manifest(weather_manifest(seed), seed, threads, "wx");
            assert_eq!(
                serial, parallel,
                "weather run diverged (seed={seed} threads={threads})"
            );
        }
    }
}
