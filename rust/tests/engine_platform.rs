//! Integration tests for the re-entrant engine + live platform layer:
//! step/run_until re-entry vs. one-shot equality, online submit while
//! running, snapshot → restore → continue determinism, the
//! failure-injection consume-once regression, and live viz routes that
//! change as the engine advances.

use chopt::config::ChoptConfig;
use chopt::coordinator::{
    run_sim, AgentEvent, Platform, RetryPolicy, SimEngine, SimSetup, Step, StopAndGoPolicy,
};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;
use chopt::viz::server::{http_get, Routes, VizServer};

fn cfg(tune: &str, step: i64, max_sessions: usize, max_gpus: usize, seed: u64) -> ChoptConfig {
    let text = format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}},
            "momentum": {{"parameters": [0.5, 0.99], "distribution": "uniform",
                    "type": "float", "p_range": [0.1, 0.999]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": {step},
          "population": 4,
          "tune": {tune},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "surrogate:resnet",
          "max_epochs": 60,
          "max_gpus": {max_gpus},
          "seed": {seed}
        }}"#
    );
    ChoptConfig::from_json_str(&text).unwrap()
}

fn surrogate(seed: u64) -> impl FnMut(u64) -> Box<dyn Trainer> {
    move |id| Box::new(SurrogateTrainer::new(seed ^ id)) as Box<dyn Trainer>
}

fn setup(n_cfgs: usize, slots: usize, gpus: usize) -> SimSetup {
    SimSetup {
        cluster_gpus: gpus,
        configs: (0..n_cfgs)
            .map(|i| cfg("{\"random\": {}}", 10, 8, 3, 100 + i as u64))
            .collect(),
        submit_times: Vec::new(),
        agent_slots: slots,
        trace: None,
        policy: StopAndGoPolicy::default(),
        master_period: 60.0,
        horizon: 1e9,
        failures: Vec::new(),
        scenario: None,
        retry: RetryPolicy::default(),
    }
}

fn outcome_key(out: &chopt::coordinator::SimOutcome) -> (Option<f64>, f64, u64, usize) {
    (
        out.best().map(|(_, _, m)| m),
        out.end_time,
        out.events_processed,
        out.agents.len(),
    )
}

#[test]
fn paused_and_resumed_run_equals_one_shot() {
    let one_shot = run_sim(setup(2, 2, 6), surrogate(7));

    let mut engine = SimEngine::new(setup(2, 2, 6), surrogate(7));
    // Slice the run arbitrarily: a few single steps, two time-bounded
    // chunks, then drain.  The popped event sequence must be identical.
    for _ in 0..5 {
        assert!(matches!(engine.step(), Step::Advanced(_)));
    }
    engine.run_until(3_000.0);
    assert!(engine.now() <= 3_000.0);
    engine.run_until(50_000.0);
    engine.run_to_completion();
    let sliced = engine.into_outcome();

    assert_eq!(outcome_key(&one_shot), outcome_key(&sliced));
    for a in &sliced.agents {
        assert!(a.finished);
        a.pools.check_invariants().unwrap();
    }
}

#[test]
fn online_submit_while_running_gets_scheduled() {
    let mut engine = SimEngine::new(setup(1, 2, 6), surrogate(9));
    engine.run_until(2_000.0);
    assert!(!engine.is_done(), "first session should still be running");

    // A second user joins the shared cluster mid-run.
    let at = engine.submit(cfg("{\"random\": {}}", 10, 6, 3, 500), 2_500.0);
    assert_eq!(at, Some(2_500.0));
    assert_eq!(engine.queue_len(), 1);

    engine.run_to_completion();
    let out = engine.into_outcome();
    assert_eq!(out.agents.len(), 2, "online submission must run");
    assert!(out.agents.iter().all(|a| a.finished));
    let mut ids: Vec<u64> = out.agents.iter().map(|a| a.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2]);
}

#[test]
fn submit_after_drain_revives_engine() {
    let mut engine = SimEngine::new(setup(1, 1, 4), surrogate(11));
    engine.run_to_completion();
    assert!(engine.is_done());
    let drained_at = engine.now();

    let accepted = engine.submit(cfg("{\"random\": {}}", 10, 5, 3, 600), drained_at + 1_000.0);
    assert!(accepted.is_some());
    assert!(!engine.is_done(), "a new submission must re-arm the engine");
    engine.run_to_completion();
    let out = engine.into_outcome();
    assert_eq!(out.agents.len(), 2);
    assert!(out.agents.iter().all(|a| a.finished));
    assert!(out.end_time > drained_at + 1_000.0);
}

#[test]
fn snapshot_restore_continue_is_deterministic() {
    // Reference: a single engine runs straight through, with one online
    // submission along the way.
    let drive = |engine: &mut SimEngine| {
        engine.run_until(3_000.0);
        engine
            .submit(cfg("{\"random\": {}}", 10, 6, 3, 700), 5_000.0)
            .unwrap();
        engine.run_until(8_000.0);
    };
    let mut reference = SimEngine::new(setup(1, 2, 6), surrogate(13));
    drive(&mut reference);
    reference.run_to_completion();
    let ref_out = reference.into_outcome();

    // Same run, but snapshotted mid-flight and restored into a fresh
    // engine (replay), which then continues to completion.
    let mut original = SimEngine::new(setup(1, 2, 6), surrogate(13));
    drive(&mut original);
    let snap = original.snapshot_json();
    // Snapshot text round-trips through serialization.
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = SimEngine::restore(&snap, surrogate(13)).unwrap();
    assert_eq!(restored.now(), original.now());
    assert_eq!(restored.events_processed(), original.events_processed());
    restored.run_to_completion();
    let restored_out = restored.into_outcome();

    assert_eq!(outcome_key(&ref_out), outcome_key(&restored_out));
    let created: Vec<usize> = ref_out.agents.iter().map(|a| a.created).collect();
    let created_r: Vec<usize> = restored_out.agents.iter().map(|a| a.created).collect();
    assert_eq!(created, created_r);
}

#[test]
fn horizon_terminated_run_restores() {
    // The final event pop past the horizon still counts toward
    // events_processed; restore must tolerate it (the replay's last step
    // reports HorizonReached, not Advanced).
    let mut s = setup(1, 1, 4);
    s.horizon = 2_000.0;
    let mut engine = SimEngine::new(s, surrogate(17));
    engine.run_to_completion();
    assert!(engine.horizon_reached(), "run must end via the horizon");
    let snap = engine.snapshot_json();

    let restored = SimEngine::restore(&snap, surrogate(17)).unwrap();
    assert_eq!(restored.events_processed(), engine.events_processed());
    assert_eq!(restored.now(), engine.now());
    assert!(restored.horizon_reached());
    // Past the horizon the clock cannot advance; submission is refused
    // instead of silently never running.
    assert_eq!(
        engine.submit(cfg("{\"random\": {}}", 10, 4, 3, 900), 9_000.0),
        None
    );
    assert_eq!(
        outcome_key(&engine.into_outcome()),
        outcome_key(&restored.into_outcome())
    );
}

#[test]
fn quiet_restore_suppresses_series_but_changes_no_decisions() {
    // Reference run, straight through.
    fn series_len(e: &SimEngine) -> usize {
        e.cluster().usage_total.series.len()
    }
    let mut reference = SimEngine::new(setup(1, 2, 6), surrogate(29));
    reference.run_until(5_000.0);
    assert!(!reference.is_done(), "snapshot must be taken mid-flight");
    let live_pts = series_len(&reference);
    let snap = reference.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();

    // Quiet restore: the replay must not re-accumulate the utilization
    // history it is about to discard...
    let mut restored = SimEngine::restore(&snap, surrogate(29)).unwrap();
    let replay_pts = series_len(&restored);
    assert!(
        replay_pts < live_pts,
        "quiet replay kept {replay_pts} series points vs live {live_pts}"
    );
    // ...but integrals (GPU-hour accounting) are preserved exactly...
    let t = reference.now();
    assert!(
        (reference.cluster().chopt_gpu_hours(t) - restored.cluster().chopt_gpu_hours(t)).abs()
            < 1e-9,
        "quiet replay changed the GPU-hours integral"
    );
    // ...and post-restore the series records level changes again.
    restored.run_to_completion();
    reference.run_to_completion();
    assert!(series_len(&restored) > replay_pts);
    let a = reference.into_outcome();
    let b = restored.into_outcome();
    assert_eq!(outcome_key(&a), outcome_key(&b));
    assert!((a.gpu_hours() - b.gpu_hours()).abs() < 1e-9);
}

#[test]
fn leaderboard_doc_is_cached_until_the_engine_advances() {
    let mut platform = Platform::new(setup(2, 2, 6), surrogate(37));
    platform.run_until(5_000.0);
    // Idle engine: repeated renders return the identical document.
    let a = platform.leaderboard_doc(10);
    let b = platform.leaderboard_doc(10);
    assert_eq!(a, b);
    // A different k is a different document (cache must not leak k).
    let top1 = platform.leaderboard_doc(1);
    assert_eq!(top1.get("rows").unwrap().as_arr().unwrap().len(), 1);
    // Advancing invalidates the cache.
    platform.run_until(30_000.0);
    let c = platform.leaderboard_doc(10);
    assert_ne!(a, c, "leaderboard must advance with the engine");
    // The by-reference session views agree with the owned ones.
    let refs = platform.sessions_ref();
    let owned = platform.sessions();
    assert_eq!(refs.len(), owned.len());
    for (r, o) in refs.iter().zip(owned.iter()) {
        assert_eq!(r.id, o.id);
        assert_eq!(r.epochs, o.epochs);
    }
}

#[test]
fn failure_injection_fires_exactly_once() {
    // Regression for the stale-failure bug: a (t, slot) failure record
    // used to be re-applied on *every* master tick with t <= now.  Under
    // the retry policy that would read as a crash loop — attempts piling
    // up each tick straight into quarantine.  One slot, two queued
    // configs, one failure while the first is running: the first agent
    // recovers once and finishes, the second runs untouched.
    let mut s = setup(2, 1, 4);
    s.failures = vec![(5_000.0, 0)];
    let mut engine = SimEngine::new(s, surrogate(55));
    engine.run_to_completion();
    assert_eq!(
        engine.fail_stats(),
        (1, 0),
        "the failure record must fire exactly once"
    );
    assert_eq!(engine.slot_restarts()[0], 1, "one recovery, no crash loop");
    assert!(engine.slot_healths()[0].is_ok());
    let out = engine.into_outcome();
    assert_eq!(out.agents.len(), 2);
    for a in &out.agents {
        assert!(a.finished, "agent {} must finish", a.id);
        assert!(
            !a.events.iter().any(|e| matches!(
                e,
                AgentEvent::Terminated("agent_failure") | AgentEvent::Terminated("quarantined")
            )),
            "no agent may be aborted"
        );
    }
    assert_eq!(out.cluster.held_by_chopt(), 0);
}

#[test]
fn platform_event_log_and_snapshot_roundtrip() {
    let dir = std::env::temp_dir().join(format!("chopt-platform-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let log_path = dir.join("events.jsonl");
    let snap_path = dir.join("snapshot.json");

    let mut platform = Platform::new(setup(1, 1, 4), surrogate(21))
        .with_event_log(&log_path)
        .unwrap()
        .with_snapshots(&snap_path, 2_000.0);
    platform.run_until(6_000.0);
    platform.snapshot_now().unwrap();
    let t_snap = platform.now();
    let events_snap = platform.engine().events_processed();
    assert!(platform.progress_events > 0, "pool transitions must stream");

    // The JSONL stream is parseable and structured.
    let events = chopt::storage::EventLog::read_all(&log_path).unwrap();
    assert!(!events.is_empty());
    assert!(events
        .iter()
        .all(|e| e.get("ev").and_then(|v| v.as_str()).is_some()));
    assert!(events
        .iter()
        .any(|e| e.get("ev").and_then(|v| v.as_str()) == Some("launched")));

    // Restore from the snapshot file and continue to completion; the
    // original platform continued live must agree.
    let mut restored = Platform::restore(&snap_path, surrogate(21)).unwrap();
    assert_eq!(restored.now(), t_snap);
    assert_eq!(restored.engine().events_processed(), events_snap);
    restored.run_to_completion(1_000.0);
    platform.run_to_completion(1_000.0);
    let a = platform.into_outcome();
    let b = restored.into_outcome();
    assert_eq!(outcome_key(&a), outcome_key(&b));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn live_routes_change_as_engine_advances() {
    // The acceptance criterion behind `chopt serve --live`: leaderboard
    // JSON served over HTTP must change as the engine advances.
    let mut platform = Platform::new(setup(1, 1, 4), surrogate(33));
    let server = VizServer::start(0, Routes::new()).unwrap();
    let addr = server.addr();

    platform.run_until(1_000.0);
    server.put_json("/api/leaderboard.json", &platform.leaderboard_doc(10));
    server.put_json("/api/status.json", &platform.status_doc());
    let (code, body1) = http_get(addr, "/api/leaderboard.json").unwrap();
    assert_eq!(code, 200);
    let doc1 = chopt::util::json::parse(&String::from_utf8(body1).unwrap()).unwrap();

    platform.run_to_completion(5_000.0);
    server.put_json("/api/leaderboard.json", &platform.leaderboard_doc(10));
    server.put_json("/api/status.json", &platform.status_doc());
    let (code, body2) = http_get(addr, "/api/leaderboard.json").unwrap();
    assert_eq!(code, 200);
    let doc2 = chopt::util::json::parse(&String::from_utf8(body2).unwrap()).unwrap();

    assert_ne!(doc1, doc2, "leaderboard must advance with the engine");
    assert!(
        doc2.get("t").unwrap().as_f64().unwrap() > doc1.get("t").unwrap().as_f64().unwrap()
    );
    assert!(!doc2.get("rows").unwrap().as_arr().unwrap().is_empty());

    let (code, status) = http_get(addr, "/api/status.json").unwrap();
    assert_eq!(code, 200);
    let status = chopt::util::json::parse(&String::from_utf8(status).unwrap()).unwrap();
    assert_eq!(status.get("done").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn engine_views_expose_live_state() {
    let mut engine = SimEngine::new(setup(2, 2, 6), surrogate(41));
    engine.run_until(2_000.0);
    assert_eq!(engine.active_agents().count(), 2);
    assert!(engine.best().is_some());
    assert!(engine.events_processed() > 0);
    assert!(!engine.master_log().is_empty());
    assert_eq!(engine.cluster().total(), 6);
    engine.run_to_completion();
    assert!(engine.is_done());
    assert_eq!(engine.done_agents().len(), 2);
    assert_eq!(engine.active_agents().count(), 0);
}
