//! Multi-tenant scheduler integration tests: the fair-share determinism
//! contract (a study on a shared cluster behaves exactly as it would on
//! a dedicated cluster of its quota size), quota enforcement under
//! stepping, cross-study Stop-and-Go preemption (pauses, never kills),
//! online study submission, and multi-study snapshot/restore.

use chopt::cluster::{Cluster, Owner};
use chopt::config::ChoptConfig;
use chopt::coordinator::{
    run_sim, Agent, AgentEvent, MultiPlatform, Pool, SimSetup, Step, StudyManifest,
    StudyScheduler, StudySpec,
};
use chopt::trainer::surrogate::SurrogateTrainer;
use chopt::trainer::Trainer;

fn config_json(step: i64, max_sessions: usize, max_gpus: usize, seed: u64) -> String {
    format!(
        r#"{{
          "h_params": {{
            "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                    "type": "float", "p_range": [0.001, 0.2]}},
            "momentum": {{"parameters": [0.5, 0.99], "distribution": "uniform",
                    "type": "float", "p_range": [0.1, 0.999]}}
          }},
          "measure": "test/accuracy",
          "order": "descending",
          "step": {step},
          "population": 4,
          "tune": {{"random": {{}}}},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "surrogate:resnet",
          "max_epochs": 60,
          "max_gpus": {max_gpus},
          "seed": {seed}
        }}"#
    )
}

fn cfg(step: i64, max_sessions: usize, max_gpus: usize, seed: u64) -> ChoptConfig {
    ChoptConfig::from_json_str(&config_json(step, max_sessions, max_gpus, seed)).unwrap()
}

fn two_study_manifest(borrow: bool) -> StudyManifest {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": {borrow}, "studies": [
            {{"name": "alice", "quota": 4, "config": {}}},
            {{"name": "bob", "quota": 4, "config": {}}}
        ]}}"#,
        config_json(10, 8, 3, 100),
        config_json(10, 8, 3, 101)
    );
    StudyManifest::from_json_str(&text).unwrap()
}

/// Per-study trainer streams, reproducible for the solo baselines.
fn study_seed(study: usize) -> u64 {
    7_000 + 1_000 * study as u64
}

fn multi_factory() -> impl FnMut(usize, u64) -> Box<dyn Trainer + Send> {
    |study, id| Box::new(SurrogateTrainer::new(study_seed(study) ^ id)) as Box<dyn Trainer + Send>
}

fn solo_factory(study: usize) -> impl FnMut(u64) -> Box<dyn Trainer> {
    move |id| Box::new(SurrogateTrainer::new(study_seed(study) ^ id)) as Box<dyn Trainer>
}

/// Everything that characterizes one study's run, for exact comparison.
fn agent_key(a: &Agent) -> (usize, usize, Option<(u64, String)>, Option<f64>, usize) {
    (
        a.created,
        a.sessions.len(),
        a.best().map(|(sid, m)| (sid.0, format!("{m:.12}"))),
        a.finished_at,
        a.events.len(),
    )
}

/// The headline acceptance criterion: two concurrent studies on a shared
/// 8-GPU cluster (quota 4 + 4, hard isolation) finish with per-study
/// results **identical** to running each study alone on a dedicated
/// 4-GPU cluster.
#[test]
fn shared_cluster_matches_dedicated_quota_runs() {
    let mut sched = StudyScheduler::new(two_study_manifest(false), multi_factory());
    sched.run_to_completion();
    let multi = sched.into_outcome();
    assert_eq!(multi.studies.len(), 2);
    let all_finished = multi
        .studies
        .iter()
        .all(|s| s.agent.as_ref().map(|a| a.finished).unwrap_or(false));
    assert!(all_finished);

    for (study, (name, seed)) in [("alice", 100u64), ("bob", 101u64)].iter().enumerate() {
        let solo = run_sim(
            SimSetup::single(cfg(10, 8, 3, *seed), 4), // dedicated quota-size cluster
            solo_factory(study),
        );
        assert_eq!(solo.agents.len(), 1);
        let shared_agent = multi.study(name).unwrap().agent.as_ref().unwrap();
        assert_eq!(
            agent_key(&solo.agents[0]),
            agent_key(shared_agent),
            "study '{name}' diverged from its dedicated-cluster run"
        );
        // Full leaderboard equality, not just the single best entry.
        let top_solo: Vec<(u64, String)> = solo.agents[0]
            .leaderboard
            .top(10)
            .iter()
            .map(|&(sid, m)| (sid.0, format!("{m:.12}")))
            .collect();
        let top_shared: Vec<(u64, String)> = shared_agent
            .leaderboard
            .top(10)
            .iter()
            .map(|&(sid, m)| (sid.0, format!("{m:.12}")))
            .collect();
        assert_eq!(top_solo, top_shared, "study '{name}' leaderboard diverged");
    }
}

/// Stepping through the run, no study ever holds more than its quota
/// when borrowing is disabled, and tenants never collide in the
/// allocator.
#[test]
fn fair_share_quotas_respected_throughout() {
    let mut sched = StudyScheduler::new(two_study_manifest(false), multi_factory());
    let mut steps = 0u64;
    while matches!(sched.step(), Step::Advanced(_)) {
        steps += 1;
        for st in sched.studies() {
            if let Some(agent) = st.agent() {
                let held = sched.cluster().held_by(Owner::Chopt(agent.tenant));
                assert!(
                    held <= st.quota(),
                    "study '{}' holds {held} > quota {} at step {steps}",
                    st.name(),
                    st.quota()
                );
            }
        }
        assert!(
            sched.cluster().used() <= sched.cluster().total(),
            "cluster oversubscribed"
        );
    }
    assert!(sched.is_done());
    let tenants: Vec<u64> = sched
        .studies()
        .iter()
        .filter_map(|s| s.agent().map(|a| a.tenant))
        .collect();
    assert_eq!(tenants.len(), 2);
    assert_ne!(tenants[0], tenants[1], "tenants must be study-qualified");
}

/// Cross-study Stop-and-Go: a lone study borrows idle quota; when the
/// second tenant arrives the borrower is preempted back down by
/// *pausing* sessions (stop pool, revival priority) — never by killing
/// them — and the newcomer gets its full guarantee.
#[test]
fn cross_study_preemption_pauses_not_kills() {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": true, "studies": [
            {{"name": "alice", "quota": 4, "config": {}}},
            {{"name": "bob", "quota": 4, "submit_at": 10000, "config": {}}}
        ]}}"#,
        // step -1 (no early stopping): alice's cohorts train straight to
        // max_epochs, so her live pool deterministically fills the
        // borrowed allocation for the phase-1/2 assertions below.
        config_json(-1, 40, 4, 100),
        config_json(10, 8, 4, 101)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();
    let mut sched = StudyScheduler::new(manifest, multi_factory());

    // Phase 1: alice alone borrows past her quota (bounded by the bonus
    // cap: 2 × her 4-GPU base = 8 = the whole cluster).
    sched.run_until(9_000.0);
    let alice_tenant = sched.study("alice").unwrap().agent().unwrap().tenant;
    assert_eq!(
        sched.cluster().held_by(Owner::Chopt(alice_tenant)),
        8,
        "lone study should borrow the idle quota"
    );
    assert!(!sched.study("bob").unwrap().started());

    // Phase 2: bob arrives; within two master periods the borrower is
    // preempted back to quota and bob holds his guarantee.
    sched.run_until(10_200.0);
    let bob_tenant = sched.study("bob").unwrap().agent().unwrap().tenant;
    assert_eq!(sched.cluster().held_by(Owner::Chopt(alice_tenant)), 4);
    assert_eq!(sched.cluster().held_by(Owner::Chopt(bob_tenant)), 4);

    let alice = sched.study("alice").unwrap().agent().unwrap();
    let preempted: Vec<&AgentEvent> = alice
        .events
        .iter()
        .filter(|e| matches!(e, AgentEvent::Preempted(..)))
        .collect();
    assert!(
        preempted.len() >= 4,
        "borrowed GPUs must be reclaimed by preemption, got {preempted:?}"
    );
    assert!(
        preempted
            .iter()
            .all(|e| matches!(e, AgentEvent::Preempted(_, Pool::Stop))),
        "cross-study preemption must pause (stop pool), never kill: {preempted:?}"
    );

    // Phase 3: both studies complete; preempted work was resumed, not
    // lost.
    sched.run_to_completion();
    let out = sched.into_outcome();
    let alice = out.study("alice").unwrap().agent.as_ref().unwrap();
    let bob = out.study("bob").unwrap().agent.as_ref().unwrap();
    assert!(alice.finished && bob.finished);
    assert!(
        alice.events.iter().any(|e| matches!(e, AgentEvent::Revived(_))),
        "preempted sessions must revive when capacity returns"
    );
    let killed = alice
        .events
        .iter()
        .any(|e| matches!(e, AgentEvent::Preempted(_, Pool::Dead)));
    assert!(!killed);
    assert!(bob.best().is_some());
    alice.pools.check_invariants().unwrap();
    bob.pools.check_invariants().unwrap();
}

/// A study submitted while the scheduler is live gets activated, honors
/// the quota arithmetic, and runs to completion; oversubscribing quotas
/// is refused.
#[test]
fn online_study_submission_runs() {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": false, "studies": [
            {{"name": "alice", "quota": 4, "config": {}}}
        ]}}"#,
        config_json(10, 8, 3, 100)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();
    let mut sched = StudyScheduler::new(manifest, multi_factory());
    sched.run_until(2_000.0);
    assert!(!sched.is_done());

    // Too big: would break the existing guarantee.
    let oversized = StudySpec {
        name: "greedy".into(),
        config: cfg(10, 6, 3, 555),
        quota: 6,
        priority: 1.0,
        submit_at: 0.0,
        failures: Vec::new(),
    };
    assert_eq!(sched.submit_study(oversized, 2_500.0), None);

    let fits = StudySpec {
        name: "carol".into(),
        config: cfg(10, 6, 3, 200),
        quota: 4,
        priority: 1.0,
        submit_at: 0.0,
        failures: Vec::new(),
    };
    assert_eq!(sched.submit_study(fits, 2_500.0), Some(2_500.0));
    sched.run_to_completion();
    let out = sched.into_outcome();
    assert_eq!(out.studies.len(), 2);
    let carol = out.study("carol").unwrap().agent.as_ref().unwrap();
    assert!(carol.finished);
    assert!(carol.best().is_some());
}

/// A mid-run snapshot of the whole multi-study state (including an
/// online submission) restores by replay and finishes identically to
/// the uninterrupted run.
#[test]
fn multi_study_snapshot_restore_is_deterministic() {
    let drive = |sched: &mut StudyScheduler| {
        sched.run_until(3_000.0);
        sched.run_until(8_000.0);
    };
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": true, "studies": [
            {{"name": "alice", "quota": 4, "config": {}}},
            {{"name": "bob", "quota": 2, "config": {}}}
        ]}}"#,
        config_json(10, 8, 3, 100),
        config_json(10, 8, 3, 101)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();

    // Reference: straight through, with one online study on the way.
    let mut reference = StudyScheduler::new(manifest.clone(), multi_factory());
    drive(&mut reference);
    reference
        .submit_study(
            StudySpec {
                name: "carol".into(),
                config: cfg(10, 6, 3, 200),
                quota: 2,
                priority: 1.0,
                submit_at: 0.0,
                failures: Vec::new(),
            },
            9_000.0,
        )
        .unwrap();
    reference.run_to_completion();
    let ref_out = reference.into_outcome();

    // Same run, snapshotted mid-flight after the online submission and
    // restored into a fresh scheduler.
    let mut original = StudyScheduler::new(manifest, multi_factory());
    drive(&mut original);
    original
        .submit_study(
            StudySpec {
                name: "carol".into(),
                config: cfg(10, 6, 3, 200),
                quota: 2,
                priority: 1.0,
                submit_at: 0.0,
                failures: Vec::new(),
            },
            9_000.0,
        )
        .unwrap();
    original.run_until(20_000.0);
    let snap = original.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = StudyScheduler::restore(&snap, multi_factory()).unwrap();
    assert_eq!(restored.now(), original.now());
    assert_eq!(restored.events_processed(), original.events_processed());
    // Quiet fast-restore: the replay keeps integrals exact but does not
    // re-accumulate the pre-snapshot utilization series.
    assert!(
        restored.cluster().usage_total.series.len() < original.cluster().usage_total.series.len(),
        "quiet replay should retain fewer series points than the live run"
    );
    assert!(
        (restored.cluster().chopt_gpu_hours(restored.now())
            - original.cluster().chopt_gpu_hours(original.now()))
        .abs()
            < 1e-9
    );
    restored.run_to_completion();
    let restored_out = restored.into_outcome();

    assert_eq!(ref_out.end_time, restored_out.end_time);
    assert_eq!(ref_out.events_processed, restored_out.events_processed);
    assert_eq!(ref_out.studies.len(), restored_out.studies.len());
    for (a, b) in ref_out.studies.iter().zip(restored_out.studies.iter()) {
        assert_eq!(a.name, b.name);
        match (&a.agent, &b.agent) {
            (Some(x), Some(y)) => assert_eq!(agent_key(x), agent_key(y), "study {}", a.name),
            (None, None) => {}
            _ => panic!("study {} activation diverged", a.name),
        }
    }
}

/// Weighted fair share: a priority-2 study converges to ~2× the GPUs of
/// a priority-1 peer (the quota guarantee is equal; the *redistributed*
/// surplus is split by weight).
#[test]
fn weighted_fair_share_gives_priority_study_double_gpus() {
    // Quotas 1 + 1 on a 30-GPU cluster leave a 28-GPU surplus for the
    // weighted split (policy bonus cap loosened so the cap doesn't mask
    // the weights): hi gets 1 + ⌊28·2/3⌋ = 19, lo gets 1 + ⌊28·1/3⌋ = 10.
    // step -1 (no early stopping) keeps sessions long-lived so the live
    // pools deterministically fill their targets.
    let text = format!(
        r#"{{"cluster_gpus": 30, "borrow": true,
            "policy": {{"max_bonus_factor": 100}},
            "studies": [
              {{"name": "hi", "quota": 1, "priority": 2, "config": {}}},
              {{"name": "lo", "quota": 1, "config": {}}}
            ]}}"#,
        config_json(-1, 400, 20, 100),
        config_json(-1, 400, 20, 101)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();
    assert_eq!(manifest.studies[0].priority, 2.0);
    assert_eq!(manifest.studies[1].priority, 1.0); // default
    let mut sched = StudyScheduler::new(manifest, multi_factory());
    sched.run_until(1_000.0);

    let hi = sched.study("hi").unwrap();
    let lo = sched.study("lo").unwrap();
    assert_eq!((hi.target(), lo.target()), (19, 10));
    let held = |sched: &StudyScheduler, name: &str| {
        let tenant = sched.study(name).unwrap().agent().unwrap().tenant;
        sched.cluster().held_by(Owner::Chopt(tenant))
    };
    let (h, l) = (held(&sched, "hi"), held(&sched, "lo"));
    assert_eq!((h, l), (19, 10), "held GPUs must track the weighted targets");
    let ratio = h as f64 / l as f64;
    assert!((1.7..=2.2).contains(&ratio), "hi/lo GPU ratio {ratio} not ~2x");
}

/// Control-plane commands (pause/resume/set_quota) are recorded replay
/// inputs: a snapshot taken *after* commands were issued restores by
/// replay and the continued run matches the uninterrupted reference.
#[test]
fn control_commands_replay_through_snapshot_restore() {
    let drive = |sched: &mut StudyScheduler| {
        sched.run_until(3_000.0);
        // Session-level commands on bob (study-qualified ids) + a
        // study-level pause on alice, all recorded as replay inputs.
        let bob_sid = sched.study("bob").unwrap().agent().unwrap().pools.live()[0];
        sched.pause_session("bob", bob_sid, 3_000.0).unwrap();
        sched.pause_study("alice", 3_000.0).unwrap();
        sched.run_until(5_000.0);
        // While paused, alice holds nothing and bob's weight doubles.
        sched.set_quota("bob", None, Some(2.0), 5_000.0).unwrap();
        sched.resume_session("bob", bob_sid, 5_000.0).unwrap();
        sched.run_until(6_000.0);
        sched.resume_study("alice", 6_000.0).unwrap();
        sched.run_until(9_000.0);
    };

    let mut reference = StudyScheduler::new(two_study_manifest(true), multi_factory());
    drive(&mut reference);
    reference.run_to_completion();
    let ref_out = reference.into_outcome();

    let mut original = StudyScheduler::new(two_study_manifest(true), multi_factory());
    drive(&mut original);
    let snap = original.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = StudyScheduler::restore(&snap, multi_factory()).unwrap();
    assert_eq!(restored.now(), original.now());
    assert_eq!(restored.events_processed(), original.events_processed());
    assert_eq!(restored.study("bob").unwrap().priority(), 2.0);

    restored.run_to_completion();
    original.run_to_completion();
    let restored_out = restored.into_outcome();
    let original_out = original.into_outcome();
    for out in [&restored_out, &original_out] {
        assert_eq!(ref_out.end_time, out.end_time);
        assert_eq!(ref_out.events_processed, out.events_processed);
    }
    for (a, b) in ref_out.studies.iter().zip(restored_out.studies.iter()) {
        assert_eq!(a.name, b.name);
        match (&a.agent, &b.agent) {
            (Some(x), Some(y)) => assert_eq!(agent_key(x), agent_key(y), "study {}", a.name),
            (None, None) => {}
            _ => panic!("study {} activation diverged", a.name),
        }
    }
}

/// Scheduler-level pause/resume semantics: a paused study drains to zero
/// GPUs (work parked, never killed) and resumes where it left off.
#[test]
fn pause_study_drains_and_resume_revives() {
    let mut sched = StudyScheduler::new(two_study_manifest(true), multi_factory());
    sched.run_until(2_000.0);
    let alice_tenant = sched.study("alice").unwrap().agent().unwrap().tenant;
    assert!(sched.cluster().held_by(Owner::Chopt(alice_tenant)) > 0);

    sched.pause_study("alice", 2_000.0).unwrap();
    // One event boundary applies the command; a master period settles it.
    sched.run_until(2_100.0);
    assert!(sched.study("alice").unwrap().paused());
    assert_eq!(sched.cluster().held_by(Owner::Chopt(alice_tenant)), 0);
    let alice = sched.study("alice").unwrap().agent().unwrap();
    assert_eq!(alice.pools.live_count(), 0);
    assert!(alice.pools.stop_count() > 0, "paused work must be parked, not killed");
    assert!(!alice.finished);

    // Paused ≠ done: the scheduler stays alive and bob keeps running.
    assert!(!sched.is_done());
    sched.run_until(4_000.0);
    assert_eq!(sched.cluster().held_by(Owner::Chopt(alice_tenant)), 0);

    sched.resume_study("alice", 4_000.0).unwrap();
    sched.run_until(4_200.0);
    assert!(!sched.study("alice").unwrap().paused());
    assert!(
        sched.cluster().held_by(Owner::Chopt(alice_tenant)) > 0,
        "resumed study must get GPUs back at the next tick"
    );
    let alice = sched.study("alice").unwrap().agent().unwrap();
    assert!(
        alice.events.iter().any(|e| matches!(e, AgentEvent::Revived(_))),
        "paused sessions must revive on resume"
    );

    sched.run_to_completion();
    let out = sched.into_outcome();
    assert!(out.studies.iter().all(|s| s
        .agent
        .as_ref()
        .map(|a| a.finished)
        .unwrap_or(false)));
}

/// The MultiPlatform streams per-study JSONL (study-labelled, string
/// ids), publishes a consistent fair-share document, and restores from
/// its own snapshots.
#[test]
fn multi_platform_streams_and_restores() {
    let dir = std::env::temp_dir().join(format!("chopt-multi-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let snap_path = dir.join("snapshot.json");

    let mut platform = MultiPlatform::new(two_study_manifest(true), multi_factory())
        .with_event_logs(&dir)
        .unwrap()
        .with_snapshots(&snap_path, 2_000.0);
    platform.run_until(6_000.0);
    platform.snapshot_now().unwrap();
    let t_snap = platform.now();
    let events_snap = platform.scheduler().events_processed();
    assert!(platform.progress_events > 0);

    // Per-study streams exist, carry the study label, and keep ids as
    // strings (the ≥2^53 corruption fix).
    for name in ["alice", "bob"] {
        let events =
            chopt::storage::EventLog::read_all(dir.join(format!("events-{name}.jsonl"))).unwrap();
        assert!(!events.is_empty(), "study {name} must stream");
        for e in &events {
            assert_eq!(e.get("study").and_then(|v| v.as_str()), Some(name));
            if let Some(sid) = e.get("session") {
                let sid = sid.as_str().expect("session ids must be strings");
                sid.parse::<u64>().expect("session ids must round-trip");
            }
        }
    }

    // Fair-share doc is self-consistent.
    let fair = platform.fair_share_doc();
    let rows = fair.get("studies").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(rows.len(), 2);
    let held_sum: i64 = rows
        .iter()
        .map(|r| r.get("held").and_then(|v| v.as_i64()).unwrap_or(0))
        .sum();
    let used = fair.get("used").and_then(|v| v.as_i64()).unwrap();
    assert_eq!(held_sum, used, "per-study held must sum to cluster used");
    for r in rows {
        let quota = r.get("quota").and_then(|v| v.as_i64()).unwrap();
        assert_eq!(quota, 4);
    }

    // Restore from the snapshot file; both continuations agree.
    let mut restored = MultiPlatform::restore(&snap_path, multi_factory()).unwrap();
    assert_eq!(restored.now(), t_snap);
    assert_eq!(restored.scheduler().events_processed(), events_snap);
    restored.run_to_completion(1_000.0);
    platform.run_to_completion(1_000.0);
    let a = platform.into_outcome();
    let b = restored.into_outcome();
    assert_eq!(a.end_time, b.end_time);
    assert_eq!(a.events_processed, b.events_processed);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Failure injection for the multi-study scheduler (manifest
/// `failures: [t, ...]` per study): the named study's agent crashes at
/// the first master tick past `t`, its live sessions are checkpointed
/// into the stop pool, and the retry policy restarts it after the
/// backoff — work parked, never lost.  Because the crash consumes no
/// random draws and frees quota only through the ordinary fair-share
/// pass, a failure injected into study A never perturbs study B's RNG
/// stream (B's run is bit-identical with and without A's crash under
/// hard isolation).  The failure is part of the manifest, so a snapshot
/// taken after the crash restores deterministically too.
#[test]
fn injected_failure_never_perturbs_peer_study() {
    let manifest = |failures: &str| {
        let text = format!(
            r#"{{"cluster_gpus": 8, "borrow": false, "studies": [
                {{"name": "alice", "quota": 4, {failures} "config": {}}},
                {{"name": "bob", "quota": 4, "config": {}}}
            ]}}"#,
            config_json(10, 12, 4, 100),
            config_json(10, 8, 4, 101)
        );
        StudyManifest::from_json_str(&text).unwrap()
    };

    let run = |m: StudyManifest| {
        let mut sched = StudyScheduler::new(m, multi_factory());
        sched.run_to_completion();
        let restarts = sched.study("alice").unwrap().restarts();
        let stats = sched.fail_stats();
        (sched.into_outcome(), restarts, stats)
    };
    let (clean, clean_restarts, clean_stats) = run(manifest(""));
    let (failed, failed_restarts, failed_stats) = run(manifest(r#""failures": [2000],"#));

    // Alice crashed and recovered in the failure run (and only there).
    assert_eq!(clean_stats, (0, 0));
    assert_eq!(clean_restarts, 0);
    assert_eq!(failed_stats, (1, 0), "the failure record must be applied, not skipped");
    assert_eq!(failed_restarts, 1, "alice must restart through the retry policy");
    let alice = failed.study("alice").unwrap().agent.as_ref().unwrap();
    assert!(alice.finished, "alice must recover and run to completion");
    assert!(
        !alice.events.iter().any(|e| matches!(
            e,
            AgentEvent::Terminated("agent_failure") | AgentEvent::Terminated("quarantined")
        )),
        "a crash within the retry budget must not abort the study"
    );
    assert!(
        alice
            .events
            .iter()
            .any(|e| matches!(e, AgentEvent::Preempted(_, Pool::Stop))),
        "the crash must checkpoint live sessions into the stop pool"
    );
    assert!(
        alice.events.iter().any(|e| matches!(e, AgentEvent::Revived(_))),
        "checkpointed sessions must revive after the backoff"
    );

    // Bob's run is bit-identical either way: the injected failure never
    // touched his RNG stream or decisions.
    let bob_clean = clean.study("bob").unwrap().agent.as_ref().unwrap();
    let bob_failed = failed.study("bob").unwrap().agent.as_ref().unwrap();
    assert_eq!(agent_key(bob_clean), agent_key(bob_failed));
    let measures = |a: &Agent| -> Vec<String> {
        let mut ss: Vec<_> = a.sessions.values().collect();
        ss.sort_by_key(|s| s.id);
        ss.iter()
            .map(|s| {
                format!(
                    "{}:{}:{:?}",
                    s.id,
                    s.epochs,
                    s.best_measure(chopt::config::Order::Descending)
                )
            })
            .collect()
    };
    assert_eq!(measures(bob_clean), measures(bob_failed));

    // The failure replays: snapshot after the crash, restore, continue —
    // identical outcome, restart counters rebuilt by the replay.
    let mut original = StudyScheduler::new(manifest(r#""failures": [2000],"#), multi_factory());
    original.run_until(8_000.0);
    assert_eq!(original.fail_stats(), (1, 0), "crash lands well before t=8000");
    assert_eq!(original.study("alice").unwrap().restarts(), 1);
    let snap = original.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = StudyScheduler::restore(&snap, multi_factory()).unwrap();
    assert_eq!(restored.events_processed(), original.events_processed());
    assert_eq!(restored.fail_stats(), original.fail_stats());
    assert_eq!(
        restored.study("alice").unwrap().restarts(),
        original.study("alice").unwrap().restarts(),
        "replay must rebuild the restart counter"
    );
    original.run_to_completion();
    restored.run_to_completion();
    let (a, b) = (original.into_outcome(), restored.into_outcome());
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.end_time, b.end_time);
}

/// Acceptance: a spot-reclaim wave — four correlated study crashes at a
/// single master tick — ends with zero silently lost sessions.  Every
/// affected study's live sessions are checkpointed into its stop pool,
/// the study restarts after its backoff, the parked sessions revive,
/// and the run terminates with every study complete.
#[test]
fn reclaim_wave_recovers_every_study_with_zero_lost_sessions() {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": false,
            "scenario": {{"sources": [
              {{"kind": "spot_reclaim", "slots": 4, "wave_size": 4,
                "first_at": 3000, "every": 0, "waves": 1, "seed": "9"}}
            ]}},
            "studies": [
              {{"name": "s0", "quota": 2, "config": {}}},
              {{"name": "s1", "quota": 2, "config": {}}},
              {{"name": "s2", "quota": 2, "config": {}}},
              {{"name": "s3", "quota": 2, "config": {}}}
            ]}}"#,
        config_json(10, 6, 2, 100),
        config_json(10, 6, 2, 101),
        config_json(10, 6, 2, 102),
        config_json(10, 6, 2, 103)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();
    let mut sched = StudyScheduler::new(manifest, multi_factory());
    sched.run_to_completion();
    assert_eq!(sched.fail_stats(), (4, 0), "the wave must hit all four studies");
    for st in sched.studies() {
        assert_eq!(st.restarts(), 1, "study '{}' must restart exactly once", st.name());
        assert!(st.health().is_ok(), "study '{}' must end healthy", st.name());
    }
    let out = sched.into_outcome();
    for s in &out.studies {
        let a = s.agent.as_ref().unwrap();
        assert!(a.finished, "study '{}' must finish after the wave", s.name);
        assert!(
            !a.events.iter().any(|e| matches!(
                e,
                AgentEvent::Terminated("agent_failure") | AgentEvent::Terminated("quarantined")
            )),
            "study '{}' must not be aborted",
            s.name
        );
        assert!(
            a.events.iter().any(|e| matches!(e, AgentEvent::Revived(_))),
            "study '{}': parked sessions must revive",
            s.name
        );
        a.pools.check_invariants().unwrap();
        // Zero silently lost sessions: every session ever created is in
        // a pool, and nothing still claims GPUs.
        assert_eq!(
            a.pools.live_count() + a.pools.stop_count() + a.pools.dead_count(),
            a.created,
            "study '{}' lost sessions",
            s.name
        );
    }
    assert_eq!(out.cluster.held_by_chopt(), 0);
}

/// Satellite: a composed scenario (diurnal + flash-crowd demand, a
/// reclaim wave, a degraded-node episode) is replay-safe.  A snapshot
/// taken mid-weather restores bit-identically, and an `?at_event=`
/// scrub (`restore_at`) re-converges to the reference outcome, because
/// the weather is a pure function of the manifest — no cursors or
/// consumed-flags are ever serialized.
#[test]
fn composed_scenario_replays_bit_identically() {
    let text = format!(
        r#"{{"cluster_gpus": 8, "borrow": true,
            "scenario": {{"sources": [
              {{"kind": "diurnal", "total_gpus": 8, "base": 0.2, "amp": 0.2,
                "period": 20000, "jitter": 0.05, "seed": "5"}},
              {{"kind": "flash_crowd", "total_gpus": 8, "spike": 0.5,
                "first_at": 4000, "every": 0, "duration": 1500, "seed": "6"}},
              {{"kind": "spot_reclaim", "slots": 2, "wave_size": 1,
                "first_at": 5000, "every": 0, "waves": 1, "seed": "7"}},
              {{"kind": "degraded_node", "gpus": 2, "first_at": 7000,
                "every": 0, "duration": 2000, "seed": "8"}}
            ]}},
            "studies": [
              {{"name": "alice", "quota": 4, "config": {}}},
              {{"name": "bob", "quota": 4, "config": {}}}
            ]}}"#,
        config_json(10, 8, 3, 100),
        config_json(10, 8, 3, 101)
    );
    let manifest = StudyManifest::from_json_str(&text).unwrap();
    assert!(manifest.scenario.is_some());

    // Reference: straight through, no interruption.
    let mut reference = StudyScheduler::new(manifest.clone(), multi_factory());
    reference.run_to_completion();
    let ref_out = reference.into_outcome();

    // Snapshot mid-weather (after the reclaim wave landed), restore.
    let mut original = StudyScheduler::new(manifest, multi_factory());
    original.run_until(6_000.0);
    assert_eq!(original.fail_stats(), (1, 0), "the reclaim wave must land before t=6000");
    let snap = original.snapshot_json();
    let snap = chopt::util::json::parse(&snap.to_string_pretty()).unwrap();
    let mut restored = StudyScheduler::restore(&snap, multi_factory()).unwrap();
    assert!(restored.manifest().scenario.is_some(), "scenario must survive the snapshot");
    assert_eq!(restored.now(), original.now());
    assert_eq!(restored.events_processed(), original.events_processed());
    assert_eq!(restored.fail_stats(), original.fail_stats(), "replay must rebuild fault counters");
    let half = original.events_processed() / 2;
    restored.run_to_completion();
    let restored_out = restored.into_outcome();
    assert_eq!(ref_out.end_time, restored_out.end_time);
    assert_eq!(ref_out.events_processed, restored_out.events_processed);
    for (a, b) in ref_out.studies.iter().zip(restored_out.studies.iter()) {
        assert_eq!(a.name, b.name);
        assert_eq!(
            agent_key(a.agent.as_ref().unwrap()),
            agent_key(b.agent.as_ref().unwrap()),
            "study {} diverged through snapshot/restore",
            a.name
        );
    }

    // `?at_event=` scrub: replay only half the recorded events, then run
    // forward — the weather re-derives from the manifest, so the scrub
    // converges to the same final outcome.
    let mut scrubbed = StudyScheduler::restore_at(&snap, multi_factory(), half).unwrap();
    assert_eq!(scrubbed.events_processed(), half);
    scrubbed.run_to_completion();
    let scrub_out = scrubbed.into_outcome();
    assert_eq!(ref_out.end_time, scrub_out.end_time);
    assert_eq!(ref_out.events_processed, scrub_out.events_processed);
    for (a, b) in ref_out.studies.iter().zip(scrub_out.studies.iter()) {
        assert_eq!(
            agent_key(a.agent.as_ref().unwrap()),
            agent_key(b.agent.as_ref().unwrap()),
            "study {} diverged through the at_event scrub",
            a.name
        );
    }
}

/// Cross-study reclaim picks the most recently granted live session
/// first (LIFO over the live pool), deterministically — no RNG draw —
/// so a preemption never perturbs the victim study's decision stream.
#[test]
fn preemption_pauses_most_recent_sessions_first() {
    let mut agent = Agent::new(1, cfg(-1, 40, 4, 77), Box::new(SurrogateTrainer::new(7)));
    let mut cluster = Cluster::new(4);
    let mut reqs = Vec::new();
    agent.fill(&mut cluster, 0.0, &mut reqs);
    let live = agent.pools.live().to_vec();
    assert_eq!(live.len(), 4, "fill should launch to the 4-GPU target");

    agent.preempt_pause_to_target(2, &mut cluster, 10.0, &mut reqs);

    // Victims are the most recently launched sessions, newest first.
    let preempted: Vec<_> = agent
        .events
        .iter()
        .filter_map(|e| match e {
            AgentEvent::Preempted(sid, Pool::Stop) => Some(*sid),
            _ => None,
        })
        .collect();
    assert_eq!(preempted, vec![live[3], live[2]]);
    // Survivors are the oldest grants, order preserved.
    assert_eq!(agent.pools.live(), &live[..2]);
    // Victims sit in the stop pool with revival priority.
    for sid in &preempted {
        assert_eq!(agent.pools.locate(*sid), Some(Pool::Stop));
        assert!(agent.pools.is_preempted(*sid));
    }
    agent.pools.check_invariants().unwrap();
}
