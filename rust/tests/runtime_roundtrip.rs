//! Integration: python-AOT artifacts -> rust PJRT load/execute round trip.
//!
//! These tests require `make artifacts` (they ARE the python->rust
//! contract check); they skip with a note when artifacts are missing so
//! `cargo test` stays green on a fresh checkout.

use chopt::hparam::{Assignment, Value};
use chopt::nsml::SessionId;
use chopt::runtime::{HostTensor, Manifest, Runtime};
use chopt::trainer::{real::RealTrainer, Trainer};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn init_artifact_produces_full_state() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let platform = rt.platform().to_lowercase();
    assert!(
        platform.contains("cpu") || platform.contains("host"),
        "unexpected platform {platform}"
    );
    let out = rt
        .execute("ic_d1_w1_init", &[HostTensor::scalar_i32(7)])
        .unwrap();
    let spec = rt.manifest.artifact("ic_d1_w1_init").unwrap();
    assert_eq!(out.len(), spec.n_outputs);
    // Params initialized He-normal: w_in must have nonzero variance.
    let w_in = out[0].as_f32().unwrap();
    let mean: f32 = w_in.iter().sum::<f32>() / w_in.len() as f32;
    let var: f32 = w_in.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / w_in.len() as f32;
    assert!(var > 1e-4, "w_in variance {var}");
    // Velocities (second half) start at zero.
    let n = out.len();
    let v_last = out[n - 1].as_f32().unwrap();
    assert!(v_last.iter().all(|&x| x == 0.0));
    // Deterministic in the seed.
    let out2 = rt
        .execute("ic_d1_w1_init", &[HostTensor::scalar_i32(7)])
        .unwrap();
    assert_eq!(out[0], out2[0]);
    let out3 = rt
        .execute("ic_d1_w1_init", &[HostTensor::scalar_i32(8)])
        .unwrap();
    assert_ne!(out[0], out3[0]);
}

#[test]
fn train_step_decreases_loss() {
    let dir = require_artifacts!();
    let mut trainer = RealTrainer::new(&dir, 42).unwrap();
    let mut hp = Assignment::new();
    hp.set("lr", Value::Float(0.08));
    hp.set("momentum", Value::Float(0.9));
    let id = SessionId(1);
    let first = trainer.train(id, "ic_d1_w1", &hp, 1).unwrap();
    assert!(first.loss.is_finite(), "loss {:?}", first);
    let later = trainer.train(id, "ic_d1_w1", &hp, 6).unwrap();
    assert!(
        later.loss < first.loss,
        "loss should fall: {} -> {}",
        first.loss,
        later.loss
    );
    assert!(later.measure >= 0.0 && later.measure <= 100.0);
    assert_eq!(trainer.epochs_done(id), 6);
}

#[test]
fn random_erasing_hyperparameter_is_runtime() {
    // re_prob is a scalar input: the same artifact trains with and
    // without augmentation — no recompilation.
    let dir = require_artifacts!();
    let mut trainer = RealTrainer::new(&dir, 43).unwrap();
    let mut hp = Assignment::new();
    hp.set("lr", Value::Float(0.05));
    hp.set("prob", Value::Float(0.9));
    hp.set("sh", Value::Float(0.5));
    let r = trainer.train(SessionId(2), "ic_d1_w1", &hp, 2).unwrap();
    assert!(r.loss.is_finite());
}

#[test]
fn clone_state_copies_weights() {
    let dir = require_artifacts!();
    let mut trainer = RealTrainer::new(&dir, 44).unwrap();
    let hp = Assignment::new();
    trainer.train(SessionId(3), "ic_d1_w1", &hp, 2).unwrap();
    trainer.clone_state(SessionId(3), SessionId(4)).unwrap();
    assert_eq!(trainer.epochs_done(SessionId(4)), 2);
    // The clone continues training from the copied weights.
    let r = trainer.train(SessionId(4), "ic_d1_w1", &hp, 3).unwrap();
    assert!(r.loss.is_finite());
    trainer.drop_state(SessionId(3));
    assert_eq!(trainer.state_count(), 1);
}

#[test]
fn qa_variant_trains() {
    let dir = require_artifacts!();
    let mut trainer = RealTrainer::new(&dir, 45).unwrap();
    let mut hp = Assignment::new();
    hp.set("lr", Value::Float(0.3));
    hp.set("momentum", Value::Float(0.9));
    hp.set("dropout", Value::Float(0.1));
    let id = SessionId(5);
    let first = trainer.train(id, "qa_bidaf", &hp, 1).unwrap();
    let later = trainer.train(id, "qa_bidaf", &hp, 5).unwrap();
    assert!(
        later.loss < first.loss,
        "qa loss should fall: {} -> {}",
        first.loss,
        later.loss
    );
}

#[test]
fn depth_variants_have_increasing_param_counts() {
    let dir = require_artifacts!();
    let trainer = RealTrainer::new(&dir, 46).unwrap();
    let hp = Assignment::new();
    let p1 = trainer.param_count("ic_d1_w1", &hp);
    let p2 = trainer.param_count("ic_d2_w1", &hp);
    let p3 = trainer.param_count("ic_d3_w1", &hp);
    let p2w = trainer.param_count("ic_d2_w2", &hp);
    assert!(p1 < p2 && p2 < p3, "{p1} {p2} {p3}");
    assert!(p2w > p2, "widen must add params: {p2w} vs {p2}");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let err = rt
        .execute("ic_d1_w1_init", &[HostTensor::scalar_f32(1.0)])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("dtype"), "got: {msg}");
}
