//! The versioned `sweep.json` comparison artifact.
//!
//! Folds per-cell `cell.json` documents into one document: the full
//! grid in expansion order, per-axis marginals (mean score / GPU-hours
//! / time-to-target over every cell sharing an axis value), and
//! rankings.  Every field is a pure function of (spec, cell records) —
//! no wall clock, no host identity — so re-running the same spec
//! produces byte-identical output.

use chopt_core::util::json::Value as Json;

use crate::spec::{CellPlan, SweepSpec};

/// Bumped whenever the artifact layout changes shape.
pub const SWEEP_SCHEMA_VERSION: f64 = 1.0;

/// Discriminator so `SweepSource`/tools can reject non-sweep JSON.
pub const SWEEP_KIND: &str = "chopt_sweep";

fn metric(rec: &Json, key: &str) -> Option<f64> {
    rec.get("metrics").and_then(|m| m.get(key)).and_then(|v| v.as_f64())
}

fn mean(vals: &[f64]) -> Json {
    if vals.is_empty() {
        Json::Null
    } else {
        Json::Num(vals.iter().sum::<f64>() / vals.len() as f64)
    }
}

/// One marginal row: aggregate metrics over every cell that shares an
/// axis value.
fn marginal_row(name: &str, idx: &[usize], records: &[Json]) -> Json {
    let recs: Vec<&Json> = idx.iter().map(|&i| &records[i]).collect();
    let scores: Vec<f64> = recs.iter().filter_map(|r| metric(r, "score")).collect();
    let bests: Vec<f64> = recs
        .iter()
        .filter_map(|r| metric(r, "best_objective"))
        .collect();
    let gpu_hours: Vec<f64> = recs.iter().filter_map(|r| metric(r, "gpu_hours")).collect();
    let hits: Vec<f64> = recs
        .iter()
        .filter_map(|r| metric(r, "time_to_target"))
        .collect();
    Json::obj()
        .with("name", Json::Str(name.to_string()))
        .with("cells", Json::Num(recs.len() as f64))
        .with("mean_score", mean(&scores))
        .with("mean_best", mean(&bests))
        .with("mean_gpu_hours", mean(&gpu_hours))
        .with("target_hits", Json::Num(hits.len() as f64))
        .with("mean_time_to_target", mean(&hits))
}

/// Marginals for one axis, in the axis's declaration order.  `pick`
/// selects the plan's value on that axis.
fn axis_marginals(
    names: &[String],
    plans: &[CellPlan],
    records: &[Json],
    pick: impl Fn(&CellPlan) -> &str,
) -> Json {
    let rows = names
        .iter()
        .map(|name| {
            let idx: Vec<usize> = plans
                .iter()
                .enumerate()
                .filter(|(_, p)| pick(p) == name)
                .map(|(i, _)| i)
                .collect();
            marginal_row(name, &idx, records)
        })
        .collect();
    Json::Arr(rows)
}

/// Rank cell ids by a metric.  Cells missing the metric sort last;
/// ties keep grid order (the sort is stable).
fn ranking(
    plans: &[CellPlan],
    records: &[Json],
    key: &str,
    descending: bool,
) -> Json {
    let mut order: Vec<(usize, Option<f64>)> = records
        .iter()
        .enumerate()
        .map(|(i, r)| (i, metric(r, key)))
        .collect();
    order.sort_by(|(_, a), (_, b)| match (a, b) {
        (Some(x), Some(y)) => {
            if descending {
                y.total_cmp(x)
            } else {
                x.total_cmp(y)
            }
        }
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => std::cmp::Ordering::Equal,
    });
    Json::Arr(
        order
            .into_iter()
            .map(|(i, _)| Json::Str(plans[i].id.clone()))
            .collect(),
    )
}

/// Build the sweep artifact from the expanded plans and their cell
/// records (both in grid order, same length).
pub fn build_artifact(spec: &SweepSpec, plans: &[CellPlan], records: &[Json]) -> Json {
    debug_assert_eq!(plans.len(), records.len());
    let scenario_names: Vec<String> = spec.scenarios.iter().map(|a| a.name.clone()).collect();
    let tuner_names: Vec<String> = spec.tuners.iter().map(|a| a.name.clone()).collect();
    let policy_names: Vec<String> = spec.policies.iter().map(|a| a.name.clone()).collect();
    let names_arr = |names: &[String]| {
        Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect())
    };
    Json::obj()
        .with("schema_version", Json::Num(SWEEP_SCHEMA_VERSION))
        .with("kind", Json::Str(SWEEP_KIND.to_string()))
        .with("seed", Json::Str(spec.seed.to_string()))
        .with(
            "axes",
            Json::obj()
                .with("scenarios", names_arr(&scenario_names))
                .with("tuners", names_arr(&tuner_names))
                .with("policies", names_arr(&policy_names)),
        )
        .with("cells", Json::Arr(records.to_vec()))
        .with(
            "marginals",
            Json::obj()
                .with(
                    "scenarios",
                    axis_marginals(&scenario_names, plans, records, |p| &p.scenario),
                )
                .with(
                    "tuners",
                    axis_marginals(&tuner_names, plans, records, |p| &p.tuner),
                )
                .with(
                    "policies",
                    axis_marginals(&policy_names, plans, records, |p| &p.policy),
                ),
        )
        .with(
            "rankings",
            Json::obj()
                .with("by_score", ranking(plans, records, "score", true))
                .with("by_gpu_hours", ranking(plans, records, "gpu_hours", false))
                .with(
                    "by_time_to_target",
                    ranking(plans, records, "time_to_target", false),
                ),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: &str, score: Option<f64>, gpu: f64) -> Json {
        let mut metrics = Json::obj().with("gpu_hours", Json::Num(gpu));
        metrics = metrics.with(
            "score",
            score.map(Json::Num).unwrap_or(Json::Null),
        );
        Json::obj()
            .with("id", Json::Str(id.to_string()))
            .with("metrics", metrics)
    }

    fn toy_spec() -> SweepSpec {
        let doc = chopt_core::util::json::parse(
            r#"{
                "base_manifest": {"cluster_gpus": 4,
                    "studies": [{"name": "a", "quota": 2, "config": {
                        "h_params": {"lr": {"parameters": [0.005, 0.09],
                            "distribution": "log_uniform", "type": "float",
                            "p_range": [0.001, 0.2]}},
                        "measure": "test/accuracy", "order": "descending",
                        "step": 10, "population": 2, "tune": {"random": {}},
                        "termination": {"max_session_number": 4},
                        "model": "surrogate:resnet", "max_epochs": 40,
                        "max_gpus": 2, "seed": 1}}]},
                "axes": {
                    "scenarios": [{"name": "calm", "scenario": null},
                                  {"name": "storm", "scenario": null}],
                    "tuners": [{"name": "random", "tune": {"random": {}}}],
                    "policies": [{"name": "strict"}]
                }
            }"#,
        )
        .unwrap();
        SweepSpec::from_json(&doc, None).unwrap()
    }

    #[test]
    fn rankings_order_and_null_metrics_last() {
        let spec = toy_spec();
        let plans = spec.cells().unwrap();
        assert_eq!(plans.len(), 2);
        let records = vec![
            rec(&plans[0].id, None, 5.0),
            rec(&plans[1].id, Some(0.9), 2.0),
        ];
        let art = build_artifact(&spec, &plans, &records);
        let by_score: Vec<&str> = art
            .path("rankings.by_score")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        // The cell with a score ranks ahead of the score-less one.
        assert_eq!(by_score, vec![plans[1].id.as_str(), plans[0].id.as_str()]);
        let by_gpu: Vec<&str> = art
            .path("rankings.by_gpu_hours")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        assert_eq!(by_gpu, vec![plans[1].id.as_str(), plans[0].id.as_str()]);
        assert_eq!(
            art.get("kind").and_then(|v| v.as_str()),
            Some(SWEEP_KIND)
        );
        // Marginals: the "calm" scenario row covers exactly one cell.
        let row = art
            .path("marginals.scenarios")
            .and_then(|v| v.as_arr())
            .unwrap()
            .first()
            .unwrap();
        assert_eq!(row.get("name").and_then(|v| v.as_str()), Some("calm"));
        assert_eq!(row.get("cells").and_then(|v| v.as_f64()), Some(1.0));
    }
}
