//! `chopt-sweep` — the policy-evaluation harness (paper §5's iterative
//! analysis procedure, made a first-class subsystem).
//!
//! CHOPT's pitch is not just running HyperOpt but *comparing* tuners
//! and sharing policies across conditions.  This crate turns that loop
//! into a deterministic grid evaluation:
//!
//! * [`spec`] — a sweep spec JSON declares three axes (scenarios ×
//!   tuner configs × scheduler policies) over one base manifest; the
//!   cross product expands into [`spec::CellPlan`]s, each carrying a
//!   fully-resolved canonical manifest and a content hash of
//!   (manifest, scenario, tuner, policy, seed, drive parameters).
//! * [`runner`] — runs every cell as an independent deterministic
//!   multi-study simulation on a bounded worker pool (cells share
//!   nothing, so the worker count is purely a wall-clock knob: output
//!   bytes are identical across pool sizes).  Completed cells are
//!   recognized by their hash, so `--resume` recomputes only missing
//!   or stale ones.
//! * [`artifact`] — folds the per-cell metrics into a versioned
//!   `sweep.json` comparison artifact: the full grid, per-axis
//!   marginals, and rankings.  No wall-clock timestamps anywhere, so a
//!   re-run of the same spec is byte-identical.
//! * [`serve`] — [`serve::SweepSource`]: a read-only
//!   `RunSource`/`CommandSink` over a sweep directory, answering
//!   `GET /api/v1/sweep` and `/api/v1/sweep/cells/<id>` through the
//!   unchanged control-plane server (fixed generation, so the response
//!   cache pins every body).
//! * [`validate`] — parse + semantic checks for manifests, scenarios,
//!   and sweep specs with `path:line:col` diagnostics (the
//!   `chopt validate` subcommand; the sweep CLI fails fast on it
//!   before burning grid cells).

pub mod artifact;
pub mod runner;
pub mod serve;
pub mod spec;
pub mod validate;

pub use artifact::{build_artifact, SWEEP_KIND, SWEEP_SCHEMA_VERSION};
pub use runner::{run_sweep, SweepOptions, SweepOutcome};
pub use serve::SweepSource;
pub use spec::{fnv1a64, CellPlan, SweepSpec};
pub use validate::{
    validate_manifest_file, validate_scenario_file, validate_sweep_file, Diagnostic, Report,
    Severity,
};
