//! Cell runner + bounded worker pool.
//!
//! Each cell is one deterministic multi-study run driven exactly like
//! `chopt multi`'s single-scheduler path: chunked advances split at
//! every scenario submission time, per-study JSONL event logs,
//! periodic snapshots, and the same final exports (`snapshot.json`,
//! `fair_share.json`, `sessions-<study>.json`).  A cell directory is
//! therefore also a valid stored-run directory (`chopt serve --store
//! <out>/cells/<id>` works).
//!
//! Cells share no mutable state — each owns its manifest, scheduler,
//! RNGs, and output directory — so the worker-pool size is purely a
//! wall-clock knob: every byte written is identical across pool sizes
//! (property-tested in `rust/tests/sweep.rs`).

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context};
use chopt_core::config::Order;
use chopt_core::trainer::surrogate::default_multi_factory;
use chopt_core::util::json::{parse, Value as Json};
use chopt_control::platform::MultiPlatform;
use chopt_engine::coordinator::{StudyManifest, StudySpec};

use crate::artifact::build_artifact;
use crate::spec::{CellPlan, SweepSpec};

/// Schema version stamped into every `cell.json`.
pub const CELL_SCHEMA_VERSION: f64 = 1.0;

/// Worker-pool and resume knobs for one sweep invocation.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Cell-worker threads (outer parallelism; inner stepping stays
    /// serial so cells match standalone runs byte for byte).
    pub workers: usize,
    /// Keep completed cells whose hash matches the plan; recompute
    /// only missing or stale ones.
    pub resume: bool,
    /// Suppress per-cell progress lines.
    pub quiet: bool,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            workers: 2,
            resume: false,
            quiet: true,
        }
    }
}

/// What one sweep invocation did: the artifact plus which cells were
/// actually computed vs reused.
#[derive(Debug)]
pub struct SweepOutcome {
    pub artifact: Json,
    pub cells_total: usize,
    pub cells_run: Vec<String>,
    pub cells_skipped: Vec<String>,
}

/// Expand the spec, run (or reuse) every cell on a bounded worker
/// pool, and write `<out>/sweep.json`.  A fresh run (no `resume`)
/// clears `<out>/cells/` first, so re-running the same spec is
/// byte-identical from a clean slate.
pub fn run_sweep(
    spec: &SweepSpec,
    out: impl AsRef<Path>,
    opts: &SweepOptions,
) -> anyhow::Result<SweepOutcome> {
    let out = out.as_ref();
    let plans = spec.cells()?;
    std::fs::create_dir_all(out)
        .with_context(|| format!("creating sweep dir {}", out.display()))?;
    let cells_dir = out.join("cells");
    if !opts.resume {
        let _ = std::fs::remove_dir_all(&cells_dir);
        let _ = std::fs::remove_file(out.join("sweep.json"));
    }
    std::fs::create_dir_all(&cells_dir)?;

    let mut skipped = Vec::new();
    let mut work: Vec<&CellPlan> = Vec::new();
    for plan in &plans {
        if opts.resume && cell_complete(&cells_dir.join(&plan.id), &plan.hash) {
            skipped.push(plan.id.clone());
        } else {
            work.push(plan);
        }
    }

    let workers = opts.workers.clamp(1, work.len().max(1));
    let next = AtomicUsize::new(0);
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= work.len() {
                    break;
                }
                let plan = work[i];
                let dir = cells_dir.join(&plan.id);
                match run_cell(plan, spec, &dir) {
                    Ok(doc) => {
                        if !opts.quiet {
                            let best = doc
                                .path("metrics.best_objective")
                                .and_then(|v| v.as_f64())
                                .map(|b| format!("{b:.4}"))
                                .unwrap_or_else(|| "-".into());
                            let events = doc
                                .path("metrics.events")
                                .and_then(|v| v.as_i64())
                                .unwrap_or(0);
                            println!("cell {:<32} best={best} events={events}", plan.id);
                        }
                    }
                    Err(e) => failures
                        .lock()
                        .unwrap()
                        .push(format!("cell '{}': {e:#}", plan.id)),
                }
            });
        }
    });
    let failures = failures.into_inner().unwrap();
    if !failures.is_empty() {
        bail!(
            "{} of {} cells failed:\n  {}",
            failures.len(),
            plans.len(),
            failures.join("\n  ")
        );
    }

    // Assemble the artifact from disk in grid order — reused and fresh
    // cells go through the same bytes, so resume cannot perturb the
    // artifact.
    let mut records = Vec::with_capacity(plans.len());
    for plan in &plans {
        let path = cells_dir.join(&plan.id).join("cell.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let hash = doc.get("hash").and_then(|v| v.as_str()).unwrap_or("");
        if hash != plan.hash {
            bail!(
                "cell '{}' hash mismatch after run ({} vs planned {})",
                plan.id,
                hash,
                plan.hash
            );
        }
        records.push(doc);
    }
    let artifact = build_artifact(spec, &plans, &records);
    std::fs::write(out.join("sweep.json"), artifact.to_string_pretty())
        .with_context(|| format!("writing {}", out.join("sweep.json").display()))?;
    Ok(SweepOutcome {
        artifact,
        cells_total: plans.len(),
        cells_run: work.iter().map(|p| p.id.clone()).collect(),
        cells_skipped: skipped,
    })
}

/// A cell is complete iff its `cell.json` parses and records the
/// planned content hash — the resume criterion.
pub fn cell_complete(dir: &Path, hash: &str) -> bool {
    std::fs::read_to_string(dir.join("cell.json"))
        .ok()
        .and_then(|text| parse(&text).ok())
        .and_then(|doc| doc.get("hash").and_then(|v| v.as_str()).map(|h| h == hash))
        .unwrap_or(false)
}

/// Take the scenario-driven submissions out of a manifest — the same
/// rule `chopt multi` applies: each submission is admitted by
/// splitting the advance at its requested time, and a
/// submissions-only scenario is dropped so parallel stepping stays
/// eligible.
pub fn take_submissions(manifest: &mut StudyManifest) -> anyhow::Result<Vec<(f64, StudySpec)>> {
    let mut subs = Vec::new();
    if let Some(sc) = manifest.scenario.as_mut() {
        let taken = std::mem::take(&mut sc.submissions);
        for (i, sub) in taken.iter().enumerate() {
            subs.push((
                sub.at,
                StudySpec::from_json(&sub.spec, manifest.studies.len() + i)?,
            ));
        }
        if sc.sources.is_empty() {
            manifest.scenario = None;
        }
    }
    subs.sort_by(|a, b| a.0.total_cmp(&b.0));
    Ok(subs)
}

/// One drive chunk, split at every pending submission time (the
/// admission rule shared with `chopt multi`); jumps idle gaps to the
/// next submission.  Errors on a rejected submission — in a sweep that
/// is a spec bug, not something to log and shrug off.
fn advance_cell(
    platform: &mut MultiPlatform<'_>,
    subs: &mut Vec<(f64, StudySpec)>,
    chunk: f64,
) -> anyhow::Result<u64> {
    let target = platform.now() + chunk;
    let mut n = 0;
    while subs.first().map(|&(at, _)| at <= target).unwrap_or(false) {
        let (at, spec) = subs.remove(0);
        n += platform.run_until(at);
        n += admit(platform, spec, at)?;
    }
    n += platform.advance((target - platform.now()).max(0.0));
    if n == 0 && !subs.is_empty() {
        let (at, spec) = subs.remove(0);
        n += platform.run_until(at);
        n += admit(platform, spec, at)?;
    }
    Ok(n)
}

fn admit(platform: &mut MultiPlatform<'_>, spec: StudySpec, at: f64) -> anyhow::Result<u64> {
    let name = spec.name.clone();
    match platform.submit_study(spec, at) {
        Some(_) => Ok(1),
        None => bail!(
            "scenario submission '{name}' rejected (duplicate name, bad quota/priority, \
             or quota does not fit)"
        ),
    }
}

/// Run one cell into `dir` (wiped first) and write `cell.json`.
/// Returns the cell document.
pub fn run_cell(plan: &CellPlan, spec: &SweepSpec, dir: &Path) -> anyhow::Result<Json> {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir)?;
    let mut manifest = plan.manifest()?;
    let mut subs = take_submissions(&mut manifest)?;
    let snap_path = dir.join("snapshot.json");
    let mut platform = MultiPlatform::new(manifest, default_multi_factory)
        .with_event_logs(dir)?
        .with_snapshots(&snap_path, spec.snapshot_every);

    let mut time_to_target: Option<f64> = None;
    loop {
        let n = advance_cell(&mut platform, &mut subs, spec.chunk)?;
        if let (Some(target), None) = (spec.target_measure, time_to_target) {
            if target_hit(&platform, target) {
                time_to_target = Some(platform.now());
            }
        }
        if (platform.is_done() && subs.is_empty()) || n == 0 {
            break;
        }
    }
    if !platform.is_done() {
        bail!("cell run stalled before completion (t={:.0}s)", platform.now());
    }
    platform.snapshot_now()?;
    std::fs::write(
        dir.join("fair_share.json"),
        platform.fair_share_doc().to_string_pretty(),
    )?;
    let names: Vec<String> = platform
        .scheduler()
        .studies()
        .iter()
        .map(|s| s.name().to_string())
        .collect();
    for name in &names {
        std::fs::write(
            dir.join(format!("sessions-{name}.json")),
            platform.study_sessions_doc(name).to_string_pretty(),
        )?;
    }

    let doc = Json::obj()
        .with("cell_schema_version", Json::Num(CELL_SCHEMA_VERSION))
        .with("id", Json::Str(plan.id.clone()))
        .with("hash", Json::Str(plan.hash.clone()))
        .with("scenario", Json::Str(plan.scenario.clone()))
        .with("tuner", Json::Str(plan.tuner.clone()))
        .with("policy", Json::Str(plan.policy.clone()))
        .with("seed", Json::Str(plan.seed.to_string()))
        .with("metrics", cell_metrics(&platform, time_to_target));
    std::fs::write(
        dir.join("manifest.json"),
        plan.manifest_doc.to_string_pretty(),
    )?;
    std::fs::write(dir.join("cell.json"), doc.to_string_pretty())?;
    Ok(doc)
}

/// Has any study's best objective crossed `target` under its own
/// order?  (Equality counts as a hit.)
fn target_hit(platform: &MultiPlatform<'_>, target: f64) -> bool {
    platform.scheduler().studies().iter().any(|st| {
        st.agent()
            .and_then(|a| a.best())
            .map(|(_, best)| best == target || st.config().order.better(best, target))
            .unwrap_or(false)
    })
}

/// Order-normalized comparison score: higher is always better, so
/// ascending-order (loss) studies rank alongside descending-order
/// (accuracy) ones.
fn score_of(order: Order, measure: f64) -> f64 {
    match order {
        Order::Descending => measure,
        Order::Ascending => -measure,
    }
}

/// Extract the per-cell comparison metrics from a finished platform.
/// Everything here is a pure function of the deterministic simulation
/// state — no wall clock, no host identity.
fn cell_metrics(platform: &MultiPlatform<'_>, time_to_target: Option<f64>) -> Json {
    let sched = platform.scheduler();
    let now = sched.now();
    let cluster = sched.cluster();
    let gpu_hours = cluster.chopt_gpu_hours(now);
    let total = cluster.total();
    let hours = now / 3600.0;
    let (applied, skipped) = sched.fail_stats();

    let mut best: Option<(String, f64, f64)> = None;
    let mut created = 0usize;
    let mut live = 0usize;
    let mut parked = 0usize;
    let mut killed = 0usize;
    let mut restarts = 0u64;
    let mut quarantined = 0usize;
    let mut rows = Vec::new();
    for st in sched.studies() {
        let st_best = st.agent().and_then(|a| a.best()).map(|(_, m)| m);
        let st_score = st_best.map(|m| score_of(st.config().order, m));
        if let (Some(m), Some(sc)) = (st_best, st_score) {
            if best.as_ref().map(|(_, _, b)| sc > *b).unwrap_or(true) {
                best = Some((st.name().to_string(), m, sc));
            }
        }
        let (s_created, s_live, s_parked, s_killed) = st
            .agent()
            .map(|a| {
                (
                    a.sessions.len(),
                    a.pools.live_count(),
                    a.pools.stop_count(),
                    a.pools.dead_count(),
                )
            })
            .unwrap_or((0, 0, 0, 0));
        created += s_created;
        live += s_live;
        parked += s_parked;
        killed += s_killed;
        restarts += st.restarts() as u64;
        if st.health_label() == "quarantined" {
            quarantined += 1;
        }
        rows.push(
            Json::obj()
                .with("study", Json::Str(st.name().to_string()))
                .with("best", st_best.map(Json::Num).unwrap_or(Json::Null))
                .with("score", st_score.map(Json::Num).unwrap_or(Json::Null))
                .with("sessions", Json::Num(s_created as f64))
                .with("restarts", Json::Num(st.restarts() as f64))
                .with("health", Json::Str(st.health_label().to_string()))
                .with("done", Json::Bool(st.done())),
        );
    }
    let (best_study, best_objective, best_score) = match best {
        Some((name, m, sc)) => (Json::Str(name), Json::Num(m), Json::Num(sc)),
        None => (Json::Null, Json::Null, Json::Null),
    };
    Json::obj()
        .with("end_time", Json::Num(now))
        .with("events", Json::Num(sched.events_processed() as f64))
        .with("best_objective", best_objective)
        .with("best_study", best_study)
        .with("score", best_score)
        .with("gpu_hours", Json::Num(gpu_hours))
        .with(
            "utilization_integral",
            Json::Num(if total > 0 {
                gpu_hours / total as f64
            } else {
                0.0
            }),
        )
        .with(
            "avg_utilization",
            Json::Num(if total > 0 && hours > 0.0 {
                gpu_hours / (total as f64 * hours)
            } else {
                0.0
            }),
        )
        .with("sessions_created", Json::Num(created as f64))
        .with("sessions_live", Json::Num(live as f64))
        .with("sessions_parked", Json::Num(parked as f64))
        .with("sessions_killed", Json::Num(killed as f64))
        .with("restarts", Json::Num(restarts as f64))
        .with("quarantined", Json::Num(quarantined as f64))
        .with("failures_applied", Json::Num(applied as f64))
        .with("failures_skipped", Json::Num(skipped as f64))
        .with(
            "time_to_target",
            time_to_target.map(Json::Num).unwrap_or(Json::Null),
        )
        .with("studies", Json::Arr(rows))
}
