//! Sweep spec: three declarative axes over one base manifest, expanded
//! into content-addressed cells.
//!
//! ```json
//! {
//!   "base_manifest": "examples/multi_study.json",
//!   "seed": "0",
//!   "chunk": 3600,
//!   "snapshot_every": 14400,
//!   "target_measure": 0.6,
//!   "axes": {
//!     "scenarios": [
//!       {"name": "calm", "scenario": null},
//!       {"name": "storm", "path": "scenarios/storm.json"},
//!       {"name": "diurnal", "scenario": {"sources": [{"kind": "diurnal", "total_gpus": 8, "base": 1, "amp": 2}]}}
//!     ],
//!     "tuners": [
//!       {"name": "random", "tune": {"random": {}}},
//!       {"name": "asha", "tune": {"asha": {"eta": 3}}}
//!     ],
//!     "policies": [
//!       {"name": "strict", "borrow": false},
//!       {"name": "borrow", "borrow": true, "retry": {"max_attempts": 3}}
//!     ]
//!   }
//! }
//! ```
//!
//! `base_manifest` is a path (resolved against the spec file's
//! directory) or an inline manifest object.  Each cell applies one
//! entry per axis to the base: the scenario replaces
//! `manifest.scenario`, the tuner replaces every study's
//! `config.tune`, and the policy overrides `borrow` / `policy` /
//! `retry` / `master_period`.  The sweep `seed` is added to every
//! study's config seed, so one spec re-seeds the whole grid.
//!
//! The resolved manifest is re-serialized through
//! [`StudyManifest::to_json`] — the **canonical form** (explicit
//! quotas, fixed key order) — and the cell hash is FNV-1a 64 over
//! those bytes plus the drive parameters.  Equal hash ⇒ equal cell
//! output bytes, which is what makes `--resume` sound.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};
use chopt_core::util::json::{parse, Value as Json};
use chopt_engine::coordinator::{valid_study_name, StudyManifest};

/// One entry of the scenario axis: a name plus the scenario document
/// that replaces `manifest.scenario` (already loaded if it came from a
/// `path`).  `Json::Null` means "no scenario".
#[derive(Debug, Clone)]
pub struct ScenarioAxis {
    pub name: String,
    pub scenario: Json,
}

/// One entry of the tuner axis: the `tune` object written into every
/// study config.
#[derive(Debug, Clone)]
pub struct TunerAxis {
    pub name: String,
    pub tune: Json,
}

/// One entry of the policy axis: scheduler-level overrides, each
/// optional so an entry can flip a single knob.
#[derive(Debug, Clone, Default)]
pub struct PolicyAxis {
    pub name: String,
    pub borrow: Option<bool>,
    pub policy: Option<Json>,
    pub retry: Option<Json>,
    pub master_period: Option<f64>,
}

/// A parsed sweep spec: base manifest + axes + drive parameters.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// The raw base manifest document (inline, or loaded from
    /// `base_manifest` as a path).
    pub base: Json,
    /// Added to every study's config seed in every cell.
    pub seed: u64,
    pub scenarios: Vec<ScenarioAxis>,
    pub tuners: Vec<TunerAxis>,
    pub policies: Vec<PolicyAxis>,
    /// Virtual seconds per drive chunk (affects `time_to_target`
    /// granularity, never simulation results).
    pub chunk: f64,
    /// Virtual seconds between periodic cell snapshots.
    pub snapshot_every: f64,
    /// Optional objective threshold: the first chunk boundary at which
    /// any study's best crosses it becomes the cell's `time_to_target`.
    pub target_measure: Option<f64>,
}

/// One expanded grid cell: axis coordinates, the canonical resolved
/// manifest, and the content hash that names its output directory
/// entry.
#[derive(Debug, Clone)]
pub struct CellPlan {
    /// `<scenario>-<tuner>-<policy>` — path-safe by construction.
    pub id: String,
    pub scenario: String,
    pub tuner: String,
    pub policy: String,
    /// (scenario, tuner, policy) axis indices, grid order.
    pub index: (usize, usize, usize),
    /// Canonical resolved manifest (`StudyManifest::to_json` form).
    pub manifest_doc: Json,
    /// FNV-1a 64 over the canonical manifest bytes + drive parameters,
    /// as 16 hex digits.
    pub hash: String,
    pub seed: u64,
}

impl CellPlan {
    /// Rebuild the runnable manifest from the canonical document.
    pub fn manifest(&self) -> anyhow::Result<StudyManifest> {
        StudyManifest::from_json(&self.manifest_doc)
            .with_context(|| format!("cell '{}' manifest", self.id))
    }
}

/// FNV-1a 64 — the same dependency-free hash the response cache uses
/// for ETags; collisions across a sweep grid's handful of cells are
/// not a realistic concern.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Axis names become path components and URL segments, so they obey
/// the same charset rule as study names, plus: no `-` ambiguity is
/// enforced (ids are joined with `-`, but axis coordinates are carried
/// separately in `cell.json`, so a dash inside a name is allowed).
fn valid_axis_name(name: &str) -> bool {
    valid_study_name(name)
}

fn parse_seed(doc: &Json, key: &str) -> anyhow::Result<u64> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(0),
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .with_context(|| format!("'{key}' must be a u64 (got '{s}')")),
        Some(v) => v
            .as_i64()
            .and_then(|n| u64::try_from(n).ok())
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

impl SweepSpec {
    /// Load a spec file; `base_manifest` / scenario `path` entries
    /// resolve relative to the spec file's directory.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<SweepSpec> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep spec {}", path.display()))?;
        let doc = parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        SweepSpec::from_json(&doc, path.parent())
    }

    /// Parse a spec document; `base_dir` anchors relative paths.
    pub fn from_json(doc: &Json, base_dir: Option<&Path>) -> anyhow::Result<SweepSpec> {
        let resolve = |p: &str| -> PathBuf {
            match base_dir {
                Some(dir) if !Path::new(p).is_absolute() => dir.join(p),
                _ => PathBuf::from(p),
            }
        };
        let base = match doc.require("base_manifest")? {
            Json::Str(p) => {
                let path = resolve(p);
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading base manifest {}", path.display()))?;
                parse(&text).with_context(|| format!("parsing {}", path.display()))?
            }
            inline @ Json::Obj(_) => inline.clone(),
            _ => bail!("'base_manifest' must be a path string or an inline manifest object"),
        };
        let axes = doc.require("axes")?;

        let mut scenarios = Vec::new();
        for (i, entry) in axis_entries(axes, "scenarios")?.iter().enumerate() {
            let name = axis_name(entry, "scenarios", i)?;
            let scenario = match (entry.get("scenario"), entry.get("path")) {
                (Some(s), None) => s.clone(),
                (None, Some(Json::Str(p))) => {
                    let path = resolve(p);
                    let text = std::fs::read_to_string(&path)
                        .with_context(|| format!("reading scenario {}", path.display()))?;
                    parse(&text).with_context(|| format!("parsing {}", path.display()))?
                }
                (None, None) => bail!("scenario axis entry '{name}' needs 'scenario' or 'path'"),
                _ => bail!("scenario axis entry '{name}': give 'scenario' or 'path', not both"),
            };
            scenarios.push(ScenarioAxis { name, scenario });
        }

        let mut tuners = Vec::new();
        for (i, entry) in axis_entries(axes, "tuners")?.iter().enumerate() {
            let name = axis_name(entry, "tuners", i)?;
            let tune = entry
                .get("tune")
                .cloned()
                .with_context(|| format!("tuner axis entry '{name}' needs a 'tune' object"))?;
            if tune.as_obj().is_none() {
                bail!("tuner axis entry '{name}': 'tune' must be an object");
            }
            tuners.push(TunerAxis { name, tune });
        }

        let mut policies = Vec::new();
        for (i, entry) in axis_entries(axes, "policies")?.iter().enumerate() {
            let name = axis_name(entry, "policies", i)?;
            policies.push(PolicyAxis {
                name,
                borrow: entry.get("borrow").and_then(|v| v.as_bool()),
                policy: entry.get("policy").filter(|v| !v.is_null()).cloned(),
                retry: entry.get("retry").filter(|v| !v.is_null()).cloned(),
                master_period: entry.get("master_period").and_then(|v| v.as_f64()),
            });
        }

        for (axis, names) in [
            ("scenarios", scenarios.iter().map(|a| &a.name).collect::<Vec<_>>()),
            ("tuners", tuners.iter().map(|a| &a.name).collect()),
            ("policies", policies.iter().map(|a| &a.name).collect()),
        ] {
            let mut seen = std::collections::HashSet::new();
            for n in names {
                if !seen.insert(n.as_str()) {
                    bail!("duplicate name '{n}' in axis '{axis}'");
                }
            }
        }

        Ok(SweepSpec {
            base,
            seed: parse_seed(doc, "seed")?,
            scenarios,
            tuners,
            policies,
            chunk: doc.get("chunk").and_then(|v| v.as_f64()).unwrap_or(3600.0).max(1.0),
            snapshot_every: doc
                .get("snapshot_every")
                .and_then(|v| v.as_f64())
                .unwrap_or(14400.0),
            target_measure: doc.get("target_measure").and_then(|v| v.as_f64()),
        })
    }

    /// The drive parameters folded into every cell hash: a cell is
    /// only reusable if it was produced under the same chunking,
    /// snapshot cadence, and target threshold.
    fn drive_params(&self) -> String {
        let target = match self.target_measure {
            Some(t) => format!("{t}"),
            None => "none".into(),
        };
        format!(
            "seed={}|chunk={}|snapshot_every={}|target={}",
            self.seed, self.chunk, self.snapshot_every, target
        )
    }

    /// Expand the full cross product in grid order (scenario-major,
    /// policy-minor).  Every cell's manifest is resolved and validated
    /// here, so a bad axis combination fails before any cell runs.
    pub fn cells(&self) -> anyhow::Result<Vec<CellPlan>> {
        if self.scenarios.is_empty() || self.tuners.is_empty() || self.policies.is_empty() {
            bail!("every axis needs at least one entry");
        }
        let params = self.drive_params();
        let mut plans =
            Vec::with_capacity(self.scenarios.len() * self.tuners.len() * self.policies.len());
        for (si, sc) in self.scenarios.iter().enumerate() {
            for (ti, tu) in self.tuners.iter().enumerate() {
                for (pi, po) in self.policies.iter().enumerate() {
                    let id = format!("{}-{}-{}", sc.name, tu.name, po.name);
                    let manifest_doc = self
                        .resolve_cell(sc, tu, po)
                        .with_context(|| format!("resolving cell '{id}'"))?;
                    let hash = format!(
                        "{:016x}",
                        fnv1a64(
                            format!("{}\u{0}{}", manifest_doc.to_string_compact(), params)
                                .as_bytes()
                        )
                    );
                    plans.push(CellPlan {
                        id,
                        scenario: sc.name.clone(),
                        tuner: tu.name.clone(),
                        policy: po.name.clone(),
                        index: (si, ti, pi),
                        manifest_doc,
                        hash,
                        seed: self.seed,
                    });
                }
            }
        }
        Ok(plans)
    }

    /// Apply one axis combination to the base manifest and return the
    /// canonical (`to_json`) document.
    fn resolve_cell(
        &self,
        sc: &ScenarioAxis,
        tu: &TunerAxis,
        po: &PolicyAxis,
    ) -> anyhow::Result<Json> {
        let mut doc = self.base.clone();
        if doc.as_obj().is_none() {
            bail!("base manifest must be a JSON object");
        }
        doc.set("scenario", sc.scenario.clone());
        if let Some(b) = po.borrow {
            doc.set("borrow", Json::Bool(b));
        }
        if let Some(p) = &po.policy {
            doc.set("policy", p.clone());
        }
        if let Some(r) = &po.retry {
            doc.set("retry", r.clone());
        }
        if let Some(mp) = po.master_period {
            doc.set("master_period", Json::Num(mp));
        }
        // The tuner override edits raw study JSON (config.tune), then
        // the whole document goes through the manifest parser — so a
        // tune object a real config would reject is caught here.
        if let Some(Json::Arr(studies)) = doc.get("studies").cloned().map(|s| {
            let mut s = s;
            if let Json::Arr(items) = &mut s {
                for study in items.iter_mut() {
                    if let Some(mut cfg) = study.get("config").cloned() {
                        if cfg.as_obj().is_some() {
                            cfg.set("tune", tu.tune.clone());
                            study.set("config", cfg);
                        }
                    }
                }
            }
            s
        }) {
            doc.set("studies", Json::Arr(studies));
        }
        let mut manifest = StudyManifest::from_json(&doc)?;
        for s in &mut manifest.studies {
            s.config.seed = s.config.seed.wrapping_add(self.seed);
        }
        Ok(manifest.to_json())
    }
}

fn axis_entries<'a>(axes: &'a Json, key: &str) -> anyhow::Result<&'a [Json]> {
    axes.require(key)?
        .as_arr()
        .with_context(|| format!("'axes.{key}' must be an array"))
}

fn axis_name(entry: &Json, axis: &str, i: usize) -> anyhow::Result<String> {
    let name = entry
        .get("name")
        .and_then(|v| v.as_str())
        .with_context(|| format!("axis '{axis}' entry {i} needs a string 'name'"))?;
    if !valid_axis_name(name) {
        bail!("axis '{axis}' name '{name}' is invalid (allowed: [A-Za-z0-9._-], no leading dot)");
    }
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn study(name: &str, seed: u64) -> String {
        format!(
            r#"{{"name": "{name}", "quota": 2, "config": {{
              "h_params": {{"lr": {{"parameters": [0.005, 0.09],
                "distribution": "log_uniform", "type": "float",
                "p_range": [0.001, 0.2]}}}},
              "measure": "test/accuracy", "order": "descending", "step": 10,
              "population": 2, "tune": {{"random": {{}}}},
              "termination": {{"max_session_number": 4}},
              "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
              "seed": {seed}
            }}}}"#
        )
    }

    fn spec_doc() -> Json {
        let text = format!(
            r#"{{
              "base_manifest": {{"cluster_gpus": 4, "borrow": false,
                                 "studies": [{}, {}]}},
              "seed": "7",
              "axes": {{
                "scenarios": [{{"name": "calm", "scenario": null}}],
                "tuners": [{{"name": "random", "tune": {{"random": {{}}}}}},
                           {{"name": "asha", "tune": {{"asha": {{}}}}}}],
                "policies": [{{"name": "strict", "borrow": false}},
                             {{"name": "borrow", "borrow": true}}]
              }}
            }}"#,
            study("a", 1),
            study("b", 2)
        );
        parse(&text).unwrap()
    }

    #[test]
    fn cells_expand_in_grid_order_with_stable_hashes() {
        let spec = SweepSpec::from_json(&spec_doc(), None).unwrap();
        let cells = spec.cells().unwrap();
        let ids: Vec<&str> = cells.iter().map(|c| c.id.as_str()).collect();
        assert_eq!(
            ids,
            [
                "calm-random-strict",
                "calm-random-borrow",
                "calm-asha-strict",
                "calm-asha-borrow"
            ]
        );
        // Same spec, same hash bytes; distinct cells, distinct hashes.
        let again = SweepSpec::from_json(&spec_doc(), None).unwrap().cells().unwrap();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.hash, b.hash);
        }
        let mut hashes: Vec<&str> = cells.iter().map(|c| c.hash.as_str()).collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), 4);
    }

    #[test]
    fn overrides_land_in_the_resolved_manifest() {
        let spec = SweepSpec::from_json(&spec_doc(), None).unwrap();
        let cells = spec.cells().unwrap();
        let strict = cells.iter().find(|c| c.id == "calm-asha-strict").unwrap();
        let m = strict.manifest().unwrap();
        assert!(!m.borrow);
        assert_eq!(m.studies[0].config.tune.name(), "asha");
        // Sweep seed 7 added to the study seeds 1 and 2.
        assert_eq!(m.studies[0].config.seed, 8);
        assert_eq!(m.studies[1].config.seed, 9);
        let borrow = cells.iter().find(|c| c.id == "calm-random-borrow").unwrap();
        assert!(borrow.manifest().unwrap().borrow);
    }

    #[test]
    fn seed_changes_every_hash() {
        let spec = SweepSpec::from_json(&spec_doc(), None).unwrap();
        let mut reseeded = spec_doc();
        reseeded.set("seed", Json::Str("8".into()));
        let other = SweepSpec::from_json(&reseeded, None).unwrap();
        for (a, b) in spec.cells().unwrap().iter().zip(other.cells().unwrap().iter()) {
            assert_ne!(a.hash, b.hash, "cell {}", a.id);
        }
    }

    #[test]
    fn bad_axis_entries_fail_fast() {
        let mut doc = spec_doc();
        let axes = doc.get("axes").unwrap().clone();
        let mut bad = axes.clone();
        bad.set("tuners", parse(r#"[{"name": "x"}]"#).unwrap());
        doc.set("axes", bad);
        assert!(SweepSpec::from_json(&doc, None).is_err());

        let mut doc = spec_doc();
        let mut bad = axes;
        bad.set(
            "policies",
            parse(r#"[{"name": "p"}, {"name": "p"}]"#).unwrap(),
        );
        doc.set("axes", bad);
        assert!(SweepSpec::from_json(&doc, None).is_err());
    }
}
