//! Read-only `/api/v1` backend over a finished sweep directory.
//!
//! `chopt serve --sweep <dir>` loads `<dir>/sweep.json` once and
//! answers `GET /api/v1/sweep` (the whole artifact) and
//! `GET /api/v1/sweep/cells/<id>` (one embedded cell record) through
//! the unchanged control-plane server.  Like a stored run, the source
//! reports a **fixed generation** — the response cache pins every body,
//! so after first touch the read surface costs no re-serialization.
//! The generation itself is the sum of per-cell processed-event
//! counts: a meaningful progress gauge, and different sweeps produce
//! different ETags.

use std::path::Path;

use anyhow::{bail, Context};
use chopt_core::util::json::{parse, Value as Json};
use chopt_control::api::{ApiCommand, ApiError, ApiQuery, CommandSink, RunSource};

use crate::artifact::SWEEP_KIND;

/// A loaded sweep artifact behind the `RunSource` trait.
pub struct SweepSource {
    artifact: Json,
    generation: u64,
}

impl SweepSource {
    /// Load `<dir>/sweep.json` (or a direct path to the file).
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<SweepSource> {
        let path = path.as_ref();
        let file = if path.is_dir() {
            path.join("sweep.json")
        } else {
            path.to_path_buf()
        };
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("reading sweep artifact {}", file.display()))?;
        let artifact =
            parse(&text).with_context(|| format!("parsing {}", file.display()))?;
        SweepSource::from_artifact(artifact)
            .with_context(|| format!("loading {}", file.display()))
    }

    /// Wrap an already-parsed artifact document.
    pub fn from_artifact(artifact: Json) -> anyhow::Result<SweepSource> {
        let kind = artifact.get("kind").and_then(|v| v.as_str()).unwrap_or("");
        if kind != SWEEP_KIND {
            bail!("not a sweep artifact (kind '{kind}', expected '{SWEEP_KIND}')");
        }
        let generation = artifact
            .get("cells")
            .and_then(|v| v.as_arr())
            .map(|cells| {
                cells
                    .iter()
                    .filter_map(|c| c.path("metrics.events").and_then(|v| v.as_i64()))
                    .map(|n| n.max(0) as u64)
                    .sum()
            })
            .unwrap_or(0);
        Ok(SweepSource {
            artifact,
            generation,
        })
    }

    /// Cell ids in grid order (used by the CLI to print a summary).
    pub fn cell_ids(&self) -> Vec<&str> {
        self.artifact
            .get("cells")
            .and_then(|v| v.as_arr())
            .map(|cells| {
                cells
                    .iter()
                    .filter_map(|c| c.get("id").and_then(|v| v.as_str()))
                    .collect()
            })
            .unwrap_or_default()
    }

    fn cell(&self, id: &str) -> Option<&Json> {
        self.artifact
            .get("cells")
            .and_then(|v| v.as_arr())?
            .iter()
            .find(|c| c.get("id").and_then(|v| v.as_str()) == Some(id))
    }
}

impl RunSource for SweepSource {
    fn generation(&self) -> u64 {
        self.generation
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        match q {
            ApiQuery::Sweep => Ok(self.artifact.clone()),
            ApiQuery::SweepCell { cell } => self.cell(cell).cloned().ok_or_else(|| {
                ApiError::NotFound(format!("no cell '{cell}' in this sweep"))
            }),
            _ => Err(ApiError::NotFound(
                "sweep server: only /api/v1/sweep and /api/v1/sweep/cells/<id> are served \
                 (serve a cell directory with --store for run-level endpoints)"
                    .into(),
            )),
        }
    }

    /// The artifact never changes after load — pin every cache entry.
    fn fixed_generation(&self) -> bool {
        true
    }
}

impl CommandSink for SweepSource {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        Err(ApiError::BadRequest(format!(
            "sweep artifact is read-only — '{}' needs a live server (chopt serve --live)",
            c.name()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact() -> Json {
        Json::obj()
            .with("schema_version", Json::Num(1.0))
            .with("kind", Json::Str(SWEEP_KIND.into()))
            .with(
                "cells",
                Json::Arr(vec![
                    Json::obj()
                        .with("id", Json::Str("a-b-c".into()))
                        .with("metrics", Json::obj().with("events", Json::Num(10.0))),
                    Json::obj()
                        .with("id", Json::Str("a-b-d".into()))
                        .with("metrics", Json::obj().with("events", Json::Num(5.0))),
                ]),
            )
    }

    #[test]
    fn serves_artifact_and_cells_with_fixed_generation() {
        let src = SweepSource::from_artifact(artifact()).unwrap();
        assert_eq!(src.generation(), 15);
        assert!(src.fixed_generation());
        assert_eq!(src.cell_ids(), vec!["a-b-c", "a-b-d"]);
        assert!(src.query(&ApiQuery::Sweep).is_ok());
        let cell = src
            .query(&ApiQuery::SweepCell {
                cell: "a-b-d".into(),
            })
            .unwrap();
        assert_eq!(cell.get("id").and_then(|v| v.as_str()), Some("a-b-d"));
        assert!(matches!(
            src.query(&ApiQuery::SweepCell { cell: "nope".into() }),
            Err(ApiError::NotFound(_))
        ));
        assert!(matches!(
            src.query(&ApiQuery::Status),
            Err(ApiError::NotFound(_))
        ));
    }

    #[test]
    fn rejects_non_sweep_documents() {
        let doc = Json::obj().with("kind", Json::Str("multi_study".into()));
        assert!(SweepSource::from_artifact(doc).is_err());
    }
}
