//! Parse + semantic validation for manifests, scenarios, and sweep
//! specs — the `chopt validate` subcommand.
//!
//! Every diagnostic carries a `line:col` pointer into the file:
//! parse errors map the parser's byte offset, semantic errors point at
//! the first occurrence of the offending key or value (best-effort
//! text scan — good enough to land an editor cursor).  Unknown keys
//! are **warnings** (forward compatibility: engines ignore them
//! silently, which is exactly how typos ship), everything that would
//! make the run refuse to start or behave nonsensically is an
//! **error**.  The sweep runner calls this before expanding the grid,
//! so a bad spec fails in milliseconds instead of after burning cells.

use std::path::Path;

use chopt_core::util::json::{parse, JsonError, Value as Json};
use chopt_engine::coordinator::{valid_study_name, StudyManifest};

use crate::spec::SweepSpec;

/// Diagnostic severity: errors fail validation (non-zero exit),
/// warnings do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding, anchored to a 1-based `line:col` in the validated file.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub message: String,
    pub line: usize,
    pub col: usize,
}

/// All findings for one file.
#[derive(Debug, Clone)]
pub struct Report {
    pub path: String,
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    /// `path:line:col: severity: message` — one finding per line, the
    /// grep/compiler convention editors already know how to jump on.
    pub fn render(&self) -> String {
        self.diags
            .iter()
            .map(|d| {
                format!(
                    "{}:{}:{}: {}: {}",
                    self.path,
                    d.line,
                    d.col,
                    d.severity.label(),
                    d.message
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Map a byte offset to a 1-based (line, col).
fn line_col(text: &str, offset: usize) -> (usize, usize) {
    let offset = offset.min(text.len());
    let before = &text[..offset];
    let line = before.bytes().filter(|&b| b == b'\n').count() + 1;
    let col = before.rfind('\n').map(|p| offset - p).unwrap_or(offset + 1);
    (line, col)
}

/// Best-effort pointer at a JSON key or string value: the first
/// occurrence of the quoted token.  Falls back to 1:1.
fn locate(text: &str, token: &str) -> (usize, usize) {
    let needle = format!("\"{token}\"");
    match text.find(&needle) {
        Some(pos) => line_col(text, pos),
        None => (1, 1),
    }
}

struct Ctx<'a> {
    text: &'a str,
    diags: Vec<Diagnostic>,
}

impl<'a> Ctx<'a> {
    fn new(text: &'a str) -> Ctx<'a> {
        Ctx {
            text,
            diags: Vec::new(),
        }
    }

    fn push(&mut self, severity: Severity, at: (usize, usize), message: String) {
        self.diags.push(Diagnostic {
            severity,
            message,
            line: at.0,
            col: at.1,
        });
    }

    fn error_at_token(&mut self, token: &str, message: String) {
        let at = locate(self.text, token);
        self.push(Severity::Error, at, message);
    }

    fn warn_at_token(&mut self, token: &str, message: String) {
        let at = locate(self.text, token);
        self.push(Severity::Warning, at, message);
    }

    /// Warn on every key of `obj` not in `known`.
    fn check_keys(&mut self, obj: &Json, known: &[&str], what: &str) {
        if let Some(pairs) = obj.as_obj() {
            for (key, _) in pairs {
                if !known.contains(&key.as_str()) {
                    self.warn_at_token(
                        key,
                        format!(
                            "unknown {what} key '{key}' (ignored by the engine; known: {})",
                            known.join(", ")
                        ),
                    );
                }
            }
        }
    }
}

fn read_and_parse(path: &Path) -> Result<(String, Json), Report> {
    let display = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            return Err(Report {
                path: display,
                diags: vec![Diagnostic {
                    severity: Severity::Error,
                    message: format!("cannot read file: {e}"),
                    line: 1,
                    col: 1,
                }],
            })
        }
    };
    match parse(&text) {
        Ok(doc) => Ok((text, doc)),
        Err(err) => {
            let (line, col, msg) = match &err {
                JsonError::Parse { offset, msg } => {
                    let (l, c) = line_col(&text, *offset);
                    (l, c, msg.clone())
                }
                other => (1, 1, other.to_string()),
            };
            Err(Report {
                path: display,
                diags: vec![Diagnostic {
                    severity: Severity::Error,
                    message: format!("JSON parse error: {msg}"),
                    line,
                    col,
                }],
            })
        }
    }
}

const MANIFEST_KEYS: &[&str] = &[
    "cluster_gpus",
    "master_period",
    "horizon",
    "borrow",
    "policy",
    "trace",
    "scenario",
    "retry",
    "studies",
];
const STUDY_KEYS: &[&str] = &["name", "quota", "priority", "submit_at", "failures", "config"];
const RETRY_KEYS: &[&str] = &[
    "base_backoff",
    "factor",
    "max_backoff",
    "max_attempts",
    "reset_window",
];
const POLICY_KEYS: &[&str] = &["low_util", "max_bonus_factor", "min_gpus"];
const SCENARIO_KEYS: &[&str] = &["sources", "submissions"];
const SWEEP_KEYS: &[&str] = &[
    "base_manifest",
    "seed",
    "chunk",
    "snapshot_every",
    "target_measure",
    "axes",
];
const AXES_KEYS: &[&str] = &["scenarios", "tuners", "policies"];
const SCENARIO_AXIS_KEYS: &[&str] = &["name", "scenario", "path"];
const TUNER_AXIS_KEYS: &[&str] = &["name", "tune"];
const POLICY_AXIS_KEYS: &[&str] = &["name", "borrow", "policy", "retry", "master_period"];

/// Semantic checks on a multi-study manifest document (shared between
/// `--manifest` files and a sweep spec's inline base).
fn check_manifest_doc(ctx: &mut Ctx<'_>, doc: &Json) {
    ctx.check_keys(doc, MANIFEST_KEYS, "manifest");
    let cluster_gpus = doc.get("cluster_gpus").and_then(|v| v.as_usize());
    if cluster_gpus.is_none() {
        ctx.error_at_token(
            "cluster_gpus",
            "manifest needs a numeric 'cluster_gpus'".into(),
        );
    }
    if let Some(mp) = doc.get("master_period").and_then(|v| v.as_f64()) {
        if !(mp.is_finite() && mp > 0.0) {
            ctx.error_at_token("master_period", format!("'master_period' must be > 0 (got {mp})"));
        }
    }
    if let Some(h) = doc.get("horizon").and_then(|v| v.as_f64()) {
        if !(h.is_finite() && h > 0.0) {
            ctx.error_at_token("horizon", format!("'horizon' must be > 0 (got {h})"));
        }
    }
    if let Some(policy) = doc.get("policy").filter(|v| !v.is_null()) {
        ctx.check_keys(policy, POLICY_KEYS, "policy");
        if let Some(lu) = policy.get("low_util").and_then(|v| v.as_f64()) {
            if !(lu > 0.0 && lu <= 1.0) {
                ctx.error_at_token("low_util", format!("'low_util' must be in (0, 1] (got {lu})"));
            }
        }
        if let Some(mb) = policy.get("max_bonus_factor").and_then(|v| v.as_f64()) {
            if !(mb.is_finite() && mb >= 1.0) {
                ctx.error_at_token(
                    "max_bonus_factor",
                    format!("'max_bonus_factor' must be >= 1 (got {mb})"),
                );
            }
        }
        if policy.get("min_gpus").and_then(|v| v.as_usize()) == Some(0) {
            ctx.error_at_token("min_gpus", "'min_gpus' must be >= 1".into());
        }
    }
    if let Some(retry) = doc.get("retry").filter(|v| !v.is_null()) {
        check_retry_doc(ctx, retry);
    }
    if let Some(scenario) = doc.get("scenario").filter(|v| !v.is_null()) {
        check_scenario_doc(ctx, scenario);
    }

    let studies = doc.get("studies").and_then(|v| v.as_arr());
    let Some(studies) = studies else {
        ctx.error_at_token("studies", "manifest needs a 'studies' array".into());
        return;
    };
    if studies.is_empty() {
        ctx.error_at_token("studies", "'studies' must not be empty".into());
        return;
    }
    let mut seen = std::collections::HashSet::new();
    let mut explicit = 0usize;
    let mut unspecified = 0usize;
    for (i, study) in studies.iter().enumerate() {
        ctx.check_keys(study, STUDY_KEYS, "study");
        let name = study
            .get("name")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("study-{i}"));
        if !valid_study_name(&name) {
            ctx.error_at_token(
                &name,
                format!(
                    "study name '{name}' is invalid (allowed: [A-Za-z0-9._-], no leading dot)"
                ),
            );
        }
        if !seen.insert(name.clone()) {
            ctx.error_at_token(&name, format!("duplicate study name '{name}'"));
        }
        match study.get("quota").and_then(|v| v.as_usize()) {
            Some(0) | None => unspecified += 1,
            Some(q) => explicit += q,
        }
        if let Some(p) = study.get("priority").filter(|v| !v.is_null()) {
            match p.as_f64() {
                Some(p) if p.is_finite() && p > 0.0 => {}
                got => ctx.error_at_token(
                    "priority",
                    format!("study '{name}': 'priority' must be a finite number > 0 (got {got:?})"),
                ),
            }
        }
        if study.get("config").is_none() {
            ctx.error_at_token(&name, format!("study '{name}' is missing 'config'"));
        }
    }
    if let Some(total) = cluster_gpus {
        if explicit > total {
            ctx.error_at_token(
                "cluster_gpus",
                format!("study quotas sum to {explicit} but the cluster has only {total} GPUs"),
            );
        } else if unspecified > 0 && (total - explicit) / unspecified == 0 {
            ctx.error_at_token(
                "studies",
                format!(
                    "{unspecified} studies without quotas but only {} unreserved GPUs",
                    total - explicit
                ),
            );
        }
    }
}

fn check_retry_doc(ctx: &mut Ctx<'_>, retry: &Json) {
    ctx.check_keys(retry, RETRY_KEYS, "retry");
    let base = retry.get("base_backoff").and_then(|v| v.as_f64());
    if let Some(b) = base {
        if !(b.is_finite() && b > 0.0) {
            ctx.error_at_token("base_backoff", format!("'base_backoff' must be > 0 (got {b})"));
        }
    }
    if let Some(f) = retry.get("factor").and_then(|v| v.as_f64()) {
        if !(f.is_finite() && f >= 1.0) {
            ctx.error_at_token("factor", format!("retry 'factor' must be >= 1 (got {f})"));
        }
    }
    if let Some(m) = retry.get("max_backoff").and_then(|v| v.as_f64()) {
        let b = base.unwrap_or(120.0);
        if !(m.is_finite() && m >= b) {
            ctx.error_at_token(
                "max_backoff",
                format!("'max_backoff' ({m}) must be >= base_backoff ({b})"),
            );
        }
    }
    if retry.get("max_attempts").and_then(|v| v.as_usize()) == Some(0) {
        ctx.error_at_token("max_attempts", "'max_attempts' must be >= 1".into());
    }
}

/// Semantic checks on a scenario document (standalone file or the
/// manifest's `scenario` field).
fn check_scenario_doc(ctx: &mut Ctx<'_>, doc: &Json) {
    ctx.check_keys(doc, SCENARIO_KEYS, "scenario");
    let known: &[(&str, &[&str])] = &[
        ("diurnal", &["kind", "total_gpus", "base", "amp", "period", "jitter", "seed"]),
        ("flash_crowd", &["kind", "total_gpus", "spike", "first_at", "every", "duration", "seed"]),
        ("spot_reclaim", &["kind", "slots", "wave_size", "first_at", "every", "waves", "seed"]),
        ("degraded_node", &["kind", "gpus", "first_at", "every", "duration", "seed"]),
    ];
    if let Some(sources) = doc.get("sources").and_then(|v| v.as_arr()) {
        for (i, src) in sources.iter().enumerate() {
            match src.get("kind").and_then(|v| v.as_str()) {
                Some(kind) => match known.iter().find(|(k, _)| *k == kind) {
                    Some((_, keys)) => ctx.check_keys(src, keys, "scenario source"),
                    None => ctx.error_at_token(
                        kind,
                        format!(
                            "unknown scenario source kind '{kind}' (known: {})",
                            known.iter().map(|(k, _)| *k).collect::<Vec<_>>().join(", ")
                        ),
                    ),
                },
                None => ctx.error_at_token(
                    "sources",
                    format!("scenario source {i} is missing 'kind'"),
                ),
            }
        }
    } else {
        ctx.error_at_token("sources", "scenario needs a 'sources' array".into());
    }
    if let Some(subs) = doc.get("submissions").and_then(|v| v.as_arr()) {
        for (i, sub) in subs.iter().enumerate() {
            ctx.check_keys(sub, &["submit_at", "study"], "scenario submission");
            if sub.get("submit_at").and_then(|v| v.as_f64()).is_none() {
                ctx.error_at_token(
                    "submissions",
                    format!("scenario submission {i} needs a numeric 'submit_at'"),
                );
            }
            if sub.get("study").is_none() {
                ctx.error_at_token(
                    "submissions",
                    format!("scenario submission {i} needs a 'study' spec object"),
                );
            }
        }
    }
}

fn check_sweep_doc(ctx: &mut Ctx<'_>, doc: &Json) {
    ctx.check_keys(doc, SWEEP_KEYS, "sweep spec");
    if let Some(Json::Obj(_)) = doc.get("base_manifest") {
        check_manifest_doc(ctx, doc.get("base_manifest").unwrap());
    }
    if let Some(c) = doc.get("chunk").and_then(|v| v.as_f64()) {
        if !(c.is_finite() && c >= 1.0) {
            ctx.error_at_token("chunk", format!("'chunk' must be >= 1 virtual second (got {c})"));
        }
    }
    let Some(axes) = doc.get("axes") else {
        ctx.error_at_token("axes", "sweep spec needs an 'axes' object".into());
        return;
    };
    ctx.check_keys(axes, AXES_KEYS, "axes");
    let per_axis: &[(&str, &[&str])] = &[
        ("scenarios", SCENARIO_AXIS_KEYS),
        ("tuners", TUNER_AXIS_KEYS),
        ("policies", POLICY_AXIS_KEYS),
    ];
    for (axis, keys) in per_axis {
        match axes.get(axis).and_then(|v| v.as_arr()) {
            Some(entries) if !entries.is_empty() => {
                for entry in entries {
                    ctx.check_keys(entry, keys, &format!("{axis} axis entry"));
                    if let Some(retry) = entry.get("retry").filter(|v| !v.is_null()) {
                        if *axis == "policies" {
                            check_retry_doc(ctx, retry);
                        }
                    }
                    if let Some(sc) = entry.get("scenario").filter(|v| !v.is_null()) {
                        if *axis == "scenarios" {
                            check_scenario_doc(ctx, sc);
                        }
                    }
                }
            }
            _ => ctx.error_at_token(
                axis,
                format!("sweep axis '{axis}' needs a non-empty array"),
            ),
        }
    }
}

/// Validate a multi-study manifest file.  Structural checks first for
/// pointed diagnostics, then the real parser as a backstop so nothing
/// the engine would reject slips through with a clean report.
pub fn validate_manifest_file(path: impl AsRef<Path>) -> Report {
    let path = path.as_ref();
    let (text, doc) = match read_and_parse(path) {
        Ok(ok) => ok,
        Err(report) => return report,
    };
    let mut ctx = Ctx::new(&text);
    check_manifest_doc(&mut ctx, &doc);
    if !ctx.diags.iter().any(|d| d.severity == Severity::Error) {
        if let Err(e) = StudyManifest::from_json(&doc) {
            ctx.push(Severity::Error, (1, 1), format!("{e:#}"));
        }
    }
    Report {
        path: path.display().to_string(),
        diags: ctx.diags,
    }
}

/// Validate a standalone scenario file.
pub fn validate_scenario_file(path: impl AsRef<Path>) -> Report {
    let path = path.as_ref();
    let (text, doc) = match read_and_parse(path) {
        Ok(ok) => ok,
        Err(report) => return report,
    };
    let mut ctx = Ctx::new(&text);
    check_scenario_doc(&mut ctx, &doc);
    if !ctx.diags.iter().any(|d| d.severity == Severity::Error) {
        if let Err(e) = chopt_cluster::Scenario::from_json(&doc) {
            ctx.push(Severity::Error, (1, 1), format!("{e:#}"));
        }
    }
    Report {
        path: path.display().to_string(),
        diags: ctx.diags,
    }
}

/// Validate a sweep spec file, including full grid expansion (every
/// resolved cell manifest must parse) — exactly what `chopt sweep`
/// runs before touching the worker pool.
pub fn validate_sweep_file(path: impl AsRef<Path>) -> Report {
    let path = path.as_ref();
    let (text, doc) = match read_and_parse(path) {
        Ok(ok) => ok,
        Err(report) => return report,
    };
    let mut ctx = Ctx::new(&text);
    check_sweep_doc(&mut ctx, &doc);
    if !ctx.diags.iter().any(|d| d.severity == Severity::Error) {
        match SweepSpec::from_json(&doc, path.parent()) {
            Err(e) => ctx.push(Severity::Error, (1, 1), format!("{e:#}")),
            Ok(spec) => {
                if let Err(e) = spec.cells() {
                    ctx.push(Severity::Error, (1, 1), format!("{e:#}"));
                }
            }
        }
    }
    Report {
        path: path.display().to_string(),
        diags: ctx.diags,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("chopt-validate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        path
    }

    const GOOD_STUDY: &str = r#"{"name": "a", "quota": 2, "config": {
        "h_params": {"lr": {"parameters": [0.005, 0.09],
            "distribution": "log_uniform", "type": "float",
            "p_range": [0.001, 0.2]}},
        "measure": "test/accuracy", "order": "descending", "step": 10,
        "population": 2, "tune": {"random": {}},
        "termination": {"max_session_number": 4},
        "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
        "seed": 1}}"#;

    #[test]
    fn parse_errors_carry_line_and_col() {
        let path = tmp("broken.json", "{\n  \"cluster_gpus\": 8,\n  oops\n}");
        let report = validate_manifest_file(&path);
        assert!(report.has_errors());
        assert_eq!(report.diags[0].line, 3);
        assert!(report.render().contains("error"));
    }

    #[test]
    fn quota_overflow_and_unknown_keys() {
        let text = format!(
            r#"{{"cluster_gpus": 1, "tpyo": 1, "studies": [{GOOD_STUDY}]}}"#
        );
        let path = tmp("over.json", &text);
        let report = validate_manifest_file(&path);
        assert!(report.has_errors(), "{}", report.render());
        assert!(report.render().contains("quotas sum to 2"), "{}", report.render());
        assert!(report.render().contains("unknown manifest key 'tpyo'"));
    }

    #[test]
    fn good_manifest_passes() {
        let text = format!(r#"{{"cluster_gpus": 4, "studies": [{GOOD_STUDY}]}}"#);
        let path = tmp("good.json", &text);
        let report = validate_manifest_file(&path);
        assert!(!report.has_errors(), "{}", report.render());
    }

    #[test]
    fn retry_bounds_are_checked() {
        let text = format!(
            r#"{{"cluster_gpus": 4,
                 "retry": {{"base_backoff": 0, "max_attempts": 0}},
                 "studies": [{GOOD_STUDY}]}}"#
        );
        let path = tmp("retry.json", &text);
        let report = validate_manifest_file(&path);
        assert!(report.has_errors());
        let rendered = report.render();
        assert!(rendered.contains("base_backoff"), "{rendered}");
        assert!(rendered.contains("max_attempts"), "{rendered}");
    }

    #[test]
    fn scenario_unknown_kind_is_an_error() {
        let path = tmp(
            "scenario.json",
            r#"{"sources": [{"kind": "tsunami", "total_gpus": 8}]}"#,
        );
        let report = validate_scenario_file(&path);
        assert!(report.has_errors());
        assert!(report.render().contains("tsunami"));
    }

    #[test]
    fn sweep_spec_missing_axis_fails() {
        let text = format!(
            r#"{{"base_manifest": {{"cluster_gpus": 4, "studies": [{GOOD_STUDY}]}},
                 "axes": {{"scenarios": [{{"name": "calm", "scenario": null}}],
                           "tuners": []}}}}"#
        );
        let path = tmp("sweep.json", &text);
        let report = validate_sweep_file(&path);
        assert!(report.has_errors());
        let rendered = report.render();
        assert!(rendered.contains("tuners"), "{rendered}");
        assert!(rendered.contains("policies"), "{rendered}");
    }
}
