//! Parameter-analytic plots: scatter, histogram, duration bars, and the
//! Fig. 8 utilization timeline.

use chopt_core::config::Order;
use chopt_core::nsml::NsmlSession;
use chopt_core::util::stats::Histogram;

use crate::svg::{color, Svg};

const W: f64 = 460.0;
const H: f64 = 320.0;
const M: f64 = 45.0;

fn scale(v: f64, lo: f64, hi: f64, out_lo: f64, out_hi: f64) -> f64 {
    let t = if (hi - lo).abs() < 1e-300 {
        0.5
    } else {
        (v - lo) / (hi - lo)
    };
    out_lo + t.clamp(0.0, 1.0) * (out_hi - out_lo)
}

/// Scatter of hyperparameter vs measure (Fig. 7 right-top: 'prob' vs
/// 'test/accuracy').
pub fn scatter(sessions: &[NsmlSession], param: &str, order: Order) -> Svg {
    let pts: Vec<(f64, f64)> = sessions
        .iter()
        .filter_map(|s| {
            Some((s.hparams.f64(param)?, s.best_measure(order)?))
        })
        .collect();
    let mut svg = Svg::new(W, H);
    svg.text(M, 18.0, 12.0, &format!("{param} vs measure (n={})", pts.len()));
    svg.line(M, H - M, W - 10.0, H - M, "#333", 1.0);
    svg.line(M, H - M, M, 25.0, "#333", 1.0);
    if pts.is_empty() {
        return svg;
    }
    let (x_lo, x_hi) = pts
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &(x, _)| {
            (l.min(x), h.max(x))
        });
    let (y_lo, y_hi) = pts
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &(_, y)| {
            (l.min(y), h.max(y))
        });
    for &(x, y) in &pts {
        let px = scale(x, x_lo, x_hi, M, W - 10.0);
        let py = scale(y, y_lo, y_hi, H - M, 25.0);
        svg.circle(px, py, 3.0, color(3), 0.65);
    }
    svg.text(M, H - M + 24.0, 9.0, &format!("{x_lo:.4}"));
    svg.text(W - 60.0, H - M + 24.0, 9.0, &format!("{x_hi:.4}"));
    svg.text(2.0, H - M, 9.0, &format!("{y_lo:.1}"));
    svg.text(2.0, 32.0, 9.0, &format!("{y_hi:.1}"));
    svg
}

/// Histogram of one hyperparameter's sampled values.
pub fn histogram(sessions: &[NsmlSession], param: &str, bins: usize) -> Svg {
    let vals: Vec<f64> = sessions
        .iter()
        .filter_map(|s| s.hparams.f64(param))
        .collect();
    let h = Histogram::build(&vals, bins.max(1));
    let mut svg = Svg::new(W, H);
    svg.text(M, 18.0, 12.0, &format!("distribution of {param} (n={})", vals.len()));
    let max_count = h.counts.iter().copied().max().unwrap_or(1).max(1) as f64;
    let bw = (W - M - 10.0) / h.counts.len() as f64;
    for (i, &c) in h.counts.iter().enumerate() {
        let bh = (c as f64 / max_count) * (H - M - 40.0);
        svg.rect(M + i as f64 * bw, H - M - bh, bw - 2.0, bh, color(0));
    }
    svg.line(M, H - M, W - 10.0, H - M, "#333", 1.0);
    svg.text(M, H - M + 24.0, 9.0, &format!("{:.4}", h.lo));
    svg.text(W - 70.0, H - M + 24.0, 9.0, &format!("{:.4}", h.hi));
    svg
}

/// Learning-duration horizontal bars (Fig. 5 left / Fig. 7 right-middle):
/// x-axis is the last learning step (epochs) of each model — "this plot
/// can help users to find biased experiments".
pub fn duration_bars(sessions: &[NsmlSession]) -> Svg {
    let mut rows: Vec<(u64, usize)> = sessions.iter().map(|s| (s.id.0, s.epochs)).collect();
    rows.sort_by_key(|&(id, _)| id);
    let height = (rows.len() as f64 * 14.0 + 70.0).max(H);
    let mut svg = Svg::new(W, height);
    svg.text(M, 18.0, 12.0, &format!("learning duration ({} models)", rows.len()));
    let max_e = rows.iter().map(|&(_, e)| e).max().unwrap_or(1).max(1) as f64;
    for (i, &(id, e)) in rows.iter().enumerate() {
        let y = 32.0 + i as f64 * 14.0;
        let w = (e as f64 / max_e) * (W - M - 80.0);
        svg.rect(M, y, w.max(1.0), 10.0, color(1));
        svg.text(M + w + 4.0, y + 9.0, 8.0, &format!("#{id} ({e}ep)"));
    }
    svg
}

/// Fig. 8: GPU allocation over time — total-used (green), non-CHOPT
/// (yellow), plus zone boundary ticks.
pub fn utilization_timeline(
    total_series: &[(f64, f64)],
    external_series: &[(f64, f64)],
    total_gpus: usize,
    horizon: f64,
) -> Svg {
    let mut svg = Svg::new(720.0, 300.0);
    let m = 45.0;
    let (w, h) = (720.0, 300.0);
    svg.text(m, 18.0, 12.0, "GPU allocation: total used (green) vs non-CHOPT (yellow)");
    svg.line(m, h - m, w - 10.0, h - m, "#333", 1.0);
    svg.line(m, h - m, m, 25.0, "#333", 1.0);
    let to_xy = |t: f64, v: f64| {
        (
            scale(t, 0.0, horizon, m, w - 10.0),
            scale(v, 0.0, total_gpus as f64, h - m, 25.0),
        )
    };
    // Step-function polylines.
    let steps = |series: &[(f64, f64)]| -> Vec<(f64, f64)> {
        let mut pts = Vec::new();
        let mut last_v = 0.0;
        for &(t, v) in series {
            pts.push(to_xy(t, last_v));
            pts.push(to_xy(t, v));
            last_v = v;
        }
        pts.push(to_xy(horizon, last_v));
        pts
    };
    svg.polyline(&steps(total_series), "#2ca02c", 1.8, 0.9);
    svg.polyline(&steps(external_series), "#e6b400", 1.8, 0.9);
    // Zone boundaries at the Fig. 8 fractions.
    for (frac, label) in [(0.0, "A"), (0.15, "B"), (0.30, "C"), (0.55, "D"), (0.80, "E")] {
        let x = scale(frac * horizon, 0.0, horizon, m, w - 10.0);
        svg.line(x, 25.0, x, h - m, "#ccc", 0.8);
        svg.text(x + 3.0, 36.0, 11.0, label);
    }
    svg.text(2.0, h - m, 9.0, "0");
    svg.text(2.0, 32.0, 9.0, &format!("{total_gpus}"));
    svg
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::hparam::{Assignment, Value};
    use chopt_core::nsml::SessionId;

    fn sessions() -> Vec<NsmlSession> {
        (0..8)
            .map(|i| {
                let mut hp = Assignment::new();
                hp.set("prob", Value::Float(0.1 * i as f64));
                let mut s = NsmlSession::new(SessionId(i), hp, "m", 0.0);
                s.report((i as usize + 1) * 10, 60.0 + i as f64, 1.0);
                s
            })
            .collect()
    }

    #[test]
    fn scatter_renders_points() {
        let doc = scatter(&sessions(), "prob", Order::Descending).finish();
        assert_eq!(doc.matches("<circle").count(), 8);
        // Unknown param -> no points, no panic.
        let empty = scatter(&sessions(), "nope", Order::Descending).finish();
        assert_eq!(empty.matches("<circle").count(), 0);
    }

    #[test]
    fn histogram_renders_bars() {
        let doc = histogram(&sessions(), "prob", 4).finish();
        assert!(doc.matches("<rect").count() >= 4);
    }

    #[test]
    fn duration_bars_scale() {
        let doc = duration_bars(&sessions()).finish();
        assert!(doc.contains("80ep"), "longest session labelled");
    }

    #[test]
    fn timeline_draws_zones() {
        let total = vec![(0.0, 2.0), (100.0, 5.0)];
        let ext = vec![(0.0, 2.0), (150.0, 1.0)];
        let doc = utilization_timeline(&total, &ext, 8, 1000.0).finish();
        for z in ["A", "B", "C", "D", "E"] {
            assert!(doc.contains(&format!(">{z}</text>")), "zone {z}");
        }
        assert!(doc.matches("<polyline").count() == 2);
    }
}
