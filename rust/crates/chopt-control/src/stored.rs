//! Stored-run read models behind `chopt serve --store`.
//!
//! [`StoredRun`] rebuilds a finished (or interrupted) run directory into
//! the *same* incremental documents the live platform serves — the
//! snapshot is replayed in full fidelity, so every `/api/v1` body is
//! byte-identical to the run served live at the same event count.
//! [`ReplaySource`] is its scrub sibling: `?at_event=N` replays a
//! snapshot (single- or multi-study) to any recorded event count.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::api::{ApiCommand, ApiError, ApiQuery, CommandSink, RunSource};
use crate::platform::{MultiPlatform, Platform};
use chopt_core::trainer::{surrogate, Trainer};
use chopt_core::util::json::{self, Value as Json};

/// Scrub-to-event replay over a run snapshot: the [`RunSource`] behind
/// `?at_event=N`.
///
/// Wraps `SimEngine::restore` (via [`Platform::restore_doc_at`]) for
/// single-study snapshots and `StudyScheduler::restore_at` (via
/// [`MultiPlatform::restore_doc_at`]) for multi-study ones: a query at
/// event count `N` rebuilds the engine by replaying the first `N`
/// recorded events (re-issuing exactly the external inputs that had
/// been enqueued by then — for multi-study runs the per-study input
/// logs are merged by virtual enqueue time during the replay) and
/// renders the document from that state.  The last scrub position is
/// cached, so repeated queries at the same `N` — the common dashboard
/// case, several views of one moment — replay once.  Determinism of the
/// engine replay makes scrubbing stable: the same `N` always yields the
/// same bytes regardless of scrub order.
pub struct ReplaySource {
    snapshot: Json,
    /// The snapshot's recorded event count — scrub positions cap here.
    target: u64,
    make: ReplayFactory,
    /// (position, replayed platform) of the last scrub.
    cache: RefCell<Option<(u64, ScrubPlatform)>>,
}

/// Trainer factory for either snapshot shape.
enum ReplayFactory {
    Single(Arc<dyn Fn(u64) -> Box<dyn Trainer>>),
    Multi(Arc<dyn Fn(usize, u64) -> Box<dyn Trainer + Send>>),
}

/// Which platform shape a scrub replayed into.
enum ScrubPlatform {
    Single(Platform<'static>),
    Multi(MultiPlatform<'static>),
}

impl ScrubPlatform {
    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        match self {
            ScrubPlatform::Single(p) => p.query(q),
            ScrubPlatform::Multi(m) => m.query(q),
        }
    }
}

impl ReplaySource {
    /// Build a scrubber over a parsed single-study snapshot document.
    /// `make` must be the trainer factory the original run used.
    pub fn new(
        snapshot: Json,
        make: impl Fn(u64) -> Box<dyn Trainer> + 'static,
    ) -> anyhow::Result<ReplaySource> {
        ReplaySource::with_factory(snapshot, Arc::new(make))
    }

    /// Build a scrubber over a parsed multi-study snapshot document.
    /// `make` must be the per-study trainer factory the original run
    /// used.
    pub fn new_multi(
        snapshot: Json,
        make: impl Fn(usize, u64) -> Box<dyn Trainer + Send> + 'static,
    ) -> anyhow::Result<ReplaySource> {
        ReplaySource::with_multi_factory(snapshot, Arc::new(make))
    }

    fn with_factory(
        snapshot: Json,
        make: Arc<dyn Fn(u64) -> Box<dyn Trainer>>,
    ) -> anyhow::Result<ReplaySource> {
        if snapshot.get("kind").and_then(|v| v.as_str()) == Some("multi_study") {
            anyhow::bail!(
                "multi-study snapshot handed to the single-study scrubber — \
                 use ReplaySource::new_multi"
            );
        }
        ReplaySource::with_any_factory(snapshot, ReplayFactory::Single(make))
    }

    fn with_multi_factory(
        snapshot: Json,
        make: Arc<dyn Fn(usize, u64) -> Box<dyn Trainer + Send>>,
    ) -> anyhow::Result<ReplaySource> {
        if snapshot.get("kind").and_then(|v| v.as_str()) != Some("multi_study") {
            anyhow::bail!(
                "single-study snapshot handed to the multi-study scrubber — use ReplaySource::new"
            );
        }
        ReplaySource::with_any_factory(snapshot, ReplayFactory::Multi(make))
    }

    fn with_any_factory(snapshot: Json, make: ReplayFactory) -> anyhow::Result<ReplaySource> {
        let target = snapshot
            .get("events_processed")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("snapshot missing 'events_processed'"))?
            as u64;
        Ok(ReplaySource {
            snapshot,
            target,
            make,
            cache: RefCell::new(None),
        })
    }

    /// The snapshot's recorded event count (the maximum scrub position).
    pub fn target(&self) -> u64 {
        self.target
    }

    /// Ensure the cached platform sits at event count `min(at, target)`;
    /// returns the effective position.
    fn scrub_to(&self, at: u64) -> Result<u64, ApiError> {
        let at = at.min(self.target);
        if let Some((pos, _)) = self.cache.borrow().as_ref() {
            if *pos == at {
                return Ok(at);
            }
        }
        let platform = match &self.make {
            ReplayFactory::Single(f) => {
                let f = f.clone();
                Platform::restore_doc_at(&self.snapshot, move |id| (*f)(id), at)
                    .map(ScrubPlatform::Single)
            }
            ReplayFactory::Multi(f) => {
                let f = f.clone();
                MultiPlatform::restore_doc_at(&self.snapshot, move |study, id| (*f)(study, id), at)
                    .map(ScrubPlatform::Multi)
            }
        }
        .map_err(|e| ApiError::BadRequest(format!("replay to event {at} failed: {e:#}")))?;
        *self.cache.borrow_mut() = Some((at, platform));
        Ok(at)
    }
}

impl RunSource for ReplaySource {
    /// The current scrub position (the snapshot end before any scrub).
    fn generation(&self) -> u64 {
        self.cache
            .borrow()
            .as_ref()
            .map(|&(pos, _)| pos)
            .unwrap_or(self.target)
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        let at = self.generation();
        self.query_at(q, at).map(|(_, doc)| doc)
    }

    fn query_at(&self, q: &ApiQuery, at: u64) -> Result<(u64, Json), ApiError> {
        let at = self.scrub_to(at)?;
        let cache = self.cache.borrow();
        let (_, platform) = cache.as_ref().expect("scrub_to populated the cache");
        platform.query(q).map(|doc| (at, doc))
    }
}

/// Which platform shape a run directory restored into.
enum StoredPlatform {
    Single(Platform<'static>),
    Multi(MultiPlatform<'static>),
}

/// A run directory rebuilt into the live read model: the [`RunSource`]
/// behind `chopt serve --store`.
///
/// `open` reads `snapshot.json` (written by `chopt watch` / `chopt
/// multi` / their `serve --live` twins) and replays it **in full
/// fidelity** (`restore_doc_full`) through the same `Platform` /
/// `MultiPlatform` document pipeline the live server uses — which is
/// what makes every `/api/v1` body byte-identical between `serve
/// --store` and `serve --live` at the same event count.  The recorded
/// JSONL progress streams are exposed via [`StoredRun::event_lines`] so
/// `GET /api/v1/events` replays them over SSE.  Both single- and
/// multi-study runs carry a [`ReplaySource`] for `?at_event=`
/// scrubbing.
///
/// Stored runs are read-only: the [`CommandSink`] half rejects every
/// command with a 400 pointing at `serve --live`.
pub struct StoredRun {
    platform: StoredPlatform,
    replay: ReplaySource,
    /// Recorded JSONL streams (one for single-study, one per study for
    /// multi), in deterministic filename order.
    events_paths: Vec<PathBuf>,
}

impl StoredRun {
    /// Open a run directory (or a `snapshot.json` path directly) with
    /// the standard CLI trainer factories.  Runs produced with custom
    /// factories restore through [`StoredRun::open_with`].
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<StoredRun> {
        StoredRun::open_with(
            path,
            surrogate::default_factory,
            surrogate::default_multi_factory,
        )
    }

    /// [`StoredRun::open`] with explicit trainer factories (`make` for
    /// single-study snapshots, `make_multi` for multi-study ones —
    /// restore-by-replay requires the factories the original run used).
    pub fn open_with(
        path: impl AsRef<Path>,
        make: impl Fn(u64) -> Box<dyn Trainer> + 'static,
        make_multi: impl Fn(usize, u64) -> Box<dyn Trainer + Send> + 'static,
    ) -> anyhow::Result<StoredRun> {
        let path = path.as_ref();
        let (snap_path, dir) = if path.is_dir() {
            (path.join("snapshot.json"), path.to_path_buf())
        } else {
            (
                path.to_path_buf(),
                path.parent()
                    .filter(|p| !p.as_os_str().is_empty())
                    .unwrap_or(Path::new("."))
                    .to_path_buf(),
            )
        };
        if !snap_path.exists() {
            anyhow::bail!(
                "no snapshot.json under '{}' — `serve --store` reads a run directory written by \
                 `chopt watch` or `chopt multi` (the legacy static sessions.json store was \
                 retired; see README §Control-plane API)",
                path.display()
            );
        }
        let text = std::fs::read_to_string(&snap_path)?;
        let doc = json::parse(&text)?;
        if doc.get("runs").is_some() && doc.get("events_processed").is_none() {
            anyhow::bail!(
                "'{}' is a legacy sessions.json store, not a run snapshot — re-run through \
                 `chopt watch`/`chopt multi` to produce a servable run directory",
                snap_path.display()
            );
        }
        if doc.get("kind").and_then(|v| v.as_str()) == Some("multi_study") {
            let make_multi: Arc<dyn Fn(usize, u64) -> Box<dyn Trainer + Send>> =
                Arc::new(make_multi);
            let f = make_multi.clone();
            let platform = MultiPlatform::restore_doc_full(&doc, move |study, id| (*f)(study, id))?;
            let replay = ReplaySource::with_multi_factory(doc, make_multi)?;
            let mut events_paths: Vec<PathBuf> = std::fs::read_dir(&dir)
                .map(|entries| {
                    entries
                        .flatten()
                        .map(|e| e.path())
                        .filter(|p| {
                            p.file_name()
                                .and_then(|n| n.to_str())
                                .map(|n| n.starts_with("events-") && n.ends_with(".jsonl"))
                                .unwrap_or(false)
                        })
                        .collect()
                })
                .unwrap_or_default();
            events_paths.sort();
            Ok(StoredRun {
                platform: StoredPlatform::Multi(platform),
                replay,
                events_paths,
            })
        } else {
            let make: Arc<dyn Fn(u64) -> Box<dyn Trainer>> = Arc::new(make);
            let f = make.clone();
            let platform = Platform::restore_doc_full(&doc, move |id| (*f)(id))?;
            let replay = ReplaySource::with_factory(doc, make)?;
            let events = dir.join("events.jsonl");
            Ok(StoredRun {
                platform: StoredPlatform::Single(platform),
                replay,
                events_paths: if events.exists() { vec![events] } else { Vec::new() },
            })
        }
    }

    pub fn is_multi(&self) -> bool {
        matches!(self.platform, StoredPlatform::Multi(_))
    }

    /// The recorded progress stream, in emit order: single-study runs
    /// return `events.jsonl` verbatim; multi-study runs merge the
    /// per-study streams by virtual time (ties keep filename order, so
    /// the merge is deterministic).  Feed these into an SSE `EventFeed`
    /// to replay the run's progress over `GET /api/v1/events`.
    pub fn event_lines(&self) -> Vec<String> {
        let mut records: Vec<(f64, usize, String)> = Vec::new();
        for (file_idx, path) in self.events_paths.iter().enumerate() {
            let Ok(text) = std::fs::read_to_string(path) else {
                continue;
            };
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let t = json::parse(line)
                    .ok()
                    .and_then(|doc| doc.get("t").and_then(|v| v.as_f64()))
                    .unwrap_or(0.0);
                records.push((t, file_idx, line.to_string()));
            }
        }
        // Stable by (t, file): intra-file order is preserved, cross-file
        // ties resolve by filename order.
        records.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        records.into_iter().map(|(_, _, line)| line).collect()
    }
}

impl RunSource for StoredRun {
    fn generation(&self) -> u64 {
        match &self.platform {
            StoredPlatform::Single(p) => p.generation(),
            StoredPlatform::Multi(m) => m.generation(),
        }
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        match &self.platform {
            StoredPlatform::Single(p) => p.query(q),
            StoredPlatform::Multi(m) => m.query(q),
        }
    }

    fn query_at(&self, q: &ApiQuery, at: u64) -> Result<(u64, Json), ApiError> {
        self.replay.query_at(q, at)
    }

    /// A stored run's documents can never change: the HTTP response
    /// cache pins its entries, making the whole read surface
    /// cache-resident after first touch.  (`ReplaySource` must *not*
    /// claim this — scrubbing moves its generation.)
    fn fixed_generation(&self) -> bool {
        true
    }
}

impl CommandSink for StoredRun {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        Err(ApiError::BadRequest(format!(
            "stored run is read-only — '{}' needs a live server (chopt serve --live)",
            c.name()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stored_run_rejects_missing_and_legacy_stores() {
        let dir = std::env::temp_dir().join(format!("chopt-stored-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // No snapshot.json at all.
        let err = StoredRun::open(&dir).unwrap_err().to_string();
        assert!(err.contains("snapshot.json"), "{err}");
        // A legacy sessions.json store is named as such.
        let legacy = dir.join("legacy.json");
        std::fs::write(&legacy, r#"{"runs": []}"#).unwrap();
        let err = StoredRun::open(&legacy).unwrap_err().to_string();
        assert!(err.contains("legacy sessions.json"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_source_rejects_shape_mismatch() {
        let single = Json::obj().with("events_processed", Json::Num(3.0));
        let err = ReplaySource::new_multi(single, surrogate::default_multi_factory)
            .unwrap_err()
            .to_string();
        assert!(err.contains("single-study snapshot"), "{err}");
        let multi = Json::obj()
            .with("kind", Json::Str("multi_study".into()))
            .with("events_processed", Json::Num(3.0));
        let err = ReplaySource::new(multi, surrogate::default_factory)
            .unwrap_err()
            .to_string();
        assert!(err.contains("multi-study snapshot"), "{err}");
    }
}
