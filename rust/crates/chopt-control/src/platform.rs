//! The live CHOPT platform: a long-lived coordinator wrapped around a
//! [`SimEngine`] (paper §3, §3.5).
//!
//! Where the engine is a pure state machine, the platform owns the
//! *observable* side of a run:
//!
//! * a structured progress stream — every agent pool transition
//!   (launch/early-stop/preempt/revive/mutate/evict/finish) is appended to
//!   a JSONL [`EventLog`] as it happens,
//! * periodic JSON snapshots of the engine (`snapshot.json`) from which a
//!   run can be **restored** and continued ([`Platform::restore`]),
//! * live view documents (leaderboard, sessions, parallel coordinates,
//!   cluster utilization, status) that `chopt serve --live` republishes to
//!   the viz HTTP server as the engine advances, and
//! * online [`Platform::submit`] — users joining the shared cluster while
//!   other sessions are mid-flight.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use chopt_core::config::ChoptConfig;
use chopt_core::events::SimTime;
use chopt_core::nsml::{NsmlSession, SessionId};
use chopt_core::trainer::Trainer;
use chopt_core::util::json::Value as Json;
use chopt_engine::storage::{EventLog, SessionStore};

use crate::api::{ApiCommand, ApiError, ApiQuery, CommandSink, RunSource};
use crate::export;
use crate::sse::EventFeed;

use chopt_engine::coordinator::agent::{Agent, AgentEvent};
use chopt_engine::coordinator::driver::{SimOutcome, SimSetup};
use chopt_engine::coordinator::engine::{SimEngine, Step};
use chopt_engine::coordinator::scheduler::{MultiOutcome, StudyManifest, StudyScheduler, StudySpec};
use chopt_engine::coordinator::Health;

/// Cached leaderboard document keyed by the engine's processed-event
/// count: when nothing was processed between renders, the previous
/// document is returned instead of rebuilding it.
struct LbCache {
    processed: u64,
    k: usize,
    doc: Json,
}

/// Leaderboard rows of *completed* agents.  Their leaderboards are
/// frozen, so the rows are rendered once when an agent finishes and
/// reused by every later render — a render only rebuilds rows for the
/// (bounded) active agent set, not the whole run history.
#[derive(Default)]
struct DoneRows {
    upto: usize,
    k: usize,
    rows: Vec<Json>,
}

/// A live run: engine + event log + snapshot cadence + view builders.
pub struct Platform<'t> {
    engine: SimEngine<'t>,
    event_log: Option<EventLog>,
    /// SSE push: progress records are published here as well as (or
    /// instead of) the JSONL log, so `GET /api/v1/events` streams them.
    progress_feed: Option<Arc<EventFeed>>,
    /// Per-agent count of [`AgentEvent`]s already drained to the log.
    cursors: HashMap<u64, usize>,
    snapshot_path: Option<PathBuf>,
    /// Virtual seconds between automatic snapshots.
    snapshot_every: SimTime,
    last_snapshot_t: SimTime,
    /// Done agents drained to completion — their event vectors can never
    /// grow again, so drains skip them (keeps the per-event drain in
    /// `drive_until` bounded by the active agent count, not run history).
    done_drained: usize,
    /// Render caches (interior-mutable so the doc methods stay `&self`
    /// for the publish loops).
    lb_cache: RefCell<Option<LbCache>>,
    done_rows: RefCell<DoneRows>,
    /// HTTP read-side generation gauge (see
    /// [`Platform::set_generation_gauge`]).
    generation_gauge: Option<Arc<AtomicU64>>,
    /// Progress events emitted over the platform's lifetime.
    pub progress_events: u64,
}

impl<'t> Platform<'t> {
    pub fn new(
        setup: SimSetup,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> Platform<'t> {
        Platform::from_engine(SimEngine::new(setup, make_trainer))
    }

    pub fn from_engine(engine: SimEngine<'t>) -> Platform<'t> {
        Platform {
            engine,
            event_log: None,
            progress_feed: None,
            cursors: HashMap::new(),
            snapshot_path: None,
            snapshot_every: 3600.0,
            last_snapshot_t: 0.0,
            done_drained: 0,
            lb_cache: RefCell::new(None),
            done_rows: RefCell::new(DoneRows::default()),
            generation_gauge: None,
            progress_events: 0,
        }
    }

    /// Publish the engine's processed-event count into `gauge` after
    /// every advance.  The HTTP layer's response cache keys live entries
    /// on this gauge (`ApiInbox::generation_gauge`); publishing from
    /// inside the advance — not just when the engine loop next serves
    /// the inbox — means a GET racing an advance can never be answered
    /// with a pre-advance cached body.
    pub fn set_generation_gauge(&mut self, gauge: Arc<AtomicU64>) {
        gauge.store(self.engine.events_processed(), Ordering::Release);
        self.generation_gauge = Some(gauge);
    }

    /// Append structured progress events to a JSONL log at `path`.
    pub fn with_event_log(mut self, path: impl AsRef<Path>) -> std::io::Result<Platform<'t>> {
        self.event_log = Some(EventLog::open(path)?);
        Ok(self)
    }

    /// Publish structured progress events into an SSE feed as well —
    /// the push stream behind `GET /api/v1/events`.  Like the JSONL log,
    /// attaching a feed switches the drive loop to per-event drains so
    /// each record carries the virtual time its transition happened.
    pub fn with_progress_feed(mut self, feed: Arc<EventFeed>) -> Platform<'t> {
        self.progress_feed = Some(feed);
        self
    }

    /// Write an engine snapshot to `path` every `every` virtual seconds
    /// (and once more at completion).
    pub fn with_snapshots(mut self, path: impl AsRef<Path>, every: SimTime) -> Platform<'t> {
        self.snapshot_path = Some(path.as_ref().to_path_buf());
        self.snapshot_every = every.max(1.0);
        self
    }

    pub fn engine(&self) -> &SimEngine<'t> {
        &self.engine
    }

    pub fn is_done(&self) -> bool {
        self.engine.is_done()
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    /// Submit a new CHOPT session to the live run (clamped to now).
    /// Returns `None` if the engine's horizon has already been reached.
    pub fn submit(&mut self, config: ChoptConfig, at: SimTime) -> Option<SimTime> {
        let at = self.engine.submit(config, at)?;
        self.log_json(
            Json::obj()
                .with("t", Json::Num(self.engine.now()))
                .with("ev", Json::Str("submitted".into()))
                .with("at", Json::Num(at)),
        );
        Some(at)
    }

    /// Advance the engine by `dt` virtual seconds, then drain progress
    /// events and maybe snapshot.  Returns events processed.  If the
    /// window is an idle gap (no event within `dt`), one event past the
    /// gap is processed so callers looping on `advance` always progress;
    /// a return of 0 therefore means the run is over.
    pub fn advance(&mut self, dt: SimTime) -> u64 {
        let mut n = self.drive_until(self.engine.now() + dt);
        if n == 0
            && !self.engine.is_done()
            && matches!(self.engine.step(), Step::Advanced(_))
        {
            n += 1;
            self.drain_progress();
        }
        self.after_advance();
        n
    }

    /// Advance the engine to virtual time `t` (strict bound — see
    /// [`SimEngine::run_until`]).
    pub fn run_until(&mut self, t: SimTime) -> u64 {
        let n = self.drive_until(t);
        self.after_advance();
        n
    }

    /// Engine `run_until`, but when an event log is attached the progress
    /// stream is drained after *every* event so each JSONL record carries
    /// the virtual time the pool transition actually happened (not the
    /// advance-chunk boundary).
    fn drive_until(&mut self, t: SimTime) -> u64 {
        if self.event_log.is_none() && self.progress_feed.is_none() {
            return self.engine.run_until(t);
        }
        let mut n = 0;
        while !self.engine.is_done() {
            match self.engine.next_event_time() {
                Some(next) if next <= t => {
                    if !matches!(self.engine.step(), Step::Advanced(_)) {
                        break;
                    }
                    n += 1;
                    self.drain_progress();
                }
                _ => break,
            }
        }
        n
    }

    /// Drive the run to completion in `chunk`-sized virtual-time slices so
    /// progress/snapshot cadence is honored throughout.
    pub fn run_to_completion(&mut self, chunk: SimTime) -> u64 {
        let chunk = chunk.max(1.0);
        let mut n = 0;
        loop {
            let stepped = self.advance(chunk);
            n += stepped;
            if self.engine.is_done() || stepped == 0 {
                break;
            }
        }
        if self.snapshot_path.is_some() {
            let _ = self.snapshot_now();
        }
        n
    }

    /// Consume the platform into the batch outcome.  The engine's final
    /// shutdown can itself emit transitions (`Terminated("horizon")` on
    /// still-active agents), so those are drained from the outcome into
    /// the event log before it is handed back.
    pub fn into_outcome(mut self) -> SimOutcome {
        self.after_advance();
        let outcome = self.engine.into_outcome();
        let now = outcome.end_time;
        for agent in &outcome.agents {
            let seen = self.cursors.get(&agent.id).copied().unwrap_or(0);
            for ev in &agent.events[seen..] {
                self.progress_events += 1;
                let doc = agent_event_json(agent.id, ev, now);
                if let Some(feed) = &self.progress_feed {
                    feed.publish_json(&doc);
                }
                if let Some(log) = &mut self.event_log {
                    let _ = log.append(&doc);
                }
            }
        }
        if let Some(log) = &mut self.event_log {
            let _ = log.flush();
        }
        outcome
    }

    // -- progress stream ---------------------------------------------------

    fn after_advance(&mut self) {
        self.drain_progress();
        if let Some(log) = &mut self.event_log {
            let _ = log.flush();
        }
        if let Some(gauge) = &self.generation_gauge {
            gauge.store(self.engine.events_processed(), Ordering::Release);
        }
        self.maybe_snapshot();
    }

    /// Append agent events that occurred since the last drain to the
    /// event log (one JSON object per pool transition).  When called once
    /// per engine step (see [`Platform::drive_until`]) `engine.now()` is
    /// exactly the virtual time the transitions happened.
    ///
    /// Only agents the engine marked *dirty* since the last drain are
    /// visited (plus newly-completed ones, for their final events), so a
    /// drain after one interval event touches one agent — not every slot.
    fn drain_progress(&mut self) {
        let now = self.engine.now();
        let mut fresh: Vec<Json> = Vec::new();
        // Newly-completed agents get one final drain; long-done ones are
        // skipped (their event vectors are immutable).
        let done_len = self.engine.done_agents().len();
        for agent in &self.engine.done_agents()[self.done_drained.min(done_len)..] {
            catch_up_cursor(&mut self.cursors, agent.id, agent, now, |doc| fresh.push(doc));
        }
        self.done_drained = done_len;
        for slot in self.engine.take_dirty_slots() {
            let Some(agent) = self.engine.agent_at(slot) else {
                continue; // the touched agent finished (drained above)
            };
            catch_up_cursor(&mut self.cursors, agent.id, agent, now, |doc| fresh.push(doc));
        }
        self.progress_events += fresh.len() as u64;
        for doc in fresh {
            self.log_json(doc);
        }
    }

    fn log_json(&mut self, doc: Json) {
        if let Some(feed) = &self.progress_feed {
            feed.publish_json(&doc);
        }
        if let Some(log) = &mut self.event_log {
            let _ = log.append(&doc);
        }
    }

    fn maybe_snapshot(&mut self) {
        if self.snapshot_path.is_none() {
            return;
        }
        let now = self.engine.now();
        if now - self.last_snapshot_t >= self.snapshot_every {
            let _ = self.snapshot_now();
        }
    }

    /// Write (and return) a snapshot right now.
    pub fn snapshot_now(&mut self) -> std::io::Result<Json> {
        let doc = self.engine.snapshot_json();
        if let Some(path) = &self.snapshot_path {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, doc.to_string_pretty())?;
        }
        self.last_snapshot_t = self.engine.now();
        Ok(doc)
    }

    /// Rebuild a platform from a snapshot file written by
    /// [`Platform::snapshot_now`].  `make_trainer` must be the factory the
    /// original run used (state is reproduced by deterministic replay).
    pub fn restore(
        path: impl AsRef<Path>,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> anyhow::Result<Platform<'t>> {
        let text = std::fs::read_to_string(path)?;
        let doc = chopt_core::util::json::parse(&text)?;
        Platform::restore_doc(&doc, make_trainer)
    }

    /// [`Platform::restore`] from an already-parsed snapshot document
    /// (quiet replay — a continued run's utilization chart starts at the
    /// snapshot point).
    pub fn restore_doc(
        doc: &Json,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> anyhow::Result<Platform<'t>> {
        Ok(Platform::from_restored_engine(SimEngine::restore(
            doc,
            make_trainer,
        )?))
    }

    /// Full-fidelity restore for read models (`stored::StoredRun`): the
    /// replay keeps series retention on, so every rendered document —
    /// including the cluster series — is byte-identical to the live
    /// run's at the same event count.
    pub fn restore_doc_full(
        doc: &Json,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
    ) -> anyhow::Result<Platform<'t>> {
        Ok(Platform::from_restored_engine(SimEngine::restore_full(
            doc,
            make_trainer,
        )?))
    }

    /// Scrub restore: the platform view of the run after only `upto`
    /// recorded events (`stored::ReplaySource`, `?at_event=`).
    pub fn restore_doc_at(
        doc: &Json,
        make_trainer: impl FnMut(u64) -> Box<dyn Trainer> + 't,
        upto: u64,
    ) -> anyhow::Result<Platform<'t>> {
        Ok(Platform::from_restored_engine(SimEngine::restore_at(
            doc,
            make_trainer,
            upto,
        )?))
    }

    /// Wrap a replayed engine: cursors start at the replayed state so a
    /// reattached log/feed only receives new transitions, and
    /// `progress_events` is reconciled to the count a live platform that
    /// drained every event would report (one per agent event) — the
    /// status document stays byte-compatible between live and restored.
    fn from_restored_engine(engine: SimEngine<'t>) -> Platform<'t> {
        let mut platform = Platform::from_engine(engine);
        for agent in platform.engine.all_agents() {
            platform.cursors.insert(agent.id, agent.events.len());
        }
        platform.progress_events = platform
            .engine
            .all_agents()
            .map(|a| a.events.len() as u64)
            .sum();
        platform.done_drained = platform.engine.done_agents().len();
        // Replay marked every touched slot dirty; the cursors above
        // already account for those events, so drop the marks.
        platform.engine.take_dirty_slots();
        platform.last_snapshot_t = platform.engine.now();
        platform
    }

    // -- live views --------------------------------------------------------

    /// All NSML sessions across all agents (done agents first), by
    /// reference — the publish-loop variant.  Rendering 10k+ sessions per
    /// refresh must not deep-clone them first.
    pub fn sessions_ref(&self) -> Vec<&NsmlSession> {
        let mut out = Vec::new();
        for agent in self.engine.all_agents() {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            out.extend(ss);
        }
        out
    }

    /// Owned-clone variant of [`Platform::sessions_ref`], kept for final
    /// exports that outlive the platform.
    pub fn sessions(&self) -> Vec<NsmlSession> {
        self.sessions_ref().into_iter().cloned().collect()
    }

    /// Live leaderboard rows (top `k` across every agent's sessions).
    ///
    /// Incremental: rows for completed agents are rendered once and
    /// cached (their leaderboards are frozen), and the whole document is
    /// cached against the engine's processed-event count — a publish loop
    /// polling an idle engine gets the cached document back instead of a
    /// rebuild over every agent in the run's history.
    pub fn leaderboard_doc(&self, k: usize) -> Json {
        let processed = self.engine.events_processed();
        if let Some(c) = self.lb_cache.borrow().as_ref() {
            if c.processed == processed && c.k == k {
                return c.doc.clone();
            }
        }
        let mut rows = self.collect_leaderboard_rows(k);
        // Cross-agent merge: best first under the first agent's order
        // (platform runs share a measure in practice).  NaN-safe.
        let descending = self.order() == chopt_core::config::Order::Descending;
        rows.sort_by(|a, b| {
            let ma = a.get("best").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let mb = b.get("best").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            // NaN rows sink to the bottom regardless of order direction.
            match (ma.is_nan(), mb.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (false, false) if descending => mb.total_cmp(&ma),
                (false, false) => ma.total_cmp(&mb),
            }
        });
        rows.truncate(k);
        let doc = Json::obj()
            .with("t", Json::Num(self.engine.now()))
            .with("rows", Json::Arr(rows));
        *self.lb_cache.borrow_mut() = Some(LbCache {
            processed,
            k,
            doc: doc.clone(),
        });
        doc
    }

    /// Candidate rows for the merged leaderboard: cached frozen rows for
    /// done agents plus freshly-rendered rows for active ones.
    fn collect_leaderboard_rows(&self, k: usize) -> Vec<Json> {
        let done = self.engine.done_agents();
        let mut cache = self.done_rows.borrow_mut();
        if cache.k != k {
            cache.rows.clear();
            cache.upto = 0;
            cache.k = k;
        }
        let upto = cache.upto.min(done.len());
        for agent in &done[upto..] {
            agent_leaderboard_rows(agent, k, &mut cache.rows);
        }
        cache.upto = done.len();
        let mut rows = cache.rows.clone();
        for agent in self.engine.active_agents() {
            agent_leaderboard_rows(agent, k, &mut rows);
        }
        rows
    }

    /// Sessions document in the `SessionStore` format `chopt serve` uses
    /// (rendered from references — no session clones).
    pub fn sessions_doc(&self) -> Json {
        let runs: Vec<(String, Vec<&NsmlSession>)> = self
            .engine
            .all_agents()
            .map(|agent| {
                let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
                ss.sort_by_key(|s| s.id);
                (format!("chopt-{}", agent.id), ss)
            })
            .collect();
        SessionStore::doc_from_refs(&runs)
    }

    /// The run's measure order (first agent's; platform runs share one).
    pub fn order(&self) -> chopt_core::config::Order {
        self.engine
            .all_agents()
            .next()
            .map(|a| a.cfg.order)
            .unwrap_or(chopt_core::config::Order::Descending)
    }

    /// Parallel-coordinates document over all sessions (axes from `space`).
    pub fn parallel_doc(&self, space: &chopt_core::hparam::Space) -> Json {
        self.parallel_doc_from(space, &self.sessions_ref())
    }

    /// Same, over a caller-held session list — lets a publish loop collect
    /// [`Platform::sessions_ref`] once and render every document from the
    /// same borrowed set.
    pub fn parallel_doc_from(
        &self,
        space: &chopt_core::hparam::Space,
        sessions: &[&NsmlSession],
    ) -> Json {
        export::parallel_coords_doc_refs(space, sessions, self.order(), "live")
    }

    /// Cluster utilization view (live Fig. 8).
    pub fn cluster_doc(&self) -> Json {
        export::cluster_doc(self.engine.cluster(), self.engine.now())
    }

    /// Paginated session page (the v1 `/api/v1/sessions` document):
    /// `total` sessions overall, rows `[offset, offset+limit)` in
    /// done-agents-first order, each labelled with its CHOPT agent id.
    pub fn sessions_page_doc(&self, limit: usize, offset: usize) -> Json {
        let mut all: Vec<(u64, &NsmlSession)> = Vec::new();
        for agent in self.engine.all_agents() {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            all.extend(ss.into_iter().map(|s| (agent.id, s)));
        }
        sessions_page(all, limit, offset)
    }

    /// Paginated per-session curves page (the v1 `/api/v1/curves`
    /// document): `total` sessions overall, curve rows for
    /// `[offset, offset+limit)` in the same done-agents-first order the
    /// sessions page uses.
    pub fn curves_page_doc(&self, limit: usize, offset: usize) -> Json {
        let all = self.sessions_ref();
        curves_page(&all, limit, offset)
    }

    /// One-object run status (the `/api/v1/status` heartbeat).
    pub fn status_doc(&self) -> Json {
        let engine = &self.engine;
        let (live, stop, dead) = engine.active_agents().fold((0, 0, 0), |acc, a| {
            (
                acc.0 + a.pools.live_count(),
                acc.1 + a.pools.stop_count(),
                acc.2 + a.pools.dead_count(),
            )
        });
        Json::obj()
            .with("t", Json::Num(engine.now()))
            .with("events_processed", Json::Num(engine.events_processed() as f64))
            .with("done", Json::Bool(engine.is_done()))
            .with("queue_len", Json::Num(engine.queue_len() as f64))
            .with("active_agents", Json::Num(engine.active_agents().count() as f64))
            .with("done_agents", Json::Num(engine.done_agents().len() as f64))
            .with("pool_live", Json::Num(live as f64))
            .with("pool_stop", Json::Num(stop as f64))
            .with("pool_dead", Json::Num(dead as f64))
            .with(
                "best",
                engine
                    .best()
                    .map(|(_, _, m)| Json::Num(m))
                    .unwrap_or(Json::Null),
            )
            .with(
                "utilization",
                Json::Num(engine.cluster().utilization()),
            )
            .with("election_term", Json::Num(engine.election().term() as f64))
            .with("injected_failures", {
                let (applied, skipped) = engine.fail_stats();
                Json::obj()
                    .with("applied", Json::Num(applied as f64))
                    .with("skipped", Json::Num(skipped as f64))
            })
            .with("progress_events", Json::Num(self.progress_events as f64))
    }
}

/// The live layer over a [`StudyScheduler`]: the multi-tenant analog of
/// [`Platform`].
///
/// * **per-study JSONL streams** — each study gets its own
///   `events-<name>.jsonl` (created lazily, so online-submitted studies
///   stream too); every record carries a `"study"` label on top of the
///   [`agent_event_json`] fields,
/// * **merged fair-share document** — [`MultiPlatform::fair_share_doc`]
///   reports cluster utilization plus per-study quota / target / held /
///   borrowed accounting (the multi-tenant Fig. 8 view),
/// * periodic snapshots + [`MultiPlatform::restore`], same replay
///   contract as the single-study platform.
pub struct MultiPlatform<'t> {
    sched: StudyScheduler<'t>,
    /// Directory for per-study JSONL streams (None = no logging).
    log_dir: Option<PathBuf>,
    logs: HashMap<usize, EventLog>,
    /// SSE push: the merged progress stream (every record carries its
    /// `"study"` label) behind `GET /api/v1/events`.
    progress_feed: Option<Arc<EventFeed>>,
    /// Per-study count of agent events already drained.
    cursors: HashMap<usize, usize>,
    snapshot_path: Option<PathBuf>,
    snapshot_every: SimTime,
    last_snapshot_t: SimTime,
    /// Per-study leaderboard documents keyed on the scheduler's
    /// processed-event count (the same RefCell pattern as the merged
    /// leaderboard cache): a dashboard polling N tenants between events
    /// re-renders nothing.
    study_lb_cache: RefCell<HashMap<String, LbCache>>,
    /// HTTP read-side generation gauge (see
    /// [`MultiPlatform::set_generation_gauge`]).
    generation_gauge: Option<Arc<AtomicU64>>,
    /// Progress events emitted over the platform's lifetime.
    pub progress_events: u64,
}

impl<'t> MultiPlatform<'t> {
    pub fn new(
        manifest: StudyManifest,
        make_trainer: impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 't,
    ) -> MultiPlatform<'t> {
        MultiPlatform::from_scheduler(StudyScheduler::new(manifest, make_trainer))
    }

    pub fn from_scheduler(sched: StudyScheduler<'t>) -> MultiPlatform<'t> {
        MultiPlatform {
            sched,
            log_dir: None,
            logs: HashMap::new(),
            progress_feed: None,
            cursors: HashMap::new(),
            snapshot_path: None,
            snapshot_every: 3600.0,
            last_snapshot_t: 0.0,
            study_lb_cache: RefCell::new(HashMap::new()),
            generation_gauge: None,
            progress_events: 0,
        }
    }

    /// Publish the scheduler's processed-event count into `gauge` after
    /// every advance — same contract as
    /// [`Platform::set_generation_gauge`].
    pub fn set_generation_gauge(&mut self, gauge: Arc<AtomicU64>) {
        gauge.store(self.sched.events_processed(), Ordering::Release);
        self.generation_gauge = Some(gauge);
    }

    /// Stream per-study progress into `dir/events-<study>.jsonl`.
    pub fn with_event_logs(mut self, dir: impl AsRef<Path>) -> std::io::Result<MultiPlatform<'t>> {
        std::fs::create_dir_all(dir.as_ref())?;
        self.log_dir = Some(dir.as_ref().to_path_buf());
        Ok(self)
    }

    /// Publish the merged progress stream into an SSE feed (the push
    /// stream behind `GET /api/v1/events`); switches the drive loop to
    /// per-event drains like the JSONL logs do.
    pub fn with_progress_feed(mut self, feed: Arc<EventFeed>) -> MultiPlatform<'t> {
        self.progress_feed = Some(feed);
        self
    }

    /// Write a scheduler snapshot to `path` every `every` virtual seconds
    /// (and once more at completion).
    pub fn with_snapshots(mut self, path: impl AsRef<Path>, every: SimTime) -> MultiPlatform<'t> {
        self.snapshot_path = Some(path.as_ref().to_path_buf());
        self.snapshot_every = every.max(1.0);
        self
    }

    pub fn scheduler(&self) -> &StudyScheduler<'t> {
        &self.sched
    }

    /// Step independent studies on up to `n` worker threads between
    /// fair-share reconciliations (the `--step-threads` flag).  Purely a
    /// wall-clock knob — see [`StudyScheduler::set_step_threads`].
    pub fn set_step_threads(&mut self, n: usize) {
        self.sched.set_step_threads(n);
    }

    pub fn is_done(&self) -> bool {
        self.sched.is_done()
    }

    pub fn now(&self) -> SimTime {
        self.sched.now()
    }

    /// Submit a new study to the live run (see
    /// [`StudyScheduler::submit_study`] for the quota rules).
    pub fn submit_study(&mut self, spec: StudySpec, at: SimTime) -> Option<SimTime> {
        self.sched.submit_study(spec, at)
    }

    /// Advance to virtual time `t`, draining per-study progress after
    /// every event when logging is enabled (so each record carries the
    /// virtual time its transition actually happened).
    pub fn run_until(&mut self, t: SimTime) -> u64 {
        let n = self.drive_until(t);
        self.after_advance();
        n
    }

    /// Advance by `dt`; if the window is an idle gap, one event past it
    /// is processed so callers looping on `advance` always make progress
    /// (a return of 0 means the run is over).
    pub fn advance(&mut self, dt: SimTime) -> u64 {
        let mut n = self.drive_until(self.sched.now() + dt);
        if n == 0
            && !self.sched.is_done()
            && matches!(self.sched.step(), Step::Advanced(_))
        {
            n += 1;
            self.drain_progress();
        }
        self.after_advance();
        n
    }

    /// Drive to completion in `chunk`-sized slices (progress/snapshot
    /// cadence honored throughout).
    pub fn run_to_completion(&mut self, chunk: SimTime) -> u64 {
        let chunk = chunk.max(1.0);
        let mut n = 0;
        loop {
            let stepped = self.advance(chunk);
            n += stepped;
            if self.sched.is_done() || stepped == 0 {
                break;
            }
        }
        if self.snapshot_path.is_some() {
            let _ = self.snapshot_now();
        }
        n
    }

    fn drive_until(&mut self, t: SimTime) -> u64 {
        if self.log_dir.is_none() && self.progress_feed.is_none() {
            return self.sched.run_until(t);
        }
        let mut n = 0;
        while !self.sched.is_done() {
            // Windowed parallel stepping: process a whole inter-barrier
            // window, then emit its progress from the recorded marks —
            // each record still stamped with the virtual time its event
            // fired, byte-identical to the per-event serial drain.
            if self.sched.step_threads() > 1 {
                let stepped = self.sched.parallel_window(t);
                if stepped > 0 {
                    n += stepped;
                    self.drain_window_progress();
                    continue;
                }
            }
            match self.sched.next_event_time() {
                Some(next) if next <= t => {
                    if !matches!(self.sched.step(), Step::Advanced(_)) {
                        break;
                    }
                    n += 1;
                    self.drain_progress();
                }
                _ => break,
            }
        }
        n
    }

    /// Drain the progress of one parallel window from its per-event
    /// marks (see [`StudyScheduler::take_window_marks`]): each mark
    /// slices that study's agent event buffer up to the recorded length
    /// and stamps the records with the mark's event time, reproducing
    /// the serial per-event drain byte-for-byte.
    fn drain_window_progress(&mut self) {
        let marks = self.sched.take_window_marks();
        // The dirty set is superseded by the marks for this window.
        self.sched.take_dirty_studies();
        let mut fresh: Vec<(usize, String, Json)> = Vec::new();
        for (idx, at, events_len) in marks {
            let Some(st) = self.sched.studies().get(idx) else {
                continue;
            };
            let Some(agent) = st.agent() else { continue };
            let name = st.name().to_string();
            let seen = self.cursors.get(&idx).copied().unwrap_or(0);
            let upto = events_len.min(agent.events.len());
            for ev in &agent.events[seen.min(upto)..upto] {
                let doc = agent_event_json(agent.id, ev, at).with("study", Json::Str(name.clone()));
                fresh.push((idx, name.clone(), doc));
            }
            self.cursors.insert(idx, upto.max(seen));
        }
        self.progress_events += fresh.len() as u64;
        for (idx, name, doc) in fresh {
            if let Some(feed) = &self.progress_feed {
                feed.publish_json(&doc);
            }
            if self.log_dir.is_some() {
                if let Some(log) = self.log_for(idx, &name) {
                    let _ = log.append(&doc);
                }
            }
        }
    }

    /// Consume the platform into the outcome, draining final shutdown
    /// transitions into the logs first.
    pub fn into_outcome(mut self) -> MultiOutcome {
        self.after_advance();
        let MultiPlatform {
            sched,
            log_dir,
            mut logs,
            progress_feed,
            cursors,
            ..
        } = self;
        let outcome = sched.into_outcome();
        let now = outcome.end_time;
        if log_dir.is_some() || progress_feed.is_some() {
            for (idx, study) in outcome.studies.iter().enumerate() {
                let Some(agent) = &study.agent else { continue };
                let seen = cursors.get(&idx).copied().unwrap_or(0);
                for ev in &agent.events[seen..] {
                    let doc = agent_event_json(agent.id, ev, now)
                        .with("study", Json::Str(study.name.clone()));
                    if let Some(feed) = &progress_feed {
                        feed.publish_json(&doc);
                    }
                    if let Some(log) = open_study_log(&log_dir, &mut logs, idx, &study.name) {
                        let _ = log.append(&doc);
                    }
                }
            }
            for log in logs.values_mut() {
                let _ = log.flush();
            }
        }
        outcome
    }

    // -- progress stream ---------------------------------------------------

    fn after_advance(&mut self) {
        self.drain_progress();
        for log in self.logs.values_mut() {
            let _ = log.flush();
        }
        if let Some(gauge) = &self.generation_gauge {
            gauge.store(self.sched.events_processed(), Ordering::Release);
        }
        self.maybe_snapshot();
    }

    fn log_for(&mut self, idx: usize, name: &str) -> Option<&mut EventLog> {
        open_study_log(&self.log_dir, &mut self.logs, idx, name)
    }

    /// Drain fresh agent events into the per-study logs.  Only studies
    /// the scheduler marked dirty since the last drain are visited — the
    /// per-event drain in `drive_until` is O(touched studies), not
    /// O(all studies), which matters at 64+ tenants.
    fn drain_progress(&mut self) {
        if self.log_dir.is_none() && self.progress_feed.is_none() {
            // No sink: discard the marks so the list cannot grow across
            // a long unlogged run.
            self.sched.take_dirty_studies();
            return;
        }
        let now = self.sched.now();
        let mut fresh: Vec<(usize, String, Json)> = Vec::new();
        for idx in self.sched.take_dirty_studies() {
            let Some(st) = self.sched.studies().get(idx) else {
                continue;
            };
            let Some(agent) = st.agent() else { continue };
            let name = st.name().to_string();
            catch_up_cursor(&mut self.cursors, idx, agent, now, |doc| {
                fresh.push((idx, name.clone(), doc.with("study", Json::Str(name.clone()))));
            });
        }
        self.progress_events += fresh.len() as u64;
        for (idx, name, doc) in fresh {
            if let Some(feed) = &self.progress_feed {
                feed.publish_json(&doc);
            }
            if self.log_dir.is_some() {
                if let Some(log) = self.log_for(idx, &name) {
                    let _ = log.append(&doc);
                }
            }
        }
    }

    fn maybe_snapshot(&mut self) {
        if self.snapshot_path.is_none() {
            return;
        }
        if self.sched.now() - self.last_snapshot_t >= self.snapshot_every {
            let _ = self.snapshot_now();
        }
    }

    /// Write (and return) a snapshot right now.
    pub fn snapshot_now(&mut self) -> std::io::Result<Json> {
        let doc = self.sched.snapshot_json();
        if let Some(path) = &self.snapshot_path {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, doc.to_string_pretty())?;
        }
        self.last_snapshot_t = self.sched.now();
        Ok(doc)
    }

    /// Rebuild a platform from a snapshot file written by
    /// [`MultiPlatform::snapshot_now`] (state reproduced by replay).
    pub fn restore(
        path: impl AsRef<Path>,
        make_trainer: impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 't,
    ) -> anyhow::Result<MultiPlatform<'t>> {
        let text = std::fs::read_to_string(path)?;
        let doc = chopt_core::util::json::parse(&text)?;
        MultiPlatform::restore_doc(&doc, make_trainer)
    }

    /// [`MultiPlatform::restore`] from an already-parsed snapshot
    /// document (quiet replay).
    pub fn restore_doc(
        doc: &Json,
        make_trainer: impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 't,
    ) -> anyhow::Result<MultiPlatform<'t>> {
        Ok(MultiPlatform::from_restored_scheduler(
            StudyScheduler::restore(doc, make_trainer)?,
        ))
    }

    /// Full-fidelity restore for read models (`stored::StoredRun`):
    /// series retention stays on during the replay, so every rendered
    /// document is byte-identical to the live run's.
    pub fn restore_doc_full(
        doc: &Json,
        make_trainer: impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 't,
    ) -> anyhow::Result<MultiPlatform<'t>> {
        Ok(MultiPlatform::from_restored_scheduler(
            StudyScheduler::restore_full(doc, make_trainer)?,
        ))
    }

    /// Scrub restore: the platform view of the run after only `upto`
    /// recorded events (`stored::ReplaySource`, `?at_event=`) — the
    /// multi-study twin of [`Platform::restore_doc_at`].
    pub fn restore_doc_at(
        doc: &Json,
        make_trainer: impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 't,
        upto: u64,
    ) -> anyhow::Result<MultiPlatform<'t>> {
        Ok(MultiPlatform::from_restored_scheduler(
            StudyScheduler::restore_at(doc, make_trainer, upto)?,
        ))
    }

    /// Wrap a replayed scheduler: cursors start at the replayed state,
    /// and `progress_events` is reconciled to the count a live, logged
    /// run would report (one per agent event) so the status document
    /// stays byte-compatible between live and restored.
    fn from_restored_scheduler(sched: StudyScheduler<'t>) -> MultiPlatform<'t> {
        let mut platform = MultiPlatform::from_scheduler(sched);
        // Events up to the snapshot were already logged by the original
        // run; start the cursors at the replayed state.
        let ends: Vec<(usize, usize)> = platform
            .sched
            .studies()
            .iter()
            .enumerate()
            .filter_map(|(idx, st)| st.agent().map(|a| (idx, a.events.len())))
            .collect();
        platform.progress_events = ends.iter().map(|&(_, len)| len as u64).sum();
        for (idx, len) in ends {
            platform.cursors.insert(idx, len);
        }
        // Replay marked every touched study dirty; the cursors already
        // account for those events, so drop the marks.
        platform.sched.take_dirty_studies();
        platform.last_snapshot_t = platform.sched.now();
        platform
    }

    // -- live views --------------------------------------------------------

    /// Merged cluster-utilization / fair-share accounting (the
    /// multi-tenant Fig. 8 view): who is guaranteed what, who holds what,
    /// and who is borrowing beyond quota right now.
    pub fn fair_share_doc(&self) -> Json {
        let cluster = self.sched.cluster();
        let studies = self
            .sched
            .studies()
            .iter()
            .map(|st| {
                let (held, live, stop, dead, best) = match st.agent() {
                    Some(a) => (
                        cluster.held_by(chopt_cluster::Owner::Chopt(a.tenant)),
                        a.pools.live_count(),
                        a.pools.stop_count(),
                        a.pools.dead_count(),
                        a.best().map(|(_, m)| Json::Num(m)).unwrap_or(Json::Null),
                    ),
                    None => (0, 0, 0, 0, Json::Null),
                };
                Json::obj()
                    .with("study", Json::Str(st.name().to_string()))
                    .with("quota", Json::Num(st.quota() as f64))
                    .with("priority", Json::Num(st.priority()))
                    .with("paused", Json::Bool(st.paused()))
                    .with("health", Json::Str(st.health_label().to_string()))
                    .with("restarts", Json::Num(st.restarts() as f64))
                    .with("target", Json::Num(st.target() as f64))
                    .with("held", Json::Num(held as f64))
                    .with(
                        "borrowed",
                        Json::Num(held.saturating_sub(st.quota()) as f64),
                    )
                    .with("pool_live", Json::Num(live as f64))
                    .with("pool_stop", Json::Num(stop as f64))
                    .with("pool_dead", Json::Num(dead as f64))
                    .with("started", Json::Bool(st.started()))
                    .with("done", Json::Bool(st.done()))
                    .with("best", best)
            })
            .collect();
        Json::obj()
            .with("t", Json::Num(self.sched.now()))
            .with("cluster_gpus", Json::Num(cluster.total() as f64))
            .with("used", Json::Num(cluster.used() as f64))
            .with(
                "external",
                Json::Num(cluster.held_by(chopt_cluster::Owner::External) as f64),
            )
            .with("utilization", Json::Num(cluster.utilization()))
            .with("studies", Json::Arr(studies))
    }

    /// Live leaderboard for one study (rows shaped like
    /// [`Platform::leaderboard_doc`], plus the study label).
    ///
    /// Cached per study against the scheduler's processed-event count
    /// (the same RefCell pattern as the merged leaderboard): polling an
    /// idle run — or one where only *other* studies advanced the clock
    /// without any event — returns the previous document instead of
    /// re-ranking.
    pub fn study_leaderboard_doc(&self, name: &str, k: usize) -> Json {
        let processed = self.sched.events_processed();
        if let Some(c) = self.study_lb_cache.borrow().get(name) {
            if c.processed == processed && c.k == k {
                return c.doc.clone();
            }
        }
        let mut rows: Vec<Json> = Vec::new();
        if let Some(agent) = self.sched.study(name).and_then(|st| st.agent()) {
            for &(sid, best) in agent.leaderboard.top(k) {
                let s = &agent.sessions[&sid];
                rows.push(
                    Json::obj()
                        .with("study", Json::Str(name.to_string()))
                        .with("chopt", Json::Str(agent.id.to_string()))
                        .with("session", Json::Str(sid.0.to_string()))
                        .with("best", Json::Num(best))
                        .with("epochs", Json::Num(s.epochs as f64))
                        .with("status", Json::Str(s.status.name().to_string()))
                        .with("order", Json::Str(agent.cfg.order.name().to_string())),
                );
            }
        }
        let doc = Json::obj()
            .with("t", Json::Num(self.sched.now()))
            .with("study", Json::Str(name.to_string()))
            .with("rows", Json::Arr(rows));
        self.study_lb_cache.borrow_mut().insert(
            name.to_string(),
            LbCache {
                processed,
                k,
                doc: doc.clone(),
            },
        );
        doc
    }

    /// Sessions document for one study in the `SessionStore` format
    /// (rendered from references — no session clones).
    pub fn study_sessions_doc(&self, name: &str) -> Json {
        let mut runs: Vec<(String, Vec<&NsmlSession>)> = Vec::new();
        if let Some(agent) = self.sched.study(name).and_then(|st| st.agent()) {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            runs.push((format!("{name}-chopt-{}", agent.id), ss));
        }
        SessionStore::doc_from_refs(&runs)
    }

    /// Paginated session page for one study (the v1
    /// `/api/v1/studies/<name>/sessions` document).
    pub fn study_sessions_page_doc(&self, name: &str, limit: usize, offset: usize) -> Json {
        let mut all: Vec<(u64, &NsmlSession)> = Vec::new();
        if let Some(agent) = self.sched.study(name).and_then(|st| st.agent()) {
            let mut ss: Vec<&NsmlSession> = agent.sessions.values().collect();
            ss.sort_by_key(|s| s.id);
            all.extend(ss.into_iter().map(|s| (agent.id, s)));
        }
        sessions_page(all, limit, offset).with("study", Json::Str(name.to_string()))
    }

    /// Paginated curves page for one study (the v1
    /// `/api/v1/studies/<name>/curves` document).
    pub fn study_curves_page_doc(&self, name: &str, limit: usize, offset: usize) -> Json {
        let mut all: Vec<&NsmlSession> = Vec::new();
        if let Some(agent) = self.sched.study(name).and_then(|st| st.agent()) {
            all.extend(agent.sessions.values());
            all.sort_by_key(|s| s.id);
        }
        curves_page(&all, limit, offset).with("study", Json::Str(name.to_string()))
    }

    /// Study directory (the v1 `/api/v1/studies` document).
    pub fn studies_doc(&self) -> Json {
        let rows: Vec<Json> = self
            .sched
            .studies()
            .iter()
            .map(|st| {
                Json::obj()
                    .with("study", Json::Str(st.name().to_string()))
                    .with("quota", Json::Num(st.quota() as f64))
                    .with("priority", Json::Num(st.priority()))
                    .with("paused", Json::Bool(st.paused()))
                    .with("health", Json::Str(st.health_label().to_string()))
                    .with("started", Json::Bool(st.started()))
                    .with("done", Json::Bool(st.done()))
                    .with(
                        "sessions",
                        Json::Num(st.agent().map(|a| a.sessions.len()).unwrap_or(0) as f64),
                    )
            })
            .collect();
        Json::obj()
            .with("t", Json::Num(self.sched.now()))
            .with("count", Json::Num(rows.len() as f64))
            .with("studies", Json::Arr(rows))
    }

    /// Parallel-coordinates document for one study (axes from the
    /// study's own search space).
    pub fn study_parallel_doc(&self, name: &str) -> Option<Json> {
        let st = self.sched.study(name)?;
        let mut refs: Vec<&NsmlSession> = Vec::new();
        if let Some(agent) = st.agent() {
            refs.extend(agent.sessions.values());
            refs.sort_by_key(|s| s.id);
        }
        Some(export::parallel_coords_doc_refs(
            &st.config().space,
            &refs,
            st.config().order,
            name,
        ))
    }

    /// One-object run status across all studies, including the
    /// fault-tolerance rollup: how many studies are currently degraded
    /// (crashed, backoff pending) or quarantined, and the injected-
    /// failure accounting (`applied` vs `skipped`).
    pub fn status_doc(&self) -> Json {
        let sched = &self.sched;
        let (started, done, degraded, quarantined) =
            sched.studies().iter().fold((0, 0, 0, 0), |acc, st| {
                let h = st.health();
                (
                    acc.0 + usize::from(st.started()),
                    acc.1 + usize::from(st.done()),
                    acc.2 + usize::from(matches!(h, Health::Down { .. })),
                    acc.3 + usize::from(h.is_quarantined()),
                )
            });
        let (applied, skipped) = sched.fail_stats();
        Json::obj()
            .with("t", Json::Num(sched.now()))
            .with("events_processed", Json::Num(sched.events_processed() as f64))
            .with("done", Json::Bool(sched.is_done()))
            .with("studies", Json::Num(sched.studies().len() as f64))
            .with("studies_started", Json::Num(started as f64))
            .with("studies_done", Json::Num(done as f64))
            .with("studies_degraded", Json::Num(degraded as f64))
            .with("studies_quarantined", Json::Num(quarantined as f64))
            .with(
                "injected_failures",
                Json::obj()
                    .with("applied", Json::Num(applied as f64))
                    .with("skipped", Json::Num(skipped as f64)),
            )
            .with("utilization", Json::Num(sched.cluster().utilization()))
            .with("progress_events", Json::Num(self.progress_events as f64))
    }
}

/// Shared pagination shell: `total` + the `[offset, offset+limit)` page
/// of rows, each a session document labelled with its CHOPT agent id.
/// Out-of-range offsets yield an empty page, not an error.
fn sessions_page(all: Vec<(u64, &NsmlSession)>, limit: usize, offset: usize) -> Json {
    let total = all.len();
    let rows: Vec<Json> = all
        .into_iter()
        .skip(offset)
        .take(limit)
        .map(|(aid, s)| s.to_json().with("chopt", Json::Str(aid.to_string())))
        .collect();
    Json::obj()
        .with("total", Json::Num(total as f64))
        .with("offset", Json::Num(offset as f64))
        .with("returned", Json::Num(rows.len() as f64))
        .with("sessions", Json::Arr(rows))
}

/// The curves twin of [`sessions_page`]: the `[offset, offset+limit)`
/// window of per-session loss/measure curves.
fn curves_page(all: &[&NsmlSession], limit: usize, offset: usize) -> Json {
    let total = all.len();
    let page: Vec<&NsmlSession> = all
        .iter()
        .copied()
        .skip(offset)
        .take(limit)
        .collect();
    let curves = export::curves_doc_refs(&page);
    Json::obj()
        .with("total", Json::Num(total as f64))
        .with("offset", Json::Num(offset as f64))
        .with("returned", Json::Num(page.len() as f64))
        .with(
            "curves",
            curves.get("curves").cloned().unwrap_or(Json::Arr(Vec::new())),
        )
}

/// The single-study **read model**: queries serve from the incremental
/// documents.  `stored::StoredRun` reuses exactly this implementation
/// on a replayed engine, which is what makes stored bodies byte-
/// identical to live ones.
impl<'t> RunSource for Platform<'t> {
    fn generation(&self) -> u64 {
        self.engine.events_processed()
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        match q {
            ApiQuery::Status => Ok(self.status_doc()),
            ApiQuery::Cluster { window } => Ok(export::cluster_doc_windowed(
                self.engine.cluster(),
                self.engine.now(),
                *window,
            )),
            ApiQuery::Leaderboard { k } => Ok(self.leaderboard_doc(*k)),
            ApiQuery::Sessions { limit, offset } => Ok(self.sessions_page_doc(*limit, *offset)),
            ApiQuery::Curves { limit, offset } => Ok(self.curves_page_doc(*limit, *offset)),
            ApiQuery::Parallel => {
                let space = self
                    .engine
                    .all_agents()
                    .next()
                    .map(|a| a.cfg.space.clone())
                    .ok_or_else(|| ApiError::NotFound("no agent has started yet".into()))?;
                Ok(self.parallel_doc(&space))
            }
            ApiQuery::FairShare
            | ApiQuery::Studies
            | ApiQuery::StudySessions { .. }
            | ApiQuery::StudyLeaderboard { .. }
            | ApiQuery::StudyParallel { .. }
            | ApiQuery::StudyCurves { .. } => Err(ApiError::NotFound(
                "multi-study endpoint; this server runs a single study".into(),
            )),
            ApiQuery::Sweep | ApiQuery::SweepCell { .. } => Err(ApiError::NotFound(
                "sweep endpoint; serve a sweep directory (chopt serve --sweep)".into(),
            )),
        }
    }
}

/// The single-study **command side**: commands feed the engine's
/// recorded-input channel and take effect at the next event boundary.
impl<'t> CommandSink for Platform<'t> {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        let now = self.engine.now();
        let ack = |kind: &str, at: SimTime| {
            Json::obj()
                .with("applied", Json::Bool(true))
                .with("command", Json::Str(kind.to_string()))
                .with("effective_at", Json::Num(at))
        };
        match c {
            ApiCommand::Submit { config, at } => {
                let cfg = ChoptConfig::from_json(config)
                    .map_err(|e| ApiError::BadRequest(format!("bad config: {e:#}")))?;
                let at = self
                    .submit(cfg, (*at).unwrap_or(now))
                    .ok_or_else(|| ApiError::BadRequest("horizon reached".into()))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::PauseSession { session, .. } => {
                let at = self
                    .engine
                    .pause_session(SessionId(*session), now)
                    .ok_or_else(|| ApiError::BadRequest("session is not live".into()))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::ResumeSession { session, .. } => {
                let at = self
                    .engine
                    .resume_session(SessionId(*session), now)
                    .ok_or_else(|| ApiError::BadRequest("session is not paused".into()))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::StopSession { session, .. } => {
                let at = self
                    .engine
                    .stop_session(SessionId(*session), now)
                    .ok_or_else(|| ApiError::BadRequest("session is not live or paused".into()))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::SubmitStudy { .. }
            | ApiCommand::PauseStudy { .. }
            | ApiCommand::ResumeStudy { .. }
            | ApiCommand::StopStudy { .. }
            | ApiCommand::SetQuota { .. } => Err(ApiError::NotFound(
                "study command; this server runs a single study".into(),
            )),
        }
    }
}

/// The multi-tenant **read model** over a [`StudyScheduler`] — also
/// reused verbatim by `stored::StoredRun` for multi-study directories.
impl<'t> RunSource for MultiPlatform<'t> {
    fn generation(&self) -> u64 {
        self.sched.events_processed()
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        let known = |study: &str| -> Result<(), ApiError> {
            if self.sched.study(study).is_some() {
                Ok(())
            } else {
                Err(ApiError::NotFound(format!("unknown study '{study}'")))
            }
        };
        match q {
            ApiQuery::Status => Ok(self.status_doc()),
            ApiQuery::Cluster { window } => Ok(export::cluster_doc_windowed(
                self.sched.cluster(),
                self.sched.now(),
                *window,
            )),
            ApiQuery::FairShare => Ok(self.fair_share_doc()),
            ApiQuery::Studies => Ok(self.studies_doc()),
            ApiQuery::StudySessions {
                study,
                limit,
                offset,
            } => {
                known(study)?;
                Ok(self.study_sessions_page_doc(study, *limit, *offset))
            }
            ApiQuery::StudyLeaderboard { study, k } => {
                known(study)?;
                Ok(self.study_leaderboard_doc(study, *k))
            }
            ApiQuery::StudyCurves {
                study,
                limit,
                offset,
            } => {
                known(study)?;
                Ok(self.study_curves_page_doc(study, *limit, *offset))
            }
            ApiQuery::StudyParallel { study } => self
                .study_parallel_doc(study)
                .ok_or_else(|| ApiError::NotFound(format!("unknown study '{study}'"))),
            ApiQuery::Sessions { .. }
            | ApiQuery::Leaderboard { .. }
            | ApiQuery::Parallel
            | ApiQuery::Curves { .. } => Err(ApiError::NotFound(
                "single-study endpoint; use /api/v1/studies/<name>/…".into(),
            )),
            ApiQuery::Sweep | ApiQuery::SweepCell { .. } => Err(ApiError::NotFound(
                "sweep endpoint; serve a sweep directory (chopt serve --sweep)".into(),
            )),
        }
    }
}

/// The multi-tenant **command side** (study + session control).
impl<'t> CommandSink for MultiPlatform<'t> {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        let now = self.sched.now();
        let ack = |kind: &str, at: SimTime| {
            Json::obj()
                .with("applied", Json::Bool(true))
                .with("command", Json::Str(kind.to_string()))
                .with("effective_at", Json::Num(at))
        };
        // Session commands must name their study: local session ids
        // repeat across studies.
        let study_of = |study: &Option<String>| -> Result<&str, ApiError> {
            study.as_deref().ok_or_else(|| {
                ApiError::BadRequest("session commands need a 'study' on a multi-study run".into())
            })
        };
        let rejected = |msg: &str| ApiError::BadRequest(msg.to_string());
        match c {
            ApiCommand::SubmitStudy { spec, at } => {
                let spec = StudySpec::from_json(spec, self.sched.studies().len())
                    .map_err(|e| ApiError::BadRequest(format!("bad study spec: {e:#}")))?;
                let at = self
                    .submit_study(spec, (*at).unwrap_or(now))
                    .ok_or_else(|| {
                        rejected(
                            "study rejected (duplicate name, bad quota/priority, or quota does not fit)",
                        )
                    })?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::PauseStudy { study } => {
                let at = self
                    .sched
                    .pause_study(study, now)
                    .ok_or_else(|| rejected("unknown or finished study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::ResumeStudy { study } => {
                let at = self
                    .sched
                    .resume_study(study, now)
                    .ok_or_else(|| rejected("unknown or finished study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::StopStudy { study } => {
                let at = self
                    .sched
                    .stop_study(study, now)
                    .ok_or_else(|| rejected("unknown or finished study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::SetQuota {
                study,
                quota,
                priority,
            } => {
                let at = self
                    .sched
                    .set_quota(study, *quota, *priority, now)
                    .ok_or_else(|| {
                        rejected("rejected (unknown study, quota does not fit, or priority ≤ 0)")
                    })?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::PauseSession { study, session } => {
                let at = self
                    .sched
                    .pause_session(study_of(study)?, SessionId(*session), now)
                    .ok_or_else(|| rejected("session is not live in that study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::ResumeSession { study, session } => {
                let at = self
                    .sched
                    .resume_session(study_of(study)?, SessionId(*session), now)
                    .ok_or_else(|| rejected("session is not paused in that study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::StopSession { study, session } => {
                let at = self
                    .sched
                    .stop_session(study_of(study)?, SessionId(*session), now)
                    .ok_or_else(|| rejected("session is not live or paused in that study"))?;
                Ok(ack(c.name(), at))
            }
            ApiCommand::Submit { .. } => Err(ApiError::NotFound(
                "single-study command; use 'submit_study' on a multi-study run".into(),
            )),
        }
    }
}

/// Cursor catch-up shared by the progress drains: render `agent`'s
/// events past the cursor stored under `key` into `emit`, then advance
/// the cursor to the end of the agent's event vector.  Keys are agent
/// ids for [`Platform`] and study indices for [`MultiPlatform`].
fn catch_up_cursor<K: std::hash::Hash + Eq + Copy, T: ?Sized + Trainer>(
    cursors: &mut HashMap<K, usize>,
    key: K,
    agent: &Agent<T>,
    now: SimTime,
    mut emit: impl FnMut(Json),
) {
    let seen = cursors.get(&key).copied().unwrap_or(0);
    for ev in &agent.events[seen..] {
        emit(agent_event_json(agent.id, ev, now));
    }
    cursors.insert(key, agent.events.len());
}

/// Render one agent's top-`k` leaderboard rows (shared by the live
/// merged leaderboard and its done-agent row cache).  Ids are serialized
/// as strings: session ids pack (chopt_id << 32 | counter) into a u64,
/// which an f64 corrupts past 2^53 (same class as the trace seed PR 1
/// fixed).
fn agent_leaderboard_rows(agent: &Agent, k: usize, rows: &mut Vec<Json>) {
    let order = agent.cfg.order;
    for &(sid, best) in agent.leaderboard.top(k) {
        let s = &agent.sessions[&sid];
        rows.push(
            Json::obj()
                .with("chopt", Json::Str(agent.id.to_string()))
                .with("session", Json::Str(sid.0.to_string()))
                .with("best", Json::Num(best))
                .with("epochs", Json::Num(s.epochs as f64))
                .with("status", Json::Str(s.status.name().to_string()))
                .with("order", Json::Str(order.name().to_string())),
        );
    }
}

/// Lazily open `dir/events-<study>.jsonl` (free function so
/// [`MultiPlatform::into_outcome`] can use it after `sched` is moved).
fn open_study_log<'a>(
    dir: &Option<PathBuf>,
    logs: &'a mut HashMap<usize, EventLog>,
    idx: usize,
    name: &str,
) -> Option<&'a mut EventLog> {
    let dir = dir.as_ref()?;
    if !logs.contains_key(&idx) {
        let log = EventLog::open(dir.join(format!("events-{name}.jsonl"))).ok()?;
        logs.insert(idx, log);
    }
    logs.get_mut(&idx)
}

/// One pool transition as a structured JSONL record.  Agent/session ids
/// are serialized as **strings**: session ids pack `(chopt_id << 32 |
/// counter)` into a u64, and routing that through `Json::Num` (an f64)
/// silently corrupts values past 2^53 — the same corruption class PR 1
/// fixed for trace seeds.  The in-repo readers
/// (`EventLog::read_all`-based tests and the viz routes) treat these
/// fields as opaque labels, so the representation change is safe.
fn agent_event_json(agent_id: u64, ev: &AgentEvent, now: SimTime) -> Json {
    let sid_str = |sid: &chopt_core::nsml::SessionId| Json::Str(sid.0.to_string());
    let base = |name: &str| {
        Json::obj()
            .with("t", Json::Num(now))
            .with("chopt", Json::Str(agent_id.to_string()))
            .with("ev", Json::Str(name.to_string()))
    };
    match ev {
        AgentEvent::Launched(sid) => base("launched").with("session", sid_str(sid)),
        AgentEvent::Revived(sid) => base("revived").with("session", sid_str(sid)),
        AgentEvent::EarlyStopped(sid, pool) => base("early_stopped")
            .with("session", sid_str(sid))
            .with("pool", Json::Str(format!("{pool:?}").to_lowercase())),
        AgentEvent::Preempted(sid, pool) => base("preempted")
            .with("session", sid_str(sid))
            .with("pool", Json::Str(format!("{pool:?}").to_lowercase())),
        AgentEvent::Finished(sid) => base("finished").with("session", sid_str(sid)),
        AgentEvent::Mutated { victim, source } => base("mutated")
            .with("session", sid_str(victim))
            .with("source", sid_str(source)),
        AgentEvent::Evicted(sid) => base("evicted").with("session", sid_str(sid)),
        AgentEvent::Terminated(reason) => {
            base("terminated").with("reason", Json::Str(reason.to_string()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_engine::coordinator::pools::Pool;
    use chopt_core::nsml::SessionId;

    /// Regression for the u64-through-f64 id corruption: a session id
    /// above 2^53 must survive the progress stream byte-exactly.
    #[test]
    fn event_stream_ids_survive_past_f64_precision() {
        // (chopt_id << 32 | counter) with chopt_id = 2^22 lands at
        // 2^54 + 1 — one past f64's contiguous-integer range, so the old
        // Json::Num encoding would have silently rounded it.
        let big = (1u64 << 54) + 1;
        let sid = SessionId(big);
        for ev in [
            AgentEvent::Launched(sid),
            AgentEvent::Revived(sid),
            AgentEvent::EarlyStopped(sid, Pool::Stop),
            AgentEvent::Preempted(sid, Pool::Stop),
            AgentEvent::Finished(sid),
            AgentEvent::Evicted(sid),
        ] {
            let doc = agent_event_json(big, &ev, 1.0);
            let text = doc.to_string_compact();
            let back = chopt_core::util::json::parse(&text).unwrap();
            let session = back.get("session").and_then(|v| v.as_str()).unwrap();
            assert_eq!(session.parse::<u64>().unwrap(), big, "{ev:?}");
            let chopt = back.get("chopt").and_then(|v| v.as_str()).unwrap();
            assert_eq!(chopt.parse::<u64>().unwrap(), big);
        }
        let doc = agent_event_json(
            big,
            &AgentEvent::Mutated {
                victim: sid,
                source: SessionId(big + 1),
            },
            1.0,
        );
        assert_eq!(
            doc.get("source").and_then(|v| v.as_str()),
            Some(format!("{}", big + 1).as_str())
        );
    }
}
