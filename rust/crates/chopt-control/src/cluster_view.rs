//! Clustered overview: 2-D PCA projection of hyperparameter vectors
//! (Fig. 5 middle).  The paper uses t-SNE; PCA is our dependency-free
//! stand-in — the view's purpose (structural overview of created models,
//! colored by performance) is preserved.

use chopt_core::config::Order;
use chopt_core::hparam::Space;
use chopt_core::nsml::NsmlSession;

use crate::svg::Svg;

/// Power-iteration PCA: top-2 principal axes of the encoded vectors.
/// Returns (projections, explained-variance fractions).
pub fn pca2(data: &[Vec<f64>]) -> (Vec<(f64, f64)>, (f64, f64)) {
    let n = data.len();
    if n == 0 {
        return (Vec::new(), (0.0, 0.0));
    }
    let d = data[0].len();
    if d == 0 {
        return (vec![(0.0, 0.0); n], (0.0, 0.0));
    }
    // Center.
    let mut mean = vec![0.0; d];
    for row in data {
        for (m, &x) in mean.iter_mut().zip(row) {
            *m += x;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let centered: Vec<Vec<f64>> = data
        .iter()
        .map(|row| row.iter().zip(&mean).map(|(&x, &m)| x - m).collect())
        .collect();
    let total_var: f64 = centered
        .iter()
        .map(|r| r.iter().map(|x| x * x).sum::<f64>())
        .sum::<f64>()
        / n as f64;

    let mut axes: Vec<Vec<f64>> = Vec::new();
    let mut vars = [0.0f64; 2];
    let mut residual = centered.clone();
    for k in 0..2.min(d) {
        // Power iteration on X^T X.
        let mut v = vec![0.0; d];
        v[k % d] = 1.0;
        for _ in 0..100 {
            // w = X^T (X v)
            let mut w = vec![0.0; d];
            for row in &residual {
                let dot: f64 = row.iter().zip(&v).map(|(a, b)| a * b).sum();
                for (wi, &ri) in w.iter_mut().zip(row) {
                    *wi += dot * ri;
                }
            }
            let norm: f64 = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-12 {
                break;
            }
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / norm;
            }
        }
        // Variance along v + deflation.
        let mut var = 0.0;
        for row in &mut residual {
            let dot: f64 = row.iter().zip(&v).map(|(a, b)| a * b).sum();
            var += dot * dot;
            for (ri, &vi) in row.iter_mut().zip(&v) {
                *ri -= dot * vi;
            }
        }
        vars[k] = var / n as f64;
        axes.push(v);
    }
    while axes.len() < 2 {
        axes.push(vec![0.0; d]);
    }

    let proj: Vec<(f64, f64)> = centered
        .iter()
        .map(|row| {
            let x: f64 = row.iter().zip(&axes[0]).map(|(a, b)| a * b).sum();
            let y: f64 = row.iter().zip(&axes[1]).map(|(a, b)| a * b).sum();
            (x, y)
        })
        .collect();
    let ev = if total_var > 1e-12 {
        (vars[0] / total_var, vars[1] / total_var)
    } else {
        (0.0, 0.0)
    };
    (proj, ev)
}

/// Render the clustered view: PCA scatter colored by measure quantile.
pub fn render(space: &Space, sessions: &[NsmlSession], order: Order) -> Svg {
    let data: Vec<Vec<f64>> = sessions.iter().map(|s| space.encode(&s.hparams)).collect();
    let (proj, ev) = pca2(&data);
    let mut svg = Svg::new(420.0, 360.0);
    svg.text(
        20.0,
        18.0,
        12.0,
        &format!(
            "hyperparameter clustered view (PCA, ev {:.0}%/{:.0}%)",
            ev.0 * 100.0,
            ev.1 * 100.0
        ),
    );
    if proj.is_empty() {
        return svg;
    }
    let (x_lo, x_hi) = proj
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &(x, _)| {
            (l.min(x), h.max(x))
        });
    let (y_lo, y_hi) = proj
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &(_, y)| {
            (l.min(y), h.max(y))
        });
    let measures: Vec<Option<f64>> = sessions.iter().map(|s| s.best_measure(order)).collect();
    let m_hi = measures.iter().flatten().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    let m_lo = measures.iter().flatten().fold(f64::INFINITY, |a, &b| a.min(b));
    for (i, &(x, y)) in proj.iter().enumerate() {
        let px = 30.0 + (x - x_lo) / (x_hi - x_lo).max(1e-12) * 360.0;
        let py = 330.0 - (y - y_lo) / (y_hi - y_lo).max(1e-12) * 290.0;
        // Color by performance tercile: green good, orange mid, red poor.
        let c = match measures[i] {
            Some(m) if m_hi > m_lo => {
                let t = (m - m_lo) / (m_hi - m_lo);
                if t > 0.66 {
                    "#2ca02c"
                } else if t > 0.33 {
                    "#ff7f0e"
                } else {
                    "#d62728"
                }
            }
            _ => "#999999",
        };
        svg.circle(px, py, 4.0, c, 0.75);
    }
    svg
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::config::ChoptConfig;
    use chopt_core::hparam::{Assignment, Value};
    use chopt_core::nsml::SessionId;

    #[test]
    fn pca_identifies_dominant_axis() {
        // Points along (1, 2) direction: first component captures ~all var.
        let data: Vec<Vec<f64>> = (0..50)
            .map(|i| {
                let t = i as f64 / 10.0;
                vec![t, 2.0 * t, 0.001 * (i % 3) as f64]
            })
            .collect();
        let (proj, ev) = pca2(&data);
        assert_eq!(proj.len(), 50);
        assert!(ev.0 > 0.99, "ev0={}", ev.0);
        assert!(ev.1 < 0.01);
        // Projections along axis-1 are spread, axis-2 nearly constant.
        let spread0: f64 = proj.iter().map(|p| p.0.abs()).fold(0.0, f64::max);
        let spread1: f64 = proj.iter().map(|p| p.1.abs()).fold(0.0, f64::max);
        assert!(spread0 > 10.0 * spread1);
    }

    #[test]
    fn pca_degenerate_inputs() {
        assert!(pca2(&[]).0.is_empty());
        let (proj, ev) = pca2(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        assert_eq!(proj.len(), 2);
        assert_eq!(ev, (0.0, 0.0));
    }

    #[test]
    fn render_colors_by_measure() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let sessions: Vec<NsmlSession> = (0..9)
            .map(|i| {
                let mut hp = Assignment::new();
                hp.set("lr", Value::Float(0.01 + 0.008 * i as f64));
                hp.set("depth", Value::Int(5 + (i % 5) as i64));
                hp.set("activation", Value::Str("relu".into()));
                let mut s = NsmlSession::new(SessionId(i), hp, "m", 0.0);
                s.report(1, i as f64 * 10.0, 1.0);
                s
            })
            .collect();
        let doc = render(&cfg.space, &sessions, Order::Descending).finish();
        assert_eq!(doc.matches("<circle").count(), 9);
        assert!(doc.contains("#2ca02c") && doc.contains("#d62728"));
    }
}
