//! Terminal report views: leaderboard table + CHOPT session summary.

use chopt_core::config::Order;
use chopt_core::nsml::NsmlSession;
use chopt_core::util::bench::Table;

/// Leaderboard table of the top-k sessions.
pub fn leaderboard_table(sessions: &[NsmlSession], order: Order, k: usize) -> Table {
    let top = chopt_core::analysis::top_k(sessions, order, k);
    let mut t = Table::new(
        &format!("Leaderboard (top {k})"),
        &["rank", "session", "best", "epochs", "revivals", "hyperparameters"],
    );
    for (i, s) in top.iter().enumerate() {
        t.row(&[
            format!("{}", i + 1),
            format!("{}", s.id),
            s.best_measure(order)
                .map(|m| format!("{m:.2}"))
                .unwrap_or_else(|| "-".into()),
            format!("{}", s.epochs),
            format!("{}", s.revivals),
            s.hparams.render(),
        ]);
    }
    t
}

/// Pool/outcome summary of a finished CHOPT session.
pub fn outcome_table(agent: &chopt_engine::coordinator::Agent) -> Table {
    let mut t = Table::new(
        &format!("CHOPT session {} ({})", agent.id, agent.tuner.name()),
        &["metric", "value"],
    );
    let sessions: Vec<&NsmlSession> = agent.sessions.values().collect();
    let finished = sessions
        .iter()
        .filter(|s| s.status == chopt_core::nsml::SessionStatus::Finished)
        .count();
    t.row(&["models created".into(), format!("{}", agent.created)]);
    t.row(&["finished".into(), format!("{finished}")]);
    t.row(&["stop pool".into(), format!("{}", agent.pools.stop_count())]);
    t.row(&["dead pool".into(), format!("{}", agent.pools.dead_count())]);
    t.row(&[
        "best".into(),
        agent
            .best()
            .map(|(id, m)| format!("{m:.2} ({id})"))
            .unwrap_or_else(|| "-".into()),
    ]);
    let gpu_h: f64 = agent.sessions.values().map(|s| s.gpu_seconds).sum::<f64>() / 3600.0;
    t.row(&["GPU hours".into(), format!("{gpu_h:.1}")]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::hparam::{Assignment, Value};
    use chopt_core::nsml::SessionId;

    #[test]
    fn leaderboard_renders() {
        let sessions: Vec<NsmlSession> = (0..5)
            .map(|i| {
                let mut hp = Assignment::new();
                hp.set("lr", Value::Float(0.01));
                let mut s = NsmlSession::new(SessionId(i), hp, "m", 0.0);
                s.report(10, 70.0 + i as f64, 1.0);
                s
            })
            .collect();
        let t = leaderboard_table(&sessions, Order::Descending, 3);
        let s = t.render();
        assert!(s.contains("74.00"));
        assert!(s.contains("nsml-4"));
        assert!(!s.contains("70.00"), "only top-3 shown");
    }
}
