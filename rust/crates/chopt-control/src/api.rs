//! The versioned control-plane API: command + query `/api/v1`.
//!
//! The serving layer used to be a passive route table the engine loop
//! pushed full documents into on every tick.  This module replaces that
//! with a **pull-based** surface:
//!
//! * **Queries** — `GET /api/v1/{status,cluster,fair_share,studies,
//!   sessions,leaderboard,parallel,curves}` (plus per-study variants
//!   under `/api/v1/studies/<name>/`) are parsed into typed [`ApiQuery`]
//!   values and answered from a [`RunSource`]'s incremental documents at
//!   request time, instead of the loop re-rendering every document every
//!   tick whether anyone is watching or not.
//! * **Commands** — `POST /api/v1/commands` bodies parse into typed
//!   [`ApiCommand`] values which a [`CommandSink`] (the `SimEngine` /
//!   `StudyScheduler` loop) applies at tick boundaries (submit a study,
//!   pause/resume/stop a session or study, set quota/priority).
//!   Commands are recorded as replay inputs, so a command-steered run
//!   stays snapshot-restorable.
//! * **Envelope** — every response carries `schema_version`,
//!   `generated_at_event` (a *string*: event counts are u64), and the
//!   payload under `data` (or `error`).  All ids are strings throughout.
//!
//! The read side is deliberately its own trait so the same `/api/v1`
//! surface serves three run shapes behind one abstraction:
//!
//! * **live** — `Platform` / `MultiPlatform` answer from their
//!   incremental documents ([`RunSource`] + [`CommandSink`]),
//! * **stored** — `stored::StoredRun` rebuilds the identical documents
//!   from a run directory's snapshot (read-only: its [`CommandSink`]
//!   rejects every command),
//! * **replayed** — `stored::ReplaySource` scrubs a snapshot to any
//!   recorded event count (`?at_event=N` on any query).
//!
//! The legacy unversioned `/api/*.json` paths completed their documented
//! deprecation: they answer `410 Gone` with a `Link` header pointing at
//! the `/api/v1` path that replaced them.
//!
//! Threading: the HTTP server answers each connection on its own thread,
//! but the platform is single-threaded by design (`&mut` engine loop).
//! The bridge is a channel of [`ApiRequest`]s: connection threads enqueue
//! and block on a reply; the engine loop drains the [`ApiInbox`] between
//! advances — which is exactly the "commands apply at tick boundaries"
//! contract.  Auth (`--api-token`) and the SSE push stream
//! (`/api/v1/events`) are enforced/served by the HTTP layer itself, so
//! the engine loop never sees unauthorized commands and never blocks on
//! a slow stream consumer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use chopt_core::util::json::Value as Json;

/// Schema version stamped into every envelope.
pub const SCHEMA_VERSION: f64 = 1.0;

/// A typed v1 query (the GET half of the surface).
#[derive(Debug, Clone, PartialEq)]
pub enum ApiQuery {
    /// One-object run status heartbeat.
    Status,
    /// Cluster utilization; `window` caps the serialized series to the
    /// last `window` virtual seconds.
    Cluster { window: Option<f64> },
    /// Multi-tenant fair-share accounting (multi-study runs only).
    FairShare,
    /// Study directory (multi-study runs only).
    Studies,
    /// Paginated session list.
    Sessions { limit: usize, offset: usize },
    /// Merged leaderboard, top `k`.
    Leaderboard { k: usize },
    /// Parallel-coordinates document.
    Parallel,
    /// Paginated per-session loss/measure curves ("Scalar plot view").
    Curves { limit: usize, offset: usize },
    /// Paginated session list of one study.
    StudySessions {
        study: String,
        limit: usize,
        offset: usize,
    },
    /// One study's leaderboard, top `k`.
    StudyLeaderboard { study: String, k: usize },
    /// One study's parallel-coordinates document.
    StudyParallel { study: String },
    /// Paginated curves of one study.
    StudyCurves {
        study: String,
        limit: usize,
        offset: usize,
    },
    /// The whole sweep comparison artifact (sweep servers only).
    Sweep,
    /// One sweep cell's record by id (sweep servers only).
    SweepCell { cell: String },
}

/// A typed v1 command (the POST half).  Session ids travel as strings.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiCommand {
    /// Submit a new study from a manifest-style spec (multi-study runs).
    /// The spec is kept as raw JSON and parsed by the platform so parse
    /// errors surface as 400s with the real message.
    SubmitStudy { spec: Json, at: Option<f64> },
    /// Submit a new CHOPT session from a Listing-1 config (single-study).
    Submit { config: Json, at: Option<f64> },
    /// Park a live session until an explicit resume.
    PauseSession { study: Option<String>, session: u64 },
    /// Revive a paused session (priority-queued if no GPU is free).
    ResumeSession { study: Option<String>, session: u64 },
    /// Kill a session outright.
    StopSession { study: Option<String>, session: u64 },
    /// Hold a study at zero GPUs until resumed.
    PauseStudy { study: String },
    ResumeStudy { study: String },
    /// Shut a study down (its sessions finish with horizon semantics).
    StopStudy { study: String },
    /// Change a study's guaranteed quota and/or fair-share weight.
    SetQuota {
        study: String,
        quota: Option<usize>,
        priority: Option<f64>,
    },
}

impl ApiCommand {
    /// Parse a `POST /api/v1/commands` body.
    pub fn from_json(doc: &Json) -> Result<ApiCommand, String> {
        let kind = doc
            .get("command")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "body must carry a string 'command' field".to_string())?;
        let study = || {
            doc.get("study")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| format!("'{kind}' needs a string 'study' field"))
        };
        let opt_study = doc
            .get("study")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string());
        // Session ids are string-encoded u64s (bare numbers accepted for
        // convenience but corrupt past 2^53) — the shared wire form.
        let session = || -> Result<u64, String> {
            match doc.get("session") {
                Some(v) => chopt_core::nsml::SessionId::from_json(v)
                    .map(|sid| sid.0)
                    .ok_or_else(|| "'session' must be a string-encoded u64 id".to_string()),
                None => Err(format!("'{kind}' needs a 'session' field")),
            }
        };
        let at = doc.get("at").and_then(|v| v.as_f64());
        match kind {
            "submit_study" => Ok(ApiCommand::SubmitStudy {
                spec: doc
                    .get("study")
                    .cloned()
                    .ok_or_else(|| "'submit_study' needs a 'study' spec object".to_string())?,
                at,
            }),
            "submit" => Ok(ApiCommand::Submit {
                config: doc
                    .get("config")
                    .cloned()
                    .ok_or_else(|| "'submit' needs a 'config' object".to_string())?,
                at,
            }),
            "pause_session" => Ok(ApiCommand::PauseSession {
                study: opt_study,
                session: session()?,
            }),
            "resume_session" => Ok(ApiCommand::ResumeSession {
                study: opt_study,
                session: session()?,
            }),
            "stop_session" => Ok(ApiCommand::StopSession {
                study: opt_study,
                session: session()?,
            }),
            "pause_study" => Ok(ApiCommand::PauseStudy { study: study()? }),
            "resume_study" => Ok(ApiCommand::ResumeStudy { study: study()? }),
            "stop_study" => Ok(ApiCommand::StopStudy { study: study()? }),
            "set_quota" => {
                let quota = match doc.get("quota") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_usize()
                            .ok_or_else(|| "'quota' must be a non-negative integer".to_string())?,
                    ),
                };
                let priority = match doc.get("priority") {
                    None | Some(Json::Null) => None,
                    Some(v) => {
                        Some(v.as_f64().ok_or_else(|| "'priority' must be a number".to_string())?)
                    }
                };
                if quota.is_none() && priority.is_none() {
                    return Err("'set_quota' needs 'quota' and/or 'priority'".to_string());
                }
                Ok(ApiCommand::SetQuota {
                    study: study()?,
                    quota,
                    priority,
                })
            }
            other => Err(format!("unknown command '{other}'")),
        }
    }

    /// The command's wire name (acks echo it).
    pub fn name(&self) -> &'static str {
        match self {
            ApiCommand::SubmitStudy { .. } => "submit_study",
            ApiCommand::Submit { .. } => "submit",
            ApiCommand::PauseSession { .. } => "pause_session",
            ApiCommand::ResumeSession { .. } => "resume_session",
            ApiCommand::StopSession { .. } => "stop_session",
            ApiCommand::PauseStudy { .. } => "pause_study",
            ApiCommand::ResumeStudy { .. } => "resume_study",
            ApiCommand::StopStudy { .. } => "stop_study",
            ApiCommand::SetQuota { .. } => "set_quota",
        }
    }
}

/// Handler-side error: mapped to an HTTP status + error envelope.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Unknown resource (study, endpoint not served by this run shape).
    NotFound(String),
    /// The request was understood but invalid (bad param, rejected
    /// command, malformed embedded config).
    BadRequest(String),
    /// The command surface requires a bearer token and none was sent.
    Unauthorized(String),
    /// A bearer token was sent but it does not match `--api-token`.
    Forbidden(String),
}

impl ApiError {
    pub fn http_status(&self) -> u16 {
        match self {
            ApiError::NotFound(_) => 404,
            ApiError::BadRequest(_) => 400,
            ApiError::Unauthorized(_) => 401,
            ApiError::Forbidden(_) => 403,
        }
    }

    pub fn message(&self) -> &str {
        match self {
            ApiError::NotFound(m)
            | ApiError::BadRequest(m)
            | ApiError::Unauthorized(m)
            | ApiError::Forbidden(m) => m,
        }
    }
}

/// Route-parse outcome: a typed call, or an HTTP-level error.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiCall {
    Query(ApiQuery),
    /// A query scrubbed to a recorded event count (`?at_event=N`) —
    /// served by replay-capable sources ([`RunSource::query_at`]).
    QueryAt(ApiQuery, u64),
    Command(ApiCommand),
}

/// Route-level errors the server answers without consulting the platform.
#[derive(Debug, Clone, PartialEq)]
pub enum RouteError {
    /// Not an API path this version serves.
    NotFound,
    /// Known path, wrong method (GET on /commands, POST on a query).
    MethodNotAllowed,
    /// Bad query parameter or malformed command body.
    BadRequest(String),
    /// A retired legacy `/api/*.json` alias; carries the `/api/v1`
    /// path that replaced it (surfaced in the `Link` response header).
    Gone(String),
}

/// Parse an HTTP request into a typed API call.  `query` is the raw
/// query string (no leading `?`); `body` is the request body.
///
/// The legacy `/api/*.json` aliases completed their documented
/// deprecation: they answer `410 Gone` ([`RouteError::Gone`]) with a
/// pointer to the `/api/v1` path that replaced them.
pub fn parse_route(
    method: &str,
    path: &str,
    query: &str,
    body: &[u8],
) -> Result<ApiCall, RouteError> {
    if path == "/api/v1/commands" {
        if method != "POST" {
            return Err(RouteError::MethodNotAllowed);
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| RouteError::BadRequest("body is not UTF-8".into()))?;
        let doc = chopt_core::util::json::parse(text)
            .map_err(|e| RouteError::BadRequest(format!("malformed JSON body: {e}")))?;
        let cmd = ApiCommand::from_json(&doc).map_err(RouteError::BadRequest)?;
        return Ok(ApiCall::Command(cmd));
    }

    let q = match route_query(path, query)? {
        Some(q) => q,
        None => return Err(RouteError::NotFound),
    };
    if method != "GET" {
        return Err(RouteError::MethodNotAllowed);
    }
    // `?at_event=N` scrubs any query to a recorded event count (replay-
    // capable sources; others answer 400).
    match param_u64(query, "at_event")? {
        Some(at) => Ok(ApiCall::QueryAt(q, at)),
        None => Ok(ApiCall::Query(q)),
    }
}

/// Map a `/api/v1` path to a query, or `None` if unknown.  Retired
/// legacy aliases short-circuit to [`RouteError::Gone`] with their
/// replacement path.
fn route_query(path: &str, query: &str) -> Result<Option<ApiQuery>, RouteError> {
    if let Some(v1) = legacy_alias_replacement(path) {
        return Err(RouteError::Gone(v1));
    }
    let k = || param_usize(query, "k", 10);
    let limit = || param_usize(query, "limit", usize::MAX);
    let offset = || param_usize(query, "offset", 0);
    let q = match path {
        "/api/v1/status" => ApiQuery::Status,
        "/api/v1/cluster" => ApiQuery::Cluster {
            window: param_f64(query, "window")?,
        },
        "/api/v1/fair_share" => ApiQuery::FairShare,
        "/api/v1/studies" => ApiQuery::Studies,
        "/api/v1/sessions" => ApiQuery::Sessions {
            limit: limit()?,
            offset: offset()?,
        },
        "/api/v1/leaderboard" => ApiQuery::Leaderboard { k: k()? },
        "/api/v1/parallel" => ApiQuery::Parallel,
        "/api/v1/curves" => ApiQuery::Curves {
            limit: limit()?,
            offset: offset()?,
        },
        "/api/v1/sweep" => ApiQuery::Sweep,
        _ => {
            // /api/v1/sweep/cells/<id> — one grid cell of a served sweep.
            if let Some(cell) = path.strip_prefix("/api/v1/sweep/cells/") {
                if cell.is_empty() || cell.contains('/') {
                    return Ok(None);
                }
                return Ok(Some(ApiQuery::SweepCell {
                    cell: cell.to_string(),
                }));
            }
            // /api/v1/studies/<name>/<view> per-study routes.
            let Some(rest) = path.strip_prefix("/api/v1/studies/") else {
                return Ok(None);
            };
            let Some((study, view)) = rest.split_once('/') else {
                return Ok(None);
            };
            if study.is_empty() || study.contains('/') {
                return Ok(None);
            }
            let study = study.to_string();
            match view {
                "sessions" => ApiQuery::StudySessions {
                    study,
                    limit: limit()?,
                    offset: offset()?,
                },
                "leaderboard" => ApiQuery::StudyLeaderboard { study, k: k()? },
                "parallel" => ApiQuery::StudyParallel { study },
                "curves" => ApiQuery::StudyCurves {
                    study,
                    limit: limit()?,
                    offset: offset()?,
                },
                _ => return Ok(None),
            }
        }
    };
    Ok(Some(q))
}

/// The `/api/v1` path that replaced a retired legacy `/api/*.json`
/// alias, or `None` for paths that were never aliases.
fn legacy_alias_replacement(path: &str) -> Option<String> {
    match path {
        "/api/status.json" => Some("/api/v1/status".into()),
        "/api/cluster.json" => Some("/api/v1/cluster".into()),
        "/api/fair_share.json" => Some("/api/v1/fair_share".into()),
        "/api/sessions.json" => Some("/api/v1/sessions".into()),
        "/api/leaderboard.json" => Some("/api/v1/leaderboard".into()),
        "/api/parallel.json" => Some("/api/v1/parallel".into()),
        "/api/curves.json" => Some("/api/v1/curves".into()),
        _ => {
            let rest = path.strip_prefix("/api/studies/")?;
            let (study, view) = rest.split_once('/')?;
            if study.is_empty() || study.contains('/') {
                return None;
            }
            // The alias family served both `/sessions.json` and the
            // suffix-less `/sessions`; both are retired.
            let view = view.strip_suffix(".json").unwrap_or(view);
            matches!(view, "sessions" | "leaderboard" | "parallel" | "curves")
                .then(|| format!("/api/v1/studies/{study}/{view}"))
        }
    }
}

fn param<'q>(query: &'q str, name: &str) -> Option<&'q str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == name)
        .map(|(_, v)| v)
}

fn param_usize(query: &str, name: &str, default: usize) -> Result<usize, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(default),
        Some(v) => v.parse::<usize>().map_err(|_| {
            RouteError::BadRequest(format!("'{name}' must be a non-negative integer"))
        }),
    }
}

fn param_u64(query: &str, name: &str) -> Result<Option<u64>, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(None),
        Some(v) => v.parse::<u64>().map(Some).map_err(|_| {
            RouteError::BadRequest(format!("'{name}' must be a non-negative integer"))
        }),
    }
}

fn param_f64(query: &str, name: &str) -> Result<Option<f64>, RouteError> {
    match param(query, name) {
        None | Some("") => Ok(None),
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|w| w.is_finite() && *w >= 0.0)
            .map(Some)
            .ok_or_else(|| {
                RouteError::BadRequest(format!("'{name}' must be a non-negative number"))
            }),
    }
}

/// The **read side** of the `/api/v1` surface: one trait, three
/// backends.  Implemented by `coordinator::Platform` (live single
/// study), `coordinator::MultiPlatform` (live multi-tenant),
/// `stored::StoredRun` (a run directory rebuilt into the same
/// incremental documents), and `stored::ReplaySource` (scrub-to-event
/// replay).  Endpoints that don't apply to a run shape return
/// [`ApiError::NotFound`].
pub trait RunSource {
    /// Monotone progress marker stamped into every envelope
    /// (`generated_at_event`) — the engine's processed-event count.
    fn generation(&self) -> u64;

    /// Answer a query from the (incremental) documents.
    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError>;

    /// Answer `q` as of recorded event count `at` (`?at_event=N`).
    /// Returns the effective generation (the replayed event count, which
    /// caps at the snapshot's end) alongside the document.  Only replay-
    /// capable sources override this; live runs cannot rewind.
    fn query_at(&self, _q: &ApiQuery, _at: u64) -> Result<(u64, Json), ApiError> {
        Err(ApiError::BadRequest(
            "this run source does not support ?at_event — serve a stored run to scrub".into(),
        ))
    }

    /// True when this source's generation can never change (a stored
    /// run).  The response cache **pins** such entries: they stay valid
    /// without consulting the generation gauge, so the whole read
    /// surface becomes cache-resident after first touch.  `ReplaySource`
    /// stays `false` — scrubbing moves its generation.
    fn fixed_generation(&self) -> bool {
        false
    }
}

/// The **command side** of the surface: applied by the engine loop
/// between advances, so effects land at tick boundaries; the returned
/// ack documents what was accepted (commands take effect at the *next*
/// event boundary).  Read-only sources (stored runs) reject every
/// command.
pub trait CommandSink {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError>;
}

/// Read + command halves together — what a *live* platform exposes and
/// what the [`ApiInbox`] serves.  Blanket-implemented, so implementing
/// the two halves is all a backend ever does.
pub trait PlatformApi: RunSource + CommandSink {}

impl<T: RunSource + CommandSink> PlatformApi for T {}

/// Wrap a payload in the uniform v1 envelope.
pub fn envelope(generation: u64, data: Json) -> Json {
    Json::obj()
        .with("schema_version", Json::Num(SCHEMA_VERSION))
        .with("api", Json::Str("v1".into()))
        .with("generated_at_event", Json::Str(generation.to_string()))
        .with("data", data)
}

/// The error-envelope twin of [`envelope`].
pub fn error_envelope(generation: Option<u64>, message: &str) -> Json {
    Json::obj()
        .with("schema_version", Json::Num(SCHEMA_VERSION))
        .with("api", Json::Str("v1".into()))
        .with(
            "generated_at_event",
            generation
                .map(|g| Json::Str(g.to_string()))
                .unwrap_or(Json::Null),
        )
        .with("error", Json::Str(message.to_string()))
}

// ---------------------------------------------------------------------
// Read-side response cache
// ---------------------------------------------------------------------

/// Sentinel for "no generation published yet" in the [`ReadState`]
/// gauge.  Until the engine loop (or a platform wired via
/// `set_generation_gauge`) publishes a real value, HTTP workers bypass
/// the generation-keyed half of the cache rather than guess.
pub const GEN_UNKNOWN: u64 = u64::MAX;

/// Key of one cached rendered response.  Live entries key on
/// `(path, query, generation, epoch)` — a generation bump or an applied
/// command changes the key, so invalidation is implicit.  `pinned`
/// entries (`?at_event=` scrubs and fixed-generation stored runs) ignore
/// both counters: their bytes can never change for that path+query.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct CacheKey {
    path: String,
    query: String,
    generation: u64,
    epoch: u64,
    pinned: bool,
}

impl CacheKey {
    fn live(path: &str, query: &str, generation: u64, epoch: u64) -> CacheKey {
        CacheKey {
            path: path.to_string(),
            query: query.to_string(),
            generation,
            epoch,
            pinned: false,
        }
    }

    fn pinned(path: &str, query: &str) -> CacheKey {
        CacheKey {
            path: path.to_string(),
            query: query.to_string(),
            generation: 0,
            epoch: 0,
            pinned: true,
        }
    }
}

struct CacheEntry {
    body: Arc<Vec<u8>>,
    etag: String,
    last_used: u64,
}

/// Size-bounded LRU of rendered response bodies.  Bodies are `Arc`ed so
/// a hit is a refcount bump, not a copy; eviction is by total body
/// bytes, so many distinct param combinations cannot grow the map
/// without bound.  `max_bytes == 0` disables caching entirely.
struct ResponseCache {
    map: HashMap<CacheKey, CacheEntry>,
    max_bytes: usize,
    used_bytes: usize,
    tick: u64,
    hits: u64,
    insertions: u64,
}

impl ResponseCache {
    fn new(max_bytes: usize) -> ResponseCache {
        ResponseCache {
            map: HashMap::new(),
            max_bytes,
            used_bytes: 0,
            tick: 0,
            hits: 0,
            insertions: 0,
        }
    }

    fn get(&mut self, key: &CacheKey) -> Option<(Arc<Vec<u8>>, String)> {
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(key)?;
        entry.last_used = tick;
        self.hits += 1;
        Some((entry.body.clone(), entry.etag.clone()))
    }

    fn insert(&mut self, key: CacheKey, body: Arc<Vec<u8>>, etag: String) {
        if self.max_bytes == 0 || body.len() > self.max_bytes {
            return;
        }
        self.tick += 1;
        if let Some(old) = self.map.remove(&key) {
            self.used_bytes -= old.body.len();
        }
        self.used_bytes += body.len();
        self.insertions += 1;
        self.map.insert(
            key,
            CacheEntry {
                body,
                etag,
                last_used: self.tick,
            },
        );
        // LRU eviction by total bytes.  The scan is O(entries), but
        // eviction only runs when an insert crosses the bound — rare
        // next to lookups, and the map stays small (generation bumps
        // orphan old entries, which age out here).
        while self.used_bytes > self.max_bytes {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = self.map.remove(&k) {
                        self.used_bytes -= e.body.len();
                    }
                }
                None => break,
            }
        }
    }
}

/// Strong ETag for a v1 response: FNV-1a 64 over the cache-key fields,
/// with the generation visible in the suffix.  Deterministic across
/// restarts — an etag curl'd from a stored run keeps validating after
/// the server is restarted on the same directory.
pub fn etag_for(path: &str, query: &str, generation: u64, epoch: u64) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(path.as_bytes());
    eat(&[0]);
    eat(query.as_bytes());
    eat(&[0]);
    eat(&generation.to_le_bytes());
    eat(&epoch.to_le_bytes());
    format!("\"{h:016x}-{generation}\"")
}

/// Read-side state shared between the HTTP workers and the engine loop:
/// the generation gauge, the command epoch, and the response cache.
///
/// * **generation** — the source's processed-event count, published by
///   the engine loop whenever it answers or starts serving, and by the
///   platforms after every advance (`set_generation_gauge`), so workers
///   can key cache lookups without a round trip to the engine thread.
/// * **epoch** — bumped on every successfully applied command.  Some
///   commands (`set_quota`) mutate scheduler state without consuming an
///   engine event, so generation alone would serve stale bytes on an
///   idle engine; folding the epoch into live keys invalidates those
///   entries too.
/// * **cache** — the size-bounded LRU of rendered bodies.
pub struct ReadState {
    generation: Arc<AtomicU64>,
    epoch: AtomicU64,
    cache: Mutex<ResponseCache>,
}

impl ReadState {
    pub fn new(cache_bytes: usize) -> Arc<ReadState> {
        Arc::new(ReadState {
            generation: Arc::new(AtomicU64::new(GEN_UNKNOWN)),
            epoch: AtomicU64::new(0),
            cache: Mutex::new(ResponseCache::new(cache_bytes)),
        })
    }

    /// The gauge handle platforms publish into
    /// (`Platform::set_generation_gauge`).
    pub fn generation_gauge(&self) -> Arc<AtomicU64> {
        self.generation.clone()
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    pub fn publish_generation(&self, generation: u64) {
        self.generation.store(generation, Ordering::Release);
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Worker-side lookup: the pinned key first (scrub targets and
    /// stored-run bodies never go stale), then the live key at the
    /// current gauge — skipped while the gauge is still unknown.
    pub fn lookup(&self, path: &str, query: &str) -> Option<(Arc<Vec<u8>>, String)> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(hit) = cache.get(&CacheKey::pinned(path, query)) {
            return Some(hit);
        }
        let generation = self.generation();
        if generation == GEN_UNKNOWN {
            return None;
        }
        let epoch = self.epoch();
        cache.get(&CacheKey::live(path, query, generation, epoch))
    }

    /// Worker-side insert after a fresh render, keyed by the reply's
    /// authoritative [`CacheStamp`] (not the gauge — the engine may have
    /// advanced while the reply was in flight).  Returns the entry's
    /// ETag; the ETag is produced even when caching is disabled, so
    /// `If-None-Match` keeps working with `--cache-mb 0`.
    pub fn store(&self, path: &str, query: &str, stamp: &CacheStamp, body: Arc<Vec<u8>>) -> String {
        let (key, etag) = if stamp.pinned {
            (
                CacheKey::pinned(path, query),
                etag_for(path, query, stamp.generation, 0),
            )
        } else {
            (
                CacheKey::live(path, query, stamp.generation, stamp.epoch),
                etag_for(path, query, stamp.generation, stamp.epoch),
            )
        };
        self.cache.lock().unwrap().insert(key, body, etag.clone());
        etag
    }

    /// Cache counters for tests and benches:
    /// `(entries, used_bytes, hits, insertions)`.
    pub fn cache_stats(&self) -> (usize, usize, u64, u64) {
        let cache = self.cache.lock().unwrap();
        (cache.map.len(), cache.used_bytes, cache.hits, cache.insertions)
    }
}

/// Cache metadata the engine loop stamps onto successful query replies:
/// the generation/epoch the body was rendered at, and whether the entry
/// is immune to both (`pinned` — deterministic `?at_event=` scrubs and
/// fixed-generation stored runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStamp {
    pub generation: u64,
    pub epoch: u64,
    pub pinned: bool,
}

/// One answered API request travelling back over the bridge.
pub struct ApiReply {
    pub status: u16,
    pub body: Json,
    /// Present only on cacheable (status-200 query) replies.
    pub stamp: Option<CacheStamp>,
}

/// One in-flight HTTP API request: the parsed call plus the reply slot
/// the connection thread blocks on.
pub struct ApiRequest {
    pub call: ApiCall,
    pub reply: mpsc::Sender<ApiReply>,
}

/// The engine-loop end of the API bridge (`VizServer::enable_api`).
pub struct ApiInbox {
    rx: mpsc::Receiver<ApiRequest>,
    state: Arc<ReadState>,
}

impl ApiInbox {
    pub(crate) fn new(rx: mpsc::Receiver<ApiRequest>, state: Arc<ReadState>) -> ApiInbox {
        ApiInbox { rx, state }
    }

    /// The generation gauge the response cache keys live entries on.
    /// Wire it with `Platform::set_generation_gauge` so advances update
    /// cache keys immediately instead of at the next serve call — a GET
    /// racing an advance must never see a pre-advance body.
    pub fn generation_gauge(&self) -> Arc<AtomicU64> {
        self.state.generation_gauge()
    }

    fn error_reply(generation: u64, e: ApiError) -> ApiReply {
        ApiReply {
            status: e.http_status(),
            body: error_envelope(Some(generation), e.message()),
            stamp: None,
        }
    }

    fn answer(&self, req: ApiRequest, api: &mut impl PlatformApi) {
        // Scrubbed queries report the replayed event count as their
        // generation; everything else reports the source's current one.
        let reply = match &req.call {
            ApiCall::Query(q) => match api.query(q) {
                Ok(data) => {
                    let generation = api.generation();
                    ApiReply {
                        status: 200,
                        body: envelope(generation, data),
                        stamp: Some(CacheStamp {
                            generation,
                            epoch: self.state.epoch(),
                            pinned: api.fixed_generation(),
                        }),
                    }
                }
                Err(e) => Self::error_reply(api.generation(), e),
            },
            ApiCall::QueryAt(q, at) => match api.query_at(q, *at) {
                // Replay to a recorded position is deterministic, so the
                // entry is pinned: valid at any later generation.
                Ok((generation, data)) => ApiReply {
                    status: 200,
                    body: envelope(generation, data),
                    stamp: Some(CacheStamp {
                        generation,
                        epoch: 0,
                        pinned: true,
                    }),
                },
                Err(e) => Self::error_reply(api.generation(), e),
            },
            ApiCall::Command(c) => match api.command(c) {
                Ok(data) => {
                    // Applied commands can mutate state without consuming
                    // an engine event (set_quota): bump the epoch so live
                    // cache entries stop matching either way.
                    self.state.bump_epoch();
                    ApiReply {
                        status: 200,
                        body: envelope(api.generation(), data),
                        stamp: None,
                    }
                }
                Err(e) => Self::error_reply(api.generation(), e),
            },
        };
        // Answering doubles as a gauge publish — the cheap way to keep
        // un-wired sources (stored runs, replay scrubbers) current.
        self.state.publish_generation(api.generation());
        // A vanished client (timeout, dropped connection) is not an error.
        let _ = req.reply.send(reply);
    }

    /// Answer everything currently queued without blocking.  Returns the
    /// number of requests served.
    pub fn drain(&self, api: &mut impl PlatformApi) -> usize {
        self.state.publish_generation(api.generation());
        let mut n = 0;
        while let Ok(req) = self.rx.try_recv() {
            self.answer(req, api);
            n += 1;
        }
        n
    }

    /// Block up to `timeout` for one request and answer it.  Returns
    /// whether a request was served.
    pub fn serve_one(&self, api: &mut impl PlatformApi, timeout: Duration) -> bool {
        self.state.publish_generation(api.generation());
        match self.rx.recv_timeout(timeout) {
            Ok(req) => {
                self.answer(req, api);
                true
            }
            Err(_) => false,
        }
    }

    /// Serve requests for roughly `window` wall-clock time (the engine
    /// loop's between-advances breather — replaces a blind sleep).
    pub fn serve_for(&self, api: &mut impl PlatformApi, window: Duration) -> usize {
        let deadline = Instant::now() + window;
        let mut n = 0;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return n;
            }
            if self.serve_one(api, deadline - now) {
                n += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_aliases_are_gone_with_a_v1_pointer() {
        for (legacy, v1) in [
            ("/api/status.json", "/api/v1/status"),
            ("/api/cluster.json", "/api/v1/cluster"),
            ("/api/fair_share.json", "/api/v1/fair_share"),
            ("/api/sessions.json", "/api/v1/sessions"),
            ("/api/leaderboard.json", "/api/v1/leaderboard"),
            ("/api/parallel.json", "/api/v1/parallel"),
            ("/api/curves.json", "/api/v1/curves"),
            ("/api/studies/alice/sessions.json", "/api/v1/studies/alice/sessions"),
            ("/api/studies/alice/leaderboard", "/api/v1/studies/alice/leaderboard"),
        ] {
            match parse_route("GET", legacy, "", b"") {
                Err(RouteError::Gone(to)) => assert_eq!(to, v1, "{legacy}"),
                other => panic!("{legacy} must be Gone, got {other:?}"),
            }
            // The replacement itself still parses.
            assert!(parse_route("GET", v1, "", b"").is_ok(), "{v1}");
        }
        // Never-alias paths are a plain 404, not Gone.
        assert!(matches!(
            parse_route("GET", "/api/nope.json", "", b""),
            Err(RouteError::NotFound)
        ));
    }

    #[test]
    fn query_params_parse_and_validate() {
        assert_eq!(
            parse_route("GET", "/api/v1/sessions", "limit=5&offset=10", b"").unwrap(),
            ApiCall::Query(ApiQuery::Sessions {
                limit: 5,
                offset: 10
            })
        );
        assert_eq!(
            parse_route("GET", "/api/v1/cluster", "window=3600", b"").unwrap(),
            ApiCall::Query(ApiQuery::Cluster {
                window: Some(3600.0)
            })
        );
        assert_eq!(
            parse_route("GET", "/api/v1/leaderboard", "k=3", b"").unwrap(),
            ApiCall::Query(ApiQuery::Leaderboard { k: 3 })
        );
        assert!(matches!(
            parse_route("GET", "/api/v1/sessions", "limit=abc", b""),
            Err(RouteError::BadRequest(_))
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/cluster", "window=-5", b""),
            Err(RouteError::BadRequest(_))
        ));
    }

    #[test]
    fn sweep_routes_parse() {
        assert_eq!(
            parse_route("GET", "/api/v1/sweep", "", b"").unwrap(),
            ApiCall::Query(ApiQuery::Sweep)
        );
        assert_eq!(
            parse_route("GET", "/api/v1/sweep/cells/calm-random-strict", "", b"").unwrap(),
            ApiCall::Query(ApiQuery::SweepCell {
                cell: "calm-random-strict".into()
            })
        );
        // Empty or nested cell ids are not routes.
        assert!(matches!(
            parse_route("GET", "/api/v1/sweep/cells/", "", b""),
            Err(RouteError::NotFound)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/sweep/cells/a/b", "", b""),
            Err(RouteError::NotFound)
        ));
    }

    #[test]
    fn at_event_wraps_any_query_into_a_scrub_call() {
        assert_eq!(
            parse_route("GET", "/api/v1/status", "at_event=120", b"").unwrap(),
            ApiCall::QueryAt(ApiQuery::Status, 120)
        );
        assert_eq!(
            parse_route("GET", "/api/v1/curves", "limit=2&at_event=7", b"").unwrap(),
            ApiCall::QueryAt(ApiQuery::Curves { limit: 2, offset: 0 }, 7)
        );
        assert!(matches!(
            parse_route("GET", "/api/v1/status", "at_event=nope", b""),
            Err(RouteError::BadRequest(_))
        ));
    }

    #[test]
    fn methods_are_enforced() {
        assert!(matches!(
            parse_route("POST", "/api/v1/status", "", b""),
            Err(RouteError::MethodNotAllowed)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/commands", "", b""),
            Err(RouteError::MethodNotAllowed)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/nope", "", b""),
            Err(RouteError::NotFound)
        ));
        assert!(matches!(
            parse_route("GET", "/api/v1/studies/a/unknown", "", b""),
            Err(RouteError::NotFound)
        ));
    }

    #[test]
    fn command_bodies_parse() {
        let pause = parse_route(
            "POST",
            "/api/v1/commands",
            "",
            br#"{"command": "pause_session", "study": "alice", "session": "18014398509481985"}"#,
        )
        .unwrap();
        // Session ids round-trip as strings past 2^53.
        assert_eq!(
            pause,
            ApiCall::Command(ApiCommand::PauseSession {
                study: Some("alice".into()),
                session: (1u64 << 54) + 1,
            })
        );
        let quota = parse_route(
            "POST",
            "/api/v1/commands",
            "",
            br#"{"command": "set_quota", "study": "bob", "priority": 2.5}"#,
        )
        .unwrap();
        assert_eq!(
            quota,
            ApiCall::Command(ApiCommand::SetQuota {
                study: "bob".into(),
                quota: None,
                priority: Some(2.5),
            })
        );
        for bad in [
            &b"not json"[..],
            br#"{"command": "warp"}"#,
            br#"{"command": "pause_session"}"#,
            br#"{"command": "set_quota", "study": "x"}"#,
        ] {
            assert!(
                matches!(
                    parse_route("POST", "/api/v1/commands", "", bad),
                    Err(RouteError::BadRequest(_))
                ),
                "{:?} must be a 400",
                String::from_utf8_lossy(bad)
            );
        }
    }

    #[test]
    fn etag_is_deterministic_and_key_sensitive() {
        let base = etag_for("/api/v1/status", "", 42, 0);
        assert_eq!(base, etag_for("/api/v1/status", "", 42, 0));
        assert!(base.starts_with('"') && base.ends_with('"'), "{base}");
        assert!(base.contains("-42"), "generation visible in {base}");
        for other in [
            etag_for("/api/v1/sessions", "", 42, 0),
            etag_for("/api/v1/status", "limit=2", 42, 0),
            etag_for("/api/v1/status", "", 43, 0),
            etag_for("/api/v1/status", "", 42, 1),
        ] {
            assert_ne!(base, other);
        }
    }

    #[test]
    fn response_cache_is_lru_and_byte_bounded() {
        let mut c = ResponseCache::new(100);
        let body = |n: usize| Arc::new(vec![b'x'; n]);
        c.insert(CacheKey::live("/a", "", 1, 0), body(40), "a".into());
        c.insert(CacheKey::live("/b", "", 1, 0), body(40), "b".into());
        // Touch /a so /b is the LRU victim when /c overflows the bound.
        assert!(c.get(&CacheKey::live("/a", "", 1, 0)).is_some());
        c.insert(CacheKey::live("/c", "", 1, 0), body(40), "c".into());
        assert!(c.get(&CacheKey::live("/b", "", 1, 0)).is_none(), "LRU evicted");
        assert!(c.get(&CacheKey::live("/a", "", 1, 0)).is_some());
        assert!(c.get(&CacheKey::live("/c", "", 1, 0)).is_some());
        assert!(c.used_bytes <= 100);
        // Oversized bodies and a zero-byte cache are never stored.
        c.insert(CacheKey::live("/big", "", 1, 0), body(101), "big".into());
        assert!(c.get(&CacheKey::live("/big", "", 1, 0)).is_none());
        let mut off = ResponseCache::new(0);
        off.insert(CacheKey::live("/a", "", 1, 0), body(1), "a".into());
        assert!(off.get(&CacheKey::live("/a", "", 1, 0)).is_none());
    }

    #[test]
    fn read_state_keys_on_generation_epoch_and_pinning() {
        let state = ReadState::new(1 << 20);
        let body = Arc::new(b"{\"data\":1}".to_vec());

        // Live entries stay invisible until the gauge knows the
        // generation they were rendered at.
        let live = CacheStamp { generation: 7, epoch: 0, pinned: false };
        let etag = state.store("/api/v1/status", "", &live, body.clone());
        assert!(state.lookup("/api/v1/status", "").is_none(), "gauge unknown");
        state.publish_generation(7);
        let (hit, hit_etag) = state.lookup("/api/v1/status", "").unwrap();
        assert_eq!((hit.as_slice(), hit_etag.as_str()), (body.as_slice(), etag.as_str()));
        // A generation bump or an applied command orphans the entry.
        state.publish_generation(8);
        assert!(state.lookup("/api/v1/status", "").is_none());
        state.publish_generation(7);
        state.bump_epoch();
        assert!(state.lookup("/api/v1/status", "").is_none());

        // Pinned entries (scrubs, stored runs) hit regardless of both.
        let pinned = CacheStamp { generation: 5, epoch: 0, pinned: true };
        state.store("/api/v1/status", "at_event=5", &pinned, body.clone());
        state.publish_generation(GEN_UNKNOWN);
        assert!(state.lookup("/api/v1/status", "at_event=5").is_some());
        // Distinct ?at_event= targets are distinct query strings: they
        // never share an entry or an etag.
        let pinned9 = CacheStamp { generation: 9, epoch: 0, pinned: true };
        let e9 = state.store("/api/v1/status", "at_event=9", &pinned9, body.clone());
        let e5 = state.lookup("/api/v1/status", "at_event=5").unwrap().1;
        assert_ne!(e5, e9);
    }

    #[test]
    fn envelope_shape() {
        let e = envelope(u64::MAX, Json::obj().with("x", Json::Num(1.0)));
        let text = e.to_string_compact();
        let back = chopt_core::util::json::parse(&text).unwrap();
        assert_eq!(back.get("schema_version").unwrap().as_f64(), Some(1.0));
        // The generation survives as a string even past 2^53.
        assert_eq!(
            back.get("generated_at_event").unwrap().as_str(),
            Some(u64::MAX.to_string().as_str())
        );
        assert_eq!(back.path("data.x").unwrap().as_f64(), Some(1.0));
        let err = error_envelope(None, "nope");
        assert!(err.get("generated_at_event").unwrap().is_null());
        assert_eq!(err.get("error").unwrap().as_str(), Some("nope"));
    }
}
