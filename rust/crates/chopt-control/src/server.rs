//! Dependency-free HTTP server for the analytic tool.
//!
//! Three serving surfaces compose:
//!
//! * a **static route table** (`Routes`) for the embedded viewer and SVG
//!   renders,
//! * the **versioned control-plane API** (`/api/v1`, see [`crate::api`])
//!   when enabled via [`VizServer::enable_api`]: API paths are parsed
//!   into typed calls and forwarded over a channel to the serving loop,
//!   which answers them between advances from any `RunSource` — a live
//!   platform, a stored run, or a replay scrubber.  The legacy
//!   `/api/*.json` aliases completed their deprecation and answer
//!   `410 Gone` with a `Link` pointer to the v1 path.  When a
//!   bearer token is configured ([`VizServer::set_api_token`]) the
//!   command surface (`POST /api/v1/commands`) answers 401/403 in the
//!   envelope error format before anything reaches the engine loop; the
//!   read side stays open.
//! * the **SSE push stream** (`GET /api/v1/events`, see
//!   [`crate::sse`]) when enabled via [`VizServer::serve_events`]:
//!   subscribers are adopted by a small broadcast writer pool
//!   ([`crate::sse::Broadcaster`]) with per-subscriber heartbeats,
//!   `Last-Event-ID` resume, and `?since=<seq>` historical replay when
//!   the feed carries a JSONL history log.
//!
//! **Concurrency model** ([`ServerConfig`]): a fixed pool of worker
//! threads drains a bounded connection queue.  When the queue is full
//! the accept loop sheds the connection with an immediate `503` +
//! `Retry-After` instead of spawning without limit — under overload the
//! server degrades to fast rejections, not to thread exhaustion.  SSE
//! subscribers are handed off to the broadcast pool, so thousands of
//! open streams occupy neither request workers nor a thread each — just
//! an entry in a writer shard.  Request sockets carry read *and* write
//! timeouts plus a total header deadline, so a stalled or slow-loris
//! client cannot pin a worker (SSE connections keep their
//! heartbeat-based liveness instead).
//!
//! **Response cache** ([`crate::api::ReadState`]): rendered v1
//! query bodies are cached keyed on `(path, params, generation, epoch)`
//! — a generation bump (engine advance) or an applied command changes
//! the key, so invalidation is implicit and a repeat GET at a fixed
//! generation is a lock + `Arc` clone, never a re-render or an engine
//! round trip.  Stored runs and `?at_event=` scrubs cache as *pinned*
//! entries (their bytes can never change), making the whole read surface
//! of a stored run cache-resident after first touch.  Every query
//! response carries a strong `ETag` + `Cache-Control: no-cache`;
//! `If-None-Match` answers a bodyless `304`.

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::{self, ApiCall, ApiInbox, ApiRequest, ReadState, RouteError};
use crate::sse::{Broadcaster, EventFeed, DEFAULT_BROADCAST_WRITERS};

/// A route table: path → (content type, body).
pub type Routes = HashMap<String, (String, Vec<u8>)>;

/// Largest accepted request body (command manifests are small).
const MAX_BODY: usize = 1 << 20;

/// How long a worker waits for the engine loop to answer an API request
/// before giving up with a 503.
const API_REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-read socket timeout while parsing a request (each `recv`).
const REQUEST_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Total wall-clock budget for reading one request (headers + body): a
/// drip-feeding client is cut off here even if every individual read
/// stays under [`REQUEST_READ_TIMEOUT`].
const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// Write timeout on request responses (SSE uses its own, longer one).
const RESPONSE_WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Write timeout on SSE streams: generous (streams are long-lived and
/// bursty) but bounded — it caps how long a stalled subscriber can
/// block its broadcast-pool shard before being dropped.
const SSE_WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Longest accepted header line and header count (slow-loris bounds).
const MAX_HEADER_LINE: usize = 8 * 1024;
const MAX_HEADER_LINES: usize = 128;

/// Worker threads' handle to the API bridge (None until
/// [`VizServer::enable_api`]).
type ApiSender = Arc<Mutex<Option<mpsc::Sender<ApiRequest>>>>;

/// Sizing knobs for the worker pool and the response cache.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Fixed number of request worker threads.
    pub workers: usize,
    /// Bounded connection-queue depth; accepts past it answer 503.
    pub queue: usize,
    /// Response-cache bound in bytes (0 disables caching; ETags remain).
    pub cache_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 8,
            queue: 128,
            cache_bytes: 32 << 20,
        }
    }
}

/// The SSE surface: the feed plus the broadcast pool that fans it out.
#[derive(Clone)]
struct SseHandle {
    feed: Arc<EventFeed>,
    broadcast: Arc<Broadcaster<TcpStream>>,
}

/// Everything a worker needs, cloned per pool thread.
#[derive(Clone)]
struct ConnShared {
    routes: Arc<Mutex<Routes>>,
    api_tx: ApiSender,
    token: Arc<Mutex<Option<String>>>,
    sse: Arc<Mutex<Option<SseHandle>>>,
    stop: Arc<AtomicBool>,
    state: Arc<ReadState>,
    sse_active: Arc<AtomicU64>,
}

/// The bounded connection queue between the accept loop and the worker
/// pool.  `push` fails (returning the stream) when full — that is the
/// accept loop's backpressure signal.
struct ConnQueue {
    inner: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    capacity: usize,
}

impl ConnQueue {
    fn new(capacity: usize) -> ConnQueue {
        ConnQueue {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    fn push(&self, stream: TcpStream) -> Result<(), TcpStream> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(stream);
        }
        q.push_back(stream);
        drop(q);
        self.cv.notify_one();
        Ok(())
    }

    /// Pop one connection, waiting up to `timeout`.  Workers loop on
    /// this with a short timeout so the stop flag is observed promptly.
    fn pop(&self, timeout: Duration) -> Option<TcpStream> {
        let mut q = self.inner.lock().unwrap();
        if q.is_empty() {
            let (guard, _) = self.cv.wait_timeout(q, timeout).unwrap();
            q = guard;
        }
        q.pop_front()
    }
}

/// The viz HTTP server.
pub struct VizServer {
    shared: ConnShared,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Connections accepted over the server's lifetime.
    pub requests: Arc<AtomicU64>,
    /// Connections shed with a 503 because the queue was full.
    pub rejected: Arc<AtomicU64>,
}

impl VizServer {
    /// Bind on 127.0.0.1:`port` (0 = ephemeral) and start serving with
    /// the default pool/cache sizing.
    pub fn start(port: u16, routes: Routes) -> std::io::Result<VizServer> {
        VizServer::start_with(port, routes, ServerConfig::default())
    }

    /// [`VizServer::start`] with explicit worker-pool and cache sizing.
    pub fn start_with(
        port: u16,
        mut routes: Routes,
        config: ServerConfig,
    ) -> std::io::Result<VizServer> {
        routes
            .entry("/".to_string())
            .or_insert(("text/html".to_string(), VIEWER_HTML.as_bytes().to_vec()));
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = ConnShared {
            routes: Arc::new(Mutex::new(routes)),
            api_tx: Arc::new(Mutex::new(None)),
            token: Arc::new(Mutex::new(None)),
            sse: Arc::new(Mutex::new(None)),
            stop: stop.clone(),
            state: ReadState::new(config.cache_bytes),
            sse_active: Arc::new(AtomicU64::new(0)),
        };
        let requests = Arc::new(AtomicU64::new(0));
        let rejected = Arc::new(AtomicU64::new(0));
        let queue = Arc::new(ConnQueue::new(config.queue));

        let (s2, q2, r2, queue2) =
            (stop.clone(), requests.clone(), rejected.clone(), queue.clone());
        let accept = std::thread::Builder::new()
            .name("viz-accept".into())
            .spawn(move || {
                while !s2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            q2.fetch_add(1, Ordering::Relaxed);
                            if let Err(stream) = queue2.push(stream) {
                                // Backpressure: every worker is busy and
                                // the queue is at capacity.  Shed the
                                // connection with an immediate 503 —
                                // bounded load, never unbounded threads.
                                r2.fetch_add(1, Ordering::Relaxed);
                                reject_saturated(stream);
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;

        let mut workers = Vec::with_capacity(config.workers.max(1));
        for i in 0..config.workers.max(1) {
            let (shared_i, queue_i) = (shared.clone(), queue.clone());
            workers.push(
                std::thread::Builder::new()
                    .name(format!("viz-worker-{i}"))
                    .spawn(move || loop {
                        match queue_i.pop(Duration::from_millis(100)) {
                            Some(stream) => {
                                let _ = handle_conn(stream, &shared_i);
                            }
                            None => {
                                if shared_i.stop.load(Ordering::Relaxed) {
                                    return;
                                }
                            }
                        }
                    })?,
            );
        }

        Ok(VizServer {
            shared,
            addr,
            stop,
            queue,
            accept: Some(accept),
            workers,
            requests,
            rejected,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Enable the `/api/v1` surface: API paths stop falling through to
    /// the static table and are forwarded to the returned [`ApiInbox`],
    /// which the engine loop drains between advances.  The inbox shares
    /// this server's [`ReadState`], so answered queries populate the
    /// response cache and applied commands invalidate it.
    pub fn enable_api(&self) -> ApiInbox {
        let (tx, rx) = mpsc::channel();
        *self.shared.api_tx.lock().unwrap() = Some(tx);
        ApiInbox::new(rx, self.shared.state.clone())
    }

    /// Require `Authorization: Bearer <token>` on the command surface
    /// (`POST /api/v1/commands`).  The read side stays open; a missing
    /// header answers 401 and a mismatched token 403, both in the
    /// envelope error format.  `None` re-opens the surface.
    pub fn set_api_token(&self, token: Option<String>) {
        *self.shared.token.lock().unwrap() = token;
    }

    /// Serve `GET /api/v1/events` as an SSE stream of `feed`: a small
    /// broadcast writer pool tails the feed for every subscriber (off
    /// the worker pool), with a comment heartbeat every `heartbeat`
    /// while a stream is idle, `Last-Event-ID` resume, and
    /// `?since=<seq>` history replay when the feed records one.
    pub fn serve_events(&self, feed: Arc<EventFeed>, heartbeat: Duration) {
        self.serve_events_with(feed, heartbeat, DEFAULT_BROADCAST_WRITERS);
    }

    /// [`VizServer::serve_events`] with an explicit broadcast-pool
    /// size.  Calling it again replaces the surface: new subscribers go
    /// to the new pool, while streams the old pool already owns keep
    /// draining until they disconnect or the server stops.
    pub fn serve_events_with(&self, feed: Arc<EventFeed>, heartbeat: Duration, writers: usize) {
        let broadcast = Broadcaster::start(
            feed.clone(),
            heartbeat,
            writers,
            self.stop.clone(),
            self.shared.sse_active.clone(),
        );
        *self.shared.sse.lock().unwrap() = Some(SseHandle { feed, broadcast });
    }

    /// Currently open SSE subscriber connections.
    pub fn sse_active(&self) -> u64 {
        self.shared.sse_active.load(Ordering::Relaxed)
    }

    /// Replace/add a route while running.
    pub fn put_route(&self, path: &str, content_type: &str, body: Vec<u8>) {
        self.shared
            .routes
            .lock()
            .unwrap()
            .insert(path.to_string(), (content_type.to_string(), body));
    }

    /// Replace/add a JSON route while running (static-document serving;
    /// live runs answer through the v1 API instead).
    pub fn put_json(&self, path: &str, doc: &chopt_core::util::json::Value) {
        self.put_route(path, "application/json", doc.to_string_compact().into_bytes());
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.cv.notify_all();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // The SSE broadcast writers are detached; they observe the stop
        // flag within one wait slice, release their subscribers (the
        // gauge drains to zero), and exit on their own.
    }

    pub fn stop(mut self) {
        self.shutdown();
    }
}

impl Drop for VizServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Best-effort 503 for a shed connection: written before the request is
/// even read, with a short write timeout so a hostile peer cannot stall
/// the accept loop either.
fn reject_saturated(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let doc = api::error_envelope(None, "server saturated: connection queue is full");
    let _ = respond(
        &mut stream,
        503,
        "application/json",
        &doc.to_string_compact().into_bytes(),
        "Retry-After: 1\r\n",
    );
}

/// One parsed HTTP request.
struct Request {
    method: String,
    path: String,
    query: String,
    body: Vec<u8>,
    /// Raw `Authorization` header value, if sent.
    authorization: Option<String>,
    /// Parsed `Last-Event-ID` header (SSE resume), if sent.
    last_event_id: Option<u64>,
    /// Raw `If-None-Match` header (ETag revalidation), if sent.
    if_none_match: Option<String>,
}

/// Read one header line byte-wise so both bounds hold: the per-recv
/// socket timeout catches a stalled client, the deadline catches a
/// drip-feeding one, and the length cap catches an endless line.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    deadline: Instant,
) -> std::io::Result<String> {
    let mut out: Vec<u8> = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        if out.len() > MAX_HEADER_LINE {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "header line too long",
            ));
        }
        if Instant::now() >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request read deadline exceeded",
            ));
        }
        match reader.read(&mut byte)? {
            0 => break, // EOF
            _ => {
                out.push(byte[0]);
                if byte[0] == b'\n' {
                    break;
                }
            }
        }
    }
    Ok(String::from_utf8_lossy(&out).into_owned())
}

fn read_request(stream: &TcpStream) -> std::io::Result<Option<Request>> {
    stream.set_read_timeout(Some(REQUEST_READ_TIMEOUT))?;
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut reader = BufReader::new(stream.try_clone()?);
    let request_line = read_line_bounded(&mut reader, deadline)?;
    if request_line.trim().is_empty() {
        // Connection opened and closed (or never spoke): nothing to do.
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "empty request",
        ));
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("GET").to_uppercase();
    let target = parts.next().unwrap_or("/");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    // Drain headers, keeping the ones the API layer consumes.
    let mut content_length = 0usize;
    let mut authorization = None;
    let mut last_event_id = None;
    let mut if_none_match = None;
    for _ in 0..MAX_HEADER_LINES {
        let line = read_line_bounded(&mut reader, deadline)?;
        if line.is_empty() || line == "\r\n" || line == "\n" {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            } else if name.eq_ignore_ascii_case("authorization") {
                authorization = Some(value.trim().to_string());
            } else if name.eq_ignore_ascii_case("last-event-id") {
                last_event_id = value.trim().parse().ok();
            } else if name.eq_ignore_ascii_case("if-none-match") {
                if_none_match = Some(value.trim().to_string());
            }
        }
    }
    if content_length > MAX_BODY {
        return Ok(None); // caller answers 400
    }
    let mut body = vec![0u8; content_length];
    let mut off = 0;
    while off < content_length {
        if Instant::now() >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request body read deadline exceeded",
            ));
        }
        let n = reader.read(&mut body[off..])?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "request body truncated",
            ));
        }
        off += n;
    }
    Ok(Some(Request {
        method,
        path,
        query,
        body,
        authorization,
        last_event_id,
        if_none_match,
    }))
}

fn handle_conn(mut stream: TcpStream, shared: &ConnShared) -> std::io::Result<()> {
    let req = match read_request(&stream)? {
        Some(r) => r,
        None => {
            stream.set_write_timeout(Some(RESPONSE_WRITE_TIMEOUT))?;
            return respond_json(
                &mut stream,
                400,
                &api::error_envelope(None, "request body too large"),
            );
        }
    };
    stream.set_write_timeout(Some(RESPONSE_WRITE_TIMEOUT))?;

    // The SSE push stream, when enabled, owns /api/v1/events.  It never
    // goes through the engine-loop bridge: the worker writes the stream
    // head and hands the socket to the broadcast pool, so a subscriber
    // costs an entry in a writer shard, not a worker or a thread.
    let sse = shared.sse.lock().unwrap().clone();
    if let Some(sse) = sse {
        if req.path == "/api/v1/events" {
            if req.method != "GET" {
                let doc = api::error_envelope(None, "method not allowed");
                let body = doc.to_string_compact().into_bytes();
                return respond(&mut stream, 405, "application/json", &body, "Allow: GET\r\n");
            }
            stream.set_write_timeout(Some(SSE_WRITE_TIMEOUT))?;
            stream.write_all(
                b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
            )?;
            // ?since= (explicit) wins over Last-Event-ID (reconnect); a
            // cursor past anything published cannot be honored (both
            // are client-controlled), so it clamps to "caught up".
            let requested = query_param_u64(&req.query, "since").or(req.last_event_id);
            let cursor = requested.unwrap_or(0).min(sse.feed.last_seq());
            sse.broadcast.adopt(stream, cursor);
            return Ok(());
        }
    }

    // The control-plane API, when enabled, owns every other /api path.
    let api_tx = shared.api_tx.lock().unwrap().clone();
    if let Some(tx) = api_tx {
        if req.path.starts_with("/api/") {
            // Command auth happens here, before anything reaches the
            // engine loop; the read side stays open.
            let token = shared.token.lock().unwrap().clone();
            if req.path == "/api/v1/commands" && req.method == "POST" {
                if let Err(e) = check_bearer(&req, &token) {
                    return respond_json(
                        &mut stream,
                        e.http_status(),
                        &api::error_envelope(None, e.message()),
                    );
                }
            }
            return handle_api(&mut stream, &req, &tx, &shared.state);
        }
    }

    // Static routes are GET-only.
    if req.method != "GET" {
        let body = b"405 method not allowed";
        return respond(&mut stream, 405, "text/plain", body, "Allow: GET\r\n");
    }
    let found = shared.routes.lock().unwrap().get(&req.path).cloned();
    match found {
        Some((ctype, body)) => respond(&mut stream, 200, &ctype, &body, ""),
        None => respond(&mut stream, 404, "text/plain", b"404 not found", ""),
    }
}

/// Enforce `Authorization: Bearer <token>` when a token is configured:
/// missing/malformed credentials → 401, a wrong token → 403.
fn check_bearer(req: &Request, required: &Option<String>) -> Result<(), api::ApiError> {
    let Some(required) = required else {
        return Ok(());
    };
    match req
        .authorization
        .as_deref()
        .and_then(|h| h.strip_prefix("Bearer "))
    {
        None => Err(api::ApiError::Unauthorized(
            "commands require 'Authorization: Bearer <token>' on this server".into(),
        )),
        Some(sent) if sent.trim() == required => Ok(()),
        Some(_) => Err(api::ApiError::Forbidden("bearer token does not match".into())),
    }
}

/// First `name=<u64>` query parameter, if present and parseable.
fn query_param_u64(query: &str, name: &str) -> Option<u64> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == name)
        .and_then(|(_, v)| v.parse().ok())
}

fn handle_api(
    stream: &mut TcpStream,
    req: &Request,
    tx: &mpsc::Sender<ApiRequest>,
    state: &Arc<ReadState>,
) -> std::io::Result<()> {
    let call = match api::parse_route(&req.method, &req.path, &req.query, &req.body) {
        Ok(call) => call,
        Err(RouteError::NotFound) => {
            return respond_json(stream, 404, &api::error_envelope(None, "unknown API path"));
        }
        Err(RouteError::MethodNotAllowed) => {
            let doc = api::error_envelope(None, "method not allowed");
            let body = doc.to_string_compact().into_bytes();
            return respond(stream, 405, "application/json", &body, "Allow: GET, POST\r\n");
        }
        Err(RouteError::BadRequest(msg)) => {
            return respond_json(stream, 400, &api::error_envelope(None, &msg));
        }
        Err(RouteError::Gone(v1)) => {
            // Retired legacy alias: 410 with a machine-readable pointer
            // to the v1 replacement (RFC 8288 successor-version link).
            let doc = api::error_envelope(
                None,
                &format!("this legacy endpoint was removed; use {v1}"),
            );
            let body = doc.to_string_compact().into_bytes();
            let headers = format!("Link: <{v1}>; rel=\"successor-version\"\r\n");
            return respond(stream, 410, "application/json", &body, &headers);
        }
    };
    // Queries try the response cache first: at a fixed generation the
    // whole read path is a lock + Arc clone, no engine round trip.
    let cacheable = matches!(call, ApiCall::Query(_) | ApiCall::QueryAt(..));
    if cacheable {
        if let Some((body, etag)) = state.lookup(&req.path, &req.query) {
            return respond_query(stream, req, &body, &etag, "hit");
        }
    }
    let (reply_tx, reply_rx) = mpsc::channel();
    let sent = tx
        .send(ApiRequest {
            call,
            reply: reply_tx,
        })
        .is_ok();
    let reply = if sent {
        reply_rx.recv_timeout(API_REPLY_TIMEOUT).ok()
    } else {
        None
    };
    match reply {
        Some(reply) => {
            if let (200, Some(stamp)) = (reply.status, reply.stamp.as_ref()) {
                let body = Arc::new(reply.body.to_string_compact().into_bytes());
                let etag = state.store(&req.path, &req.query, stamp, body.clone());
                return respond_query(stream, req, &body, &etag, "miss");
            }
            respond_json(stream, reply.status, &reply.body)
        }
        None => respond_json(
            stream,
            503,
            &api::error_envelope(None, "engine loop is not serving the API"),
        ),
    }
}

/// Answer a cacheable query: `ETag` + `Cache-Control: no-cache` on
/// every response, `X-Cache` reporting hit/miss, and `If-None-Match`
/// short-circuited to a bodyless 304 (no re-render, no copy).
fn respond_query(
    stream: &mut TcpStream,
    req: &Request,
    body: &[u8],
    etag: &str,
    x_cache: &str,
) -> std::io::Result<()> {
    let headers = format!("ETag: {etag}\r\nCache-Control: no-cache\r\nX-Cache: {x_cache}\r\n");
    if if_none_match_matches(req.if_none_match.as_deref(), etag) {
        return respond(stream, 304, "application/json", b"", &headers);
    }
    respond(stream, 200, "application/json", body, &headers)
}

/// `If-None-Match` comparison: `*` matches anything; otherwise compare
/// against each listed entity-tag (the weak prefix is ignored — weak
/// comparison is what 304 revalidation uses).
fn if_none_match_matches(header: Option<&str>, etag: &str) -> bool {
    let Some(header) = header else {
        return false;
    };
    header
        .split(',')
        .map(str::trim)
        .any(|t| t == "*" || t == etag || t.strip_prefix("W/") == Some(etag))
}

fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    doc: &chopt_core::util::json::Value,
) -> std::io::Result<()> {
    let body = doc.to_string_compact().into_bytes();
    respond(stream, status, "application/json", &body, "")
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        304 => "Not Modified",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        410 => "Gone",
        503 => "Service Unavailable",
        _ => "OK",
    }
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &[u8],
    extra_headers: &str,
) -> std::io::Result<()> {
    let mut r = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n{extra_headers}Connection: close\r\n\r\n",
        status_text(status),
        body.len()
    )
    .into_bytes();
    r.extend_from_slice(body);
    stream.write_all(&r)?;
    stream.flush()
}

/// Minimal HTTP client (tests, examples' self-check, smoke scripts).
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    http_request_with_headers(addr, method, path, &[], body)
}

/// [`http_request`] with extra request headers (auth, SSE resume).
pub fn http_request_with_headers(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    let (status, _head, body) = http_request_full(addr, method, path, headers, body)?;
    Ok((status, body))
}

/// [`http_request_with_headers`], also returning the raw response head
/// (status line + headers) so callers can read `ETag`/`X-Cache`.
pub fn http_request_full(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<(u16, String, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    let extra: String = headers
        .iter()
        .map(|(k, v)| format!("{k}: {v}\r\n"))
        .collect();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    let text_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(buf.len());
    let head = String::from_utf8_lossy(&buf[..text_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    Ok((status, head, buf[text_end..].to_vec()))
}

/// Minimal GET client.
pub fn http_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    http_request(addr, "GET", path, b"")
}

/// Minimal POST client (command bodies).
pub fn http_post(
    addr: std::net::SocketAddr,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    http_request(addr, "POST", path, body)
}

/// Embedded single-file viewer: renders the v1 status + parallel queries
/// (unwrapping the versioned envelope) on a canvas.  Redraws are pushed:
/// the viewer subscribes to `GET /api/v1/events` (SSE) and re-renders
/// when progress arrives, with a slow safety-net poll instead of the old
/// 2-second busy poll.
const VIEWER_HTML: &str = r#"<!doctype html>
<html><head><meta charset="utf-8"><title>CHOPT viz</title>
<style>body{font-family:monospace;margin:16px}canvas{border:1px solid #ccc}</style>
</head><body>
<h2>CHOPT — parallel coordinates</h2>
<div>views: <a href="/api/v1/parallel">parallel</a>
 <a href="/api/v1/status">status</a>
 <a href="/api/v1/cluster?window=86400">cluster</a>
 <a href="/api/v1/curves?limit=20">curves</a>
 <a href="/api/v1/events">events (SSE)</a>
 <a href="/svg/parallel.svg">parallel.svg</a></div>
<div id="status"></div>
<canvas id="c" width="1000" height="440"></canvas>
<script>
// v1 responses wrap the document in {schema_version, data}.  The
// legacy /api/*.json fallbacks are gone (the server answers them 410).
const unwrap=j=>j&&j.data!==undefined?j.data:j;
async function getDoc(p){
  try{const r=await fetch(p);if(r.ok)return unwrap(await r.json());}catch(e){}
  return null;
}
async function draw(){
getDoc('/api/v1/status').then(s=>{
  if(s)document.getElementById('status').textContent=
    't='+Math.round(s.t)+'s  events='+s.events_processed+'  best='+(s.best==null?'-':s.best.toFixed(2))+(s.done?'  [done]':'');
});
getDoc('/api/v1/parallel').then(doc=>{
  if(!doc||!doc.axes)return;
  const cv=document.getElementById('c'),g=cv.getContext('2d');
  g.clearRect(0,0,cv.width,cv.height);
  const axes=doc.axes,lines=doc.lines;const m=60,w=cv.width-2*m,h=cv.height-80;
  const x=i=>m+w*i/(axes.length-1);
  const ranges=axes.map(a=>({lo:Infinity,hi:-Infinity}));
  const val=(l,a,i)=>i==axes.length-1?l.measure:(typeof l.values[a.name]==='number'?l.values[a.name]:null);
  lines.forEach(l=>axes.forEach((a,i)=>{const v=val(l,a,i);if(v!=null){ranges[i].lo=Math.min(ranges[i].lo,v);ranges[i].hi=Math.max(ranges[i].hi,v);}}));
  g.strokeStyle='#888';axes.forEach((a,i)=>{g.beginPath();g.moveTo(x(i),40);g.lineTo(x(i),40+h);g.stroke();g.fillText(a.name,x(i)-20,30);});
  g.strokeStyle='rgba(123,79,166,0.45)';
  lines.forEach(l=>{g.beginPath();let started=false;axes.forEach((a,i)=>{
    let v=val(l,a,i);const r=ranges[i];if(v==null||r.hi<=r.lo){v=r.lo||0}
    const y=40+h-(r.hi>r.lo?(v-r.lo)/(r.hi-r.lo):0.5)*h;
    if(!started){g.moveTo(x(i),y);started=true}else{g.lineTo(x(i),y)}});g.stroke();});
}).catch(()=>{});
}
draw();
// Push-driven redraw: progress events (SSE) coalesce into one draw per
// 500ms; polling is only the fallback when EventSource is unavailable
// or the stream endpoint is not served.
let pend=null;const kick=()=>{if(pend)return;pend=setTimeout(()=>{pend=null;draw()},500)};
let pushed=false;
if(window.EventSource){
  const es=new EventSource('/api/v1/events');
  es.onmessage=()=>{pushed=true;kick()};
}
setInterval(()=>{if(!pushed)draw()},2000);
setInterval(draw,30000);
</script></body></html>"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_routes_and_404() {
        let mut routes = Routes::new();
        routes.insert(
            "/api/test.json".into(),
            ("application/json".into(), b"{\"ok\":true}".to_vec()),
        );
        let server = VizServer::start(0, routes).unwrap();
        let addr = server.addr();
        let (status, body) = http_get(addr, "/api/test.json").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        let (status, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(status, 404);
        // Embedded viewer present at /.
        let (status, body) = http_get(addr, "/").unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("parallel coordinates"));
        // Live route update.
        server.put_route("/late", "text/plain", b"hello".to_vec());
        let (status, body) = http_get(addr, "/late").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
        server.stop();
    }

    #[test]
    fn static_routes_reject_non_get() {
        let server = VizServer::start(0, Routes::new()).unwrap();
        let addr = server.addr();
        let (status, _) = http_post(addr, "/", b"{}").unwrap();
        assert_eq!(status, 405, "POST to a static route must be a 405");
        server.stop();
    }

    #[test]
    fn bearer_check_maps_missing_vs_wrong() {
        let req = |auth: Option<&str>| Request {
            method: "POST".into(),
            path: "/api/v1/commands".into(),
            query: String::new(),
            body: Vec::new(),
            authorization: auth.map(|s| s.to_string()),
            last_event_id: None,
            if_none_match: None,
        };
        let token = Some("sekrit".to_string());
        // No token configured: everything passes.
        assert!(check_bearer(&req(None), &None).is_ok());
        // Missing or non-bearer credentials: 401.
        assert_eq!(
            check_bearer(&req(None), &token).unwrap_err().http_status(),
            401
        );
        assert_eq!(
            check_bearer(&req(Some("Basic abc")), &token).unwrap_err().http_status(),
            401
        );
        // Wrong token: 403.  Right token: pass.
        assert_eq!(
            check_bearer(&req(Some("Bearer nope")), &token).unwrap_err().http_status(),
            403
        );
        assert!(check_bearer(&req(Some("Bearer sekrit")), &token).is_ok());
    }

    #[test]
    fn if_none_match_comparison() {
        let etag = "\"abc-7\"";
        assert!(if_none_match_matches(Some("\"abc-7\""), etag));
        assert!(if_none_match_matches(Some("W/\"abc-7\""), etag));
        assert!(if_none_match_matches(Some("\"x\", \"abc-7\""), etag));
        assert!(if_none_match_matches(Some("*"), etag));
        assert!(!if_none_match_matches(Some("\"other\""), etag));
        assert!(!if_none_match_matches(None, etag));
    }

    #[test]
    fn sse_route_rejects_non_get() {
        let server = VizServer::start(0, Routes::new()).unwrap();
        server.serve_events(
            crate::sse::EventFeed::new(8),
            Duration::from_millis(50),
        );
        let (status, _) = http_post(server.addr(), "/api/v1/events", b"").unwrap();
        assert_eq!(status, 405);
        server.stop();
    }

    #[test]
    fn sse_subscribers_share_the_broadcast_pool_and_track_active() {
        let server = VizServer::start(0, Routes::new()).unwrap();
        let feed = crate::sse::EventFeed::new(64);
        feed.publish(r#"{"ev":"x"}"#.into());
        // Two writers, three subscribers: more streams than pool threads.
        server.serve_events_with(feed.clone(), Duration::from_millis(30), 2);
        let addr = server.addr();
        let mut clients = Vec::new();
        for _ in 0..3 {
            let mut s = TcpStream::connect(addr).unwrap();
            write!(s, "GET /api/v1/events HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            s.flush().unwrap();
            s.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            clients.push(s);
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.sse_active() < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.sse_active(), 3, "gauge counts every open stream");
        // Every subscriber gets the retained record, regardless of which
        // shard owns it.
        for s in &mut clients {
            let mut buf = Vec::new();
            let mut chunk = [0u8; 1024];
            while !String::from_utf8_lossy(&buf).contains("id: 1\ndata: ") {
                assert!(Instant::now() < deadline, "no frame: {:?}", String::from_utf8_lossy(&buf));
                match s.read(&mut chunk) {
                    Ok(0) => break,
                    Ok(n) => buf.extend_from_slice(&chunk[..n]),
                    Err(_) => {}
                }
            }
            assert!(String::from_utf8_lossy(&buf).contains("id: 1\ndata: "));
        }
        // Disconnects release their slots; publishes force the writers
        // to notice the dead sockets.
        drop(clients);
        while server.sse_active() > 0 && Instant::now() < deadline {
            feed.publish(r#"{"ev":"y"}"#.into());
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(server.sse_active(), 0, "closed streams must drain the gauge");
        server.stop();
    }

    #[test]
    fn worker_pool_serves_concurrent_connections() {
        // A pool smaller than the burst still completes every request:
        // the queue absorbs what the workers haven't reached yet.
        let mut routes = Routes::new();
        routes.insert("/x".into(), ("text/plain".into(), b"y".to_vec()));
        let server = VizServer::start_with(
            0,
            routes,
            ServerConfig {
                workers: 2,
                queue: 64,
                cache_bytes: 0,
            },
        )
        .unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..16)
            .map(|_| std::thread::spawn(move || http_get(addr, "/x").unwrap()))
            .collect();
        for h in handles {
            let (status, body) = h.join().unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, b"y");
        }
        assert!(server.requests.load(std::sync::atomic::Ordering::Relaxed) >= 16);
        assert_eq!(server.rejected.load(std::sync::atomic::Ordering::Relaxed), 0);
        server.stop();
    }

    #[test]
    fn saturated_queue_sheds_with_503() {
        let mut routes = Routes::new();
        routes.insert("/x".into(), ("text/plain".into(), b"y".to_vec()));
        let server = VizServer::start_with(
            0,
            routes,
            ServerConfig {
                workers: 1,
                queue: 1,
                cache_bytes: 0,
            },
        )
        .unwrap();
        let addr = server.addr();
        // Occupy the lone worker with an idle connection, then fill the
        // one queue slot with another.  The staggered sleeps let the
        // accept loop dispatch each before the next arrives.
        let idle_a = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(200));
        let idle_b = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(200));
        // Third connection: queue full → unsolicited 503 + Retry-After.
        let mut probe = TcpStream::connect(addr).unwrap();
        probe
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = Vec::new();
        let _ = probe.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("503"), "expected a 503, got: {text}");
        assert!(text.contains("Retry-After"), "{text}");
        assert!(text.contains("saturated"), "{text}");
        assert!(server.rejected.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        // Recovery: once the idle connections drain (read timeout or
        // close), normal requests flow again.
        drop(idle_a);
        drop(idle_b);
        let t0 = Instant::now();
        loop {
            if let Ok((200, body)) = http_get(addr, "/x") {
                assert_eq!(body, b"y");
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "server never recovered after shedding"
            );
            std::thread::sleep(Duration::from_millis(100));
        }
        server.stop();
    }

    #[test]
    fn slow_loris_header_is_cut_off() {
        let server = VizServer::start_with(
            0,
            Routes::new(),
            ServerConfig {
                workers: 1,
                queue: 4,
                cache_bytes: 0,
            },
        )
        .unwrap();
        let addr = server.addr();
        // A client that sends a partial request line and stalls: the
        // per-recv timeout must free the worker (connection closed)
        // rather than pinning it, and the server keeps serving others.
        let mut loris = TcpStream::connect(addr).unwrap();
        loris.write_all(b"GET /").unwrap();
        loris
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut buf = Vec::new();
        let t0 = Instant::now();
        let _ = loris.read_to_end(&mut buf); // server closes on timeout
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "stalled client was not cut off"
        );
        let (status, _) = http_get(addr, "/").unwrap();
        assert_eq!(status, 200, "worker must be free after the cut-off");
        server.stop();
    }
}
