//! Server-sent-events push for dashboards: the progress-stream feed
//! behind `GET /api/v1/events`.
//!
//! The viewer used to poll every v1 query on a timer whether anything
//! had happened or not.  The platform now publishes every progress
//! record (the same JSON objects the JSONL event log receives) into an
//! [`EventFeed`] — a bounded, sequence-numbered ring buffer — and a
//! small [`Broadcaster`] writer pool fans the feed out to every open
//! SSE connection:
//!
//! * events are framed as `id: <seq>` + `data: <json>` blocks, so
//!   browsers' `EventSource` reconnect sends `Last-Event-ID` and the
//!   stream resumes after the last record the client saw;
//! * when a stream is idle a comment heartbeat (`: heartbeat`) is
//!   written at the configured cadence, so proxies and clients can tell
//!   "no events" from "dead server";
//! * the buffer is bounded: a slow client that reconnects past the
//!   retention window resumes from the oldest retained record and the
//!   frame notes how many were dropped.
//!
//! The feed is `Sync` (mutex + condvar) while the platform stays
//! single-threaded: publishing is a lock + push from the engine loop,
//! never an I/O wait on a consumer.

use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use chopt_core::util::json::Value as Json;

/// Default retained events for live runs (stored runs retain everything).
pub const DEFAULT_FEED_CAPACITY: usize = 65_536;

struct FeedInner {
    /// (sequence, serialized JSON line) — sequences start at 1 and never
    /// repeat; the front is the oldest retained record.
    events: VecDeque<(u64, String)>,
    next_seq: u64,
    /// Records evicted by the capacity bound over the feed's lifetime.
    dropped: u64,
}

/// Optional on-disk mirror of the feed: every published record appended
/// as one JSONL line *while the ring lock is held*, so line `k` of the
/// file is exactly sequence `k`.  This is what lets `?since=<seq>` (and
/// a `Last-Event-ID` resume that fell behind the window) replay records
/// the bounded ring already evicted.
struct HistoryLog {
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

/// The progress-event ring buffer SSE connections tail.
pub struct EventFeed {
    inner: Mutex<FeedInner>,
    cv: Condvar,
    capacity: usize,
    history: Option<HistoryLog>,
}

impl EventFeed {
    /// A feed retaining at most `capacity` records (older ones are
    /// evicted; reconnecting clients see the drop count).
    pub fn new(capacity: usize) -> Arc<EventFeed> {
        EventFeed::build(capacity, None)
    }

    /// A feed that also mirrors every record to a JSONL history log at
    /// `path` (truncated — feed sequences restart at 1 with the feed).
    /// SSE connections use it to serve `?since=` below the ring's
    /// retention window.
    pub fn with_history(
        capacity: usize,
        path: impl AsRef<Path>,
    ) -> std::io::Result<Arc<EventFeed>> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = std::fs::File::create(&path)?;
        Ok(EventFeed::build(
            capacity,
            Some(HistoryLog {
                path,
                file: Mutex::new(file),
            }),
        ))
    }

    fn build(capacity: usize, history: Option<HistoryLog>) -> Arc<EventFeed> {
        Arc::new(EventFeed {
            inner: Mutex::new(FeedInner {
                events: VecDeque::new(),
                next_seq: 1,
                dropped: 0,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
            history,
        })
    }

    /// Path of the history log, when one is attached.
    pub fn history_path(&self) -> Option<&Path> {
        self.history.as_ref().map(|h| h.path.as_path())
    }

    /// Publish one already-serialized JSON record; returns its sequence.
    pub fn publish(&self, line: String) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if let Some(h) = &self.history {
            // Written under the ring lock so line k == seq k.  A failed
            // write (disk full) degrades ?since= to the drop notice;
            // publishing itself never fails.
            let mut f = h.file.lock().unwrap();
            let _ = f.write_all(line.as_bytes());
            let _ = f.write_all(b"\n");
        }
        inner.events.push_back((seq, line));
        while inner.events.len() > self.capacity {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        drop(inner);
        self.cv.notify_all();
        seq
    }

    /// Replay records from the history log with sequence in
    /// `(after, oldest-retained)` — the gap the ring has already
    /// evicted.  At most `cap` records per call: callers loop,
    /// interleaving writes, instead of buffering an unbounded backlog.
    /// `None` when the feed has no history log attached.  Only fully
    /// written lines below the ring's oldest record are returned, so a
    /// concurrent publish can never surface a torn line.
    pub fn history_after(&self, after: u64, cap: usize) -> Option<Vec<(u64, String)>> {
        let history = self.history.as_ref()?;
        let oldest = {
            let inner = self.inner.lock().unwrap();
            inner.events.front().map(|&(s, _)| s).unwrap_or(inner.next_seq)
        };
        if after.saturating_add(1) >= oldest {
            return Some(Vec::new());
        }
        let file = match std::fs::File::open(&history.path) {
            Ok(f) => f,
            Err(_) => return Some(Vec::new()),
        };
        let mut out = Vec::new();
        let mut seq = 0u64;
        for line in std::io::BufReader::new(file).lines() {
            let Ok(line) = line else { break };
            seq += 1;
            if seq <= after {
                continue;
            }
            if seq >= oldest || out.len() >= cap {
                break;
            }
            out.push((seq, line));
        }
        Some(out)
    }

    /// Publish a JSON document (compact form — same bytes as the JSONL
    /// event log).
    pub fn publish_json(&self, doc: &Json) -> u64 {
        self.publish(doc.to_string_compact())
    }

    /// Sequence of the most recent record (0 = nothing published yet).
    pub fn last_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq - 1
    }

    /// Shared core of [`EventFeed::read_after`] / [`EventFeed::wait_after`]:
    /// records with sequence > `after` that are still retained, plus how
    /// many the cursor missed to eviction.  Saturating arithmetic —
    /// `after` arrives from the client-controlled `Last-Event-ID`
    /// header, so `u64::MAX` must not overflow (it simply sees nothing
    /// new and no drops).
    fn collect_after(inner: &FeedInner, after: u64) -> (u64, Vec<(u64, String)>) {
        let oldest = inner.events.front().map(|&(s, _)| s).unwrap_or(inner.next_seq);
        let missed = oldest.saturating_sub(after.saturating_add(1));
        let out = inner
            .events
            .iter()
            .filter(|&&(s, _)| s > after)
            .cloned()
            .collect();
        (missed, out)
    }

    /// Records with sequence > `after` that are still retained, plus how
    /// many the client missed to eviction (non-zero only when `after`
    /// fell behind the retention window).
    pub fn read_after(&self, after: u64) -> (u64, Vec<(u64, String)>) {
        EventFeed::collect_after(&self.inner.lock().unwrap(), after)
    }

    /// Like [`EventFeed::read_after`], but blocks up to `timeout` for at
    /// least one fresh record.  An empty result means the timeout passed
    /// with nothing new — the caller's heartbeat moment.
    pub fn wait_after(&self, after: u64, timeout: Duration) -> (u64, Vec<(u64, String)>) {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            // Cheap emptiness check before scanning the ring.
            if inner.next_seq > after.saturating_add(1) {
                let (missed, out) = EventFeed::collect_after(&inner, after);
                if !out.is_empty() || missed > 0 {
                    return (missed, out);
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return (0, Vec::new());
            }
            let (guard, _) = self.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }
}

/// Writer threads in the default broadcast pool (see [`Broadcaster`]).
pub const DEFAULT_BROADCAST_WRITERS: usize = 4;

/// Records per history-backfill batch written in one sweep: bounds the
/// memory a far-behind `?since=` subscriber can pin per iteration (the
/// next sweep continues from its advanced cursor).
const HISTORY_CHUNK: usize = 1024;

/// Upper bound on one broadcast wait slice: new subscribers are adopted
/// and the stop flag observed within this latency even when the feed is
/// idle and the heartbeat cadence is long.
const BROADCAST_SLICE: Duration = Duration::from_millis(50);

/// One SSE subscriber owned by the broadcast pool.
struct Subscriber<W> {
    sink: W,
    /// Sequence of the last record written to this sink.
    cursor: u64,
    /// When the sink last received bytes (heartbeat bookkeeping).
    last_write: Instant,
}

/// A writer thread's adoption inbox; the thread itself owns its share
/// of the subscribers.
struct Shard<W> {
    inbox: Mutex<Vec<Subscriber<W>>>,
    cv: Condvar,
}

/// A small fixed pool of writer threads fanning one [`EventFeed`] out
/// to every SSE subscriber.
///
/// The server used to spawn one long-lived tailing thread per
/// subscriber; under thousands of open streams that is thousands of
/// parked threads.  The broadcaster instead keeps a handful of writer
/// threads, each owning a shard of the subscribers: one
/// [`EventFeed::wait_after`] per shard wakes on fresh records, and the
/// writer sweeps its shard, framing each subscriber's batch from that
/// subscriber's own cursor — `Last-Event-ID` resume, `?since=` history
/// backfill, and drop notices behave exactly as the per-thread tailers
/// did.  Heartbeats stay per-subscriber at the configured cadence.  A
/// stalled sink blocks only its shard, and only up to the sink's write
/// timeout, after which it is dropped.
pub struct Broadcaster<W: Write + Send + 'static> {
    feed: Arc<EventFeed>,
    shards: Vec<Arc<Shard<W>>>,
    /// Round-robin adoption counter.
    next: AtomicUsize,
    /// Currently owned subscribers — the server's `sse_active` gauge.
    active: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
}

impl<W: Write + Send + 'static> Broadcaster<W> {
    /// Start `writers` detached writer threads tailing `feed`.  The
    /// threads exit once `stop` is set (observed within one wait
    /// slice); `active` is incremented per adopted subscriber and
    /// decremented when one is dropped, so it always reads as
    /// "currently open streams".
    pub fn start(
        feed: Arc<EventFeed>,
        heartbeat: Duration,
        writers: usize,
        stop: Arc<AtomicBool>,
        active: Arc<AtomicU64>,
    ) -> Arc<Broadcaster<W>> {
        let heartbeat = heartbeat.max(Duration::from_millis(10));
        let mut shards = Vec::with_capacity(writers.max(1));
        for i in 0..writers.max(1) {
            let shard = Arc::new(Shard {
                inbox: Mutex::new(Vec::new()),
                cv: Condvar::new(),
            });
            let (feed_i, stop_i, active_i, shard_i) =
                (feed.clone(), stop.clone(), active.clone(), shard.clone());
            let spawned = std::thread::Builder::new()
                .name(format!("viz-sse-{i}"))
                .spawn(move || writer_loop(&feed_i, heartbeat, &shard_i, &stop_i, &active_i));
            // A shard only joins the pool with a live writer behind it;
            // thread exhaustion shrinks the pool instead of stranding
            // subscribers in an inbox nobody drains.
            if spawned.is_ok() {
                shards.push(shard);
            }
        }
        Arc::new(Broadcaster {
            feed,
            shards,
            next: AtomicUsize::new(0),
            active,
            stop,
        })
    }

    /// The feed this pool broadcasts.
    pub fn feed(&self) -> &Arc<EventFeed> {
        &self.feed
    }

    /// Hand one subscriber to the pool, resuming after `cursor` (0 =
    /// from the start of retention).  The sink's HTTP/SSE response head
    /// must already be written and its write timeout configured.  With
    /// no live writers (thread exhaustion at start) or a stopped pool
    /// the sink is simply dropped, closing the connection.
    pub fn adopt(&self, sink: W, cursor: u64) {
        if self.shards.is_empty() || self.stop.load(Ordering::Relaxed) {
            return;
        }
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.active.fetch_add(1, Ordering::Relaxed);
        let shard = &self.shards[i];
        shard.inbox.lock().unwrap().push(Subscriber {
            sink,
            cursor,
            last_write: Instant::now(),
        });
        shard.cv.notify_one();
    }
}

/// One shard's writer: adopt pending subscribers, wait (bounded) for
/// the feed to move past the furthest-behind cursor, then sweep every
/// subscriber.  Dead sinks are dropped and decrement the gauge; on stop
/// the remaining subscribers are released the same way.
fn writer_loop<W: Write>(
    feed: &EventFeed,
    heartbeat: Duration,
    shard: &Shard<W>,
    stop: &AtomicBool,
    active: &AtomicU64,
) {
    let mut subs: Vec<Subscriber<W>> = Vec::new();
    let slice = heartbeat.min(BROADCAST_SLICE);
    loop {
        {
            let mut inbox = shard.inbox.lock().unwrap();
            if subs.is_empty() && inbox.is_empty() && !stop.load(Ordering::Relaxed) {
                // Nothing to tail: the inbox condvar is the only event
                // worth waking for, and `adopt` signals it.
                let (guard, _) = shard.cv.wait_timeout(inbox, slice).unwrap();
                inbox = guard;
            }
            subs.append(&mut inbox);
        }
        if stop.load(Ordering::Relaxed) {
            active.fetch_sub(subs.len() as u64, Ordering::Relaxed);
            return;
        }
        if subs.is_empty() {
            continue;
        }
        // One bounded wait for the whole shard, keyed on the furthest-
        // behind cursor so a backfilling subscriber never stalls the
        // sweep; the slice cap keeps adoption and stop latency low.
        let min_cursor = subs.iter().map(|s| s.cursor).min().unwrap_or(0);
        let _ = feed.wait_after(min_cursor, slice);
        subs.retain_mut(|sub| match sweep_one(feed, heartbeat, sub) {
            Ok(()) => true,
            Err(_) => {
                // Disconnected (or write-timed-out): release the slot.
                active.fetch_sub(1, Ordering::Relaxed);
                false
            }
        });
    }
}

/// Write everything one subscriber is owed right now: a history
/// backfill batch when its cursor fell below the ring's retention
/// window (or the drop notice when no history log is attached), any
/// fresh ring records, or a heartbeat once idle past the cadence.
/// `Err` means the sink is gone and the subscriber must be dropped.
fn sweep_one<W: Write>(
    feed: &EventFeed,
    heartbeat: Duration,
    sub: &mut Subscriber<W>,
) -> std::io::Result<()> {
    let (missed, batch) = feed.read_after(sub.cursor);
    if missed > 0 {
        // The ring evicted part of the requested window.  Replay the
        // gap from the history log in bounded batches (the next sweep
        // continues from the advanced cursor), or say what was lost
        // instead of silently skipping it.
        match feed.history_after(sub.cursor, HISTORY_CHUNK) {
            Some(hist) if !hist.is_empty() => {
                let mut out = String::new();
                for (seq, line) in &hist {
                    out.push_str(&format!("id: {seq}\ndata: {line}\n\n"));
                    sub.cursor = *seq;
                }
                sub.sink.write_all(out.as_bytes())?;
                sub.sink.flush()?;
                sub.last_write = Instant::now();
                return Ok(());
            }
            _ => {
                sub.sink
                    .write_all(format!(": resumed past {missed} dropped events\n\n").as_bytes())?;
            }
        }
    }
    if batch.is_empty() {
        if sub.last_write.elapsed() >= heartbeat {
            sub.sink.write_all(b": heartbeat\n\n")?;
            sub.sink.flush()?;
            sub.last_write = Instant::now();
        }
        return Ok(());
    }
    let mut out = String::new();
    for (seq, line) in &batch {
        out.push_str(&format!("id: {seq}\ndata: {line}\n\n"));
        sub.cursor = *seq;
    }
    sub.sink.write_all(out.as_bytes())?;
    sub.sink.flush()?;
    sub.last_write = Instant::now();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_and_reads_are_ordered() {
        let feed = EventFeed::new(16);
        assert_eq!(feed.last_seq(), 0);
        assert_eq!(feed.publish("a".into()), 1);
        assert_eq!(feed.publish("b".into()), 2);
        let (missed, got) = feed.read_after(0);
        assert_eq!(missed, 0);
        assert_eq!(got, vec![(1, "a".to_string()), (2, "b".to_string())]);
        let (_, tail) = feed.read_after(1);
        assert_eq!(tail, vec![(2, "b".to_string())]);
        assert!(feed.read_after(2).1.is_empty());
    }

    #[test]
    fn capacity_evicts_and_reports_missed() {
        let feed = EventFeed::new(2);
        for s in ["a", "b", "c", "d"] {
            feed.publish(s.into());
        }
        // Only 3 and 4 retained; a client resuming after 1 missed one.
        let (missed, got) = feed.read_after(1);
        assert_eq!(missed, 1);
        assert_eq!(got.first().map(|&(s, _)| s), Some(3));
        assert_eq!(feed.last_seq(), 4);
        // A future/huge cursor (client-controlled Last-Event-ID) must
        // not overflow or mis-report drops — it just sees nothing new.
        let (missed, got) = feed.read_after(u64::MAX);
        assert_eq!((missed, got.len()), (0, 0));
        assert!(feed.wait_after(u64::MAX, Duration::from_millis(5)).1.is_empty());
    }

    #[test]
    fn history_log_replays_evicted_records() {
        let dir = std::env::temp_dir().join(format!("chopt-sse-hist-{}", std::process::id()));
        let path = dir.join("events.jsonl");
        let feed = EventFeed::with_history(2, &path).unwrap();
        assert_eq!(feed.history_path(), Some(path.as_path()));
        for s in ["a", "b", "c", "d", "e"] {
            feed.publish(s.into());
        }
        // Ring retains 4..5; the ring alone reports 3 missed from 0.
        let (missed, got) = feed.read_after(0);
        assert_eq!(missed, 3);
        assert_eq!(got.first().map(|&(s, _)| s), Some(4));
        // The history log covers the evicted gap exactly: (after, oldest).
        assert_eq!(
            feed.history_after(0, 100).unwrap(),
            vec![(1, "a".to_string()), (2, "b".to_string()), (3, "c".to_string())]
        );
        // The cap bounds each batch; the cursor loop picks up the rest.
        assert_eq!(feed.history_after(0, 1).unwrap(), vec![(1, "a".to_string())]);
        assert_eq!(feed.history_after(1, 1).unwrap(), vec![(2, "b".to_string())]);
        // At or past the ring's oldest record: nothing from history.
        assert!(feed.history_after(3, 100).unwrap().is_empty());
        assert!(feed.history_after(u64::MAX, 100).unwrap().is_empty());
        // Feeds without history report None (callers fall back to the
        // drop notice).
        assert!(EventFeed::new(2).history_after(0, 10).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_blocks_until_publish_or_timeout() {
        let feed = EventFeed::new(8);
        // Timeout path: nothing published.
        let t0 = Instant::now();
        let (_, got) = feed.wait_after(0, Duration::from_millis(30));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // Wake path: a publish from another thread releases the wait.
        let f2 = feed.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f2.publish("x".into());
        });
        let (_, got) = feed.wait_after(0, Duration::from_secs(5));
        assert_eq!(got.len(), 1);
        h.join().unwrap();
    }

    /// Shared-buffer sink for broadcast tests.
    #[derive(Clone)]
    struct MemSink(Arc<Mutex<Vec<u8>>>);

    impl MemSink {
        fn new() -> MemSink {
            MemSink(Arc::new(Mutex::new(Vec::new())))
        }

        fn text(&self) -> String {
            String::from_utf8_lossy(&self.0.lock().unwrap()).to_string()
        }
    }

    impl Write for MemSink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// A sink whose client hung up: every write fails.
    struct DeadSink;

    impl Write for DeadSink {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"))
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"))
        }
    }

    fn wait_until(deadline: Duration, mut ok: impl FnMut() -> bool) -> bool {
        let end = Instant::now() + deadline;
        while Instant::now() < end {
            if ok() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        ok()
    }

    enum Sink {
        Mem(MemSink),
        Dead(DeadSink),
    }

    impl Write for Sink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            match self {
                Sink::Mem(m) => m.write(buf),
                Sink::Dead(d) => d.write(buf),
            }
        }

        fn flush(&mut self) -> std::io::Result<()> {
            match self {
                Sink::Mem(m) => m.flush(),
                Sink::Dead(d) => d.flush(),
            }
        }
    }

    #[test]
    fn broadcast_pool_fans_out_resumes_and_tracks_active() {
        let feed = EventFeed::new(64);
        feed.publish("a".into());
        feed.publish("b".into());
        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicU64::new(0));
        let pool: Arc<Broadcaster<Sink>> = Broadcaster::start(
            feed.clone(),
            Duration::from_millis(20),
            2,
            stop.clone(),
            active.clone(),
        );
        assert_eq!(pool.feed().last_seq(), 2);

        // Two subscribers at different cursors: each gets its own window.
        let fresh = MemSink::new();
        let resumed = MemSink::new();
        pool.adopt(Sink::Mem(fresh.clone()), 0);
        pool.adopt(Sink::Mem(resumed.clone()), 1);
        assert_eq!(active.load(Ordering::Relaxed), 2, "gauge counts open streams");
        assert!(
            wait_until(Duration::from_secs(5), || {
                fresh.text().contains("id: 2\ndata: b")
                    && resumed.text().contains("id: 2\ndata: b")
            }),
            "fresh: {:?} resumed: {:?}",
            fresh.text(),
            resumed.text()
        );
        assert!(fresh.text().contains("id: 1\ndata: a"), "{}", fresh.text());
        assert!(
            !resumed.text().contains("id: 1\ndata: a"),
            "a resumed stream must not replay its cursor: {}",
            resumed.text()
        );

        // A record published after adoption is pushed to both, and an
        // idle stream heartbeats at the cadence.
        feed.publish("c".into());
        assert!(
            wait_until(Duration::from_secs(5), || {
                [&fresh, &resumed].iter().all(|s| {
                    let t = s.text();
                    t.contains("id: 3\ndata: c") && t.contains(": heartbeat")
                })
            }),
            "fresh: {:?} resumed: {:?}",
            fresh.text(),
            resumed.text()
        );

        // A dead sink is dropped on its first sweep and releases the slot.
        pool.adopt(Sink::Dead(DeadSink), 0);
        assert!(
            wait_until(Duration::from_secs(5), || active.load(Ordering::Relaxed) == 2),
            "dead subscriber must decrement the gauge (active={})",
            active.load(Ordering::Relaxed)
        );

        // Stop releases the survivors; the gauge drains to zero.
        stop.store(true, Ordering::Relaxed);
        assert!(
            wait_until(Duration::from_secs(5), || active.load(Ordering::Relaxed) == 0),
            "stop must release every subscriber (active={})",
            active.load(Ordering::Relaxed)
        );
        // A post-stop adoption is refused outright.
        pool.adopt(Sink::Mem(MemSink::new()), 0);
        assert_eq!(active.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn broadcast_pool_backfills_evicted_gap_from_history() {
        let dir = std::env::temp_dir().join(format!("chopt-sse-pool-{}", std::process::id()));
        let feed = EventFeed::with_history(2, dir.join("events.jsonl")).unwrap();
        for s in ["a", "b", "c", "d"] {
            feed.publish(s.into());
        }
        // Ring retains 3..4; a from-zero subscriber needs 1..2 from disk.
        let stop = Arc::new(AtomicBool::new(false));
        let pool: Arc<Broadcaster<MemSink>> = Broadcaster::start(
            feed.clone(),
            Duration::from_millis(20),
            1,
            stop.clone(),
            Arc::new(AtomicU64::new(0)),
        );
        let sink = MemSink::new();
        pool.adopt(sink.clone(), 0);
        assert!(
            wait_until(Duration::from_secs(5), || sink.text().contains("id: 4\ndata: d")),
            "{}",
            sink.text()
        );
        let text = sink.text();
        for frame in ["id: 1\ndata: a", "id: 2\ndata: b", "id: 3\ndata: c"] {
            assert!(text.contains(frame), "missing {frame:?} in {text}");
        }
        assert!(
            !text.contains("dropped events"),
            "history-backed resume must not report drops: {text}"
        );
        stop.store(true, Ordering::Relaxed);
        std::fs::remove_dir_all(&dir).ok();
    }
}
