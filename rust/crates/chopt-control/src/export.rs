//! Session results → JSON export (the contract between the coordinator
//! and any front end; the embedded HTML viewer consumes exactly this).

use chopt_core::config::Order;
use chopt_core::hparam::Space;
use chopt_core::nsml::NsmlSession;
use chopt_core::util::json::Value as Json;

/// Axes + lines document for parallel coordinates (Fig. 3):
/// every axis is a hyperparameter (plus the measure as the last axis);
/// every line is one NSML session.
pub fn parallel_coords_doc(
    space: &Space,
    sessions: &[NsmlSession],
    order: Order,
    run_label: &str,
) -> Json {
    let refs: Vec<&NsmlSession> = sessions.iter().collect();
    parallel_coords_doc_refs(space, &refs, order, run_label)
}

/// Reference-taking core of [`parallel_coords_doc`] — the live publish
/// loop renders 10k+ sessions per refresh and must not clone them first.
pub fn parallel_coords_doc_refs(
    space: &Space,
    sessions: &[&NsmlSession],
    order: Order,
    run_label: &str,
) -> Json {
    let mut axes: Vec<Json> = space
        .defs
        .iter()
        .map(|d| {
            Json::obj()
                .with("name", Json::Str(d.name.clone()))
                .with("type", Json::Str(d.ptype.name().to_string()))
                .with("distribution", Json::Str(d.dist.name().to_string()))
        })
        .collect();
    axes.push(
        Json::obj()
            .with("name", Json::Str("measure".into()))
            .with("type", Json::Str("float".into()))
            .with("distribution", Json::Str("uniform".into())),
    );

    let lines: Vec<Json> = sessions
        .iter()
        .map(|s| {
            let mut values = Json::obj();
            for (k, v) in s.hparams.iter() {
                values.set(k, v.to_json());
            }
            Json::obj()
                // Session ids are strings: they pack (chopt_id << 32 |
                // counter) into a u64, which an f64 corrupts past 2^53.
                .with("id", Json::Str(s.id.0.to_string()))
                .with("values", values)
                .with(
                    "measure",
                    s.best_measure(order).map(Json::Num).unwrap_or(Json::Null),
                )
                .with("status", Json::Str(s.status.name().to_string()))
                .with("epochs", Json::Num(s.epochs as f64))
        })
        .collect();

    Json::obj()
        .with("label", Json::Str(run_label.to_string()))
        .with("axes", Json::Arr(axes))
        .with("lines", Json::Arr(lines))
}

/// Scalar-plot view: loss/measure curves per session ("Scalar plot view").
pub fn curves_doc(sessions: &[NsmlSession]) -> Json {
    let refs: Vec<&NsmlSession> = sessions.iter().collect();
    curves_doc_refs(&refs)
}

/// Reference-taking core of [`curves_doc`] — the `/api/v1/curves` query
/// renders straight from borrowed sessions (no clones per request).
pub fn curves_doc_refs(sessions: &[&NsmlSession]) -> Json {
    let curves: Vec<Json> = sessions
        .iter()
        .map(|s| {
            Json::obj()
                .with("id", Json::Str(s.id.0.to_string()))
                .with(
                    "epochs",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.epoch as f64)).collect()),
                )
                .with(
                    "measure",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.measure)).collect()),
                )
                .with(
                    "loss",
                    Json::Arr(s.history.iter().map(|p| Json::Num(p.loss)).collect()),
                )
        })
        .collect();
    Json::obj().with("curves", Json::Arr(curves))
}

/// Model summary table rows ("Model summary view"): precise values of the
/// selected sessions.
pub fn summary_doc(sessions: &[&NsmlSession], order: Order) -> Json {
    let rows: Vec<Json> = sessions
        .iter()
        .map(|s| {
            Json::obj()
                .with("id", Json::Str(s.id.0.to_string()))
                .with("hparams", s.hparams.to_json())
                .with(
                    "best",
                    s.best_measure(order).map(Json::Num).unwrap_or(Json::Null),
                )
                .with("epochs", Json::Num(s.epochs as f64))
                .with("revivals", Json::Num(s.revivals as f64))
                .with("gpu_seconds", Json::Num(s.gpu_seconds))
        })
        .collect();
    Json::obj().with("rows", Json::Arr(rows))
}

/// Live cluster-utilization document (Fig. 8 as a stream): the per-tenant
/// usage change-points plus the instantaneous holdings at `now`.  The
/// `serve --live` viewer polls this as the engine advances.
pub fn cluster_doc(cluster: &chopt_cluster::Cluster, now: f64) -> Json {
    cluster_doc_windowed(cluster, now, None)
}

/// [`cluster_doc`] with an optional history window (`?window=` on the v1
/// cluster query): only change-points within the last `window` virtual
/// seconds are serialized, plus one carried point *before* the cut so the
/// level at the window start is correct.  A long live run's unbounded
/// series no longer has to be re-serialized whole on every refresh.
pub fn cluster_doc_windowed(
    cluster: &chopt_cluster::Cluster,
    now: f64,
    window: Option<f64>,
) -> Json {
    let cut = window.map(|w| now - w.max(0.0));
    let series = |ti: &chopt_core::events::TimeIntegrator| {
        let pts = &ti.series;
        let start = match cut {
            // First change-point inside the window, minus one so the
            // pre-window level is carried across the cut.
            Some(c) => pts.partition_point(|&(t, _)| t < c).saturating_sub(1),
            None => 0,
        };
        Json::Arr(
            pts[start..]
                .iter()
                .map(|&(t, v)| Json::Arr(vec![Json::Num(t), Json::Num(v)]))
                .collect(),
        )
    };
    Json::obj()
        .with("t", Json::Num(now))
        .with("total_gpus", Json::Num(cluster.total() as f64))
        .with("used", Json::Num(cluster.used() as f64))
        .with("chopt_held", Json::Num(cluster.held_by_chopt() as f64))
        .with("utilization", Json::Num(cluster.utilization()))
        .with("chopt_gpu_hours", Json::Num(cluster.chopt_gpu_hours(now)))
        .with("window", window.map(Json::Num).unwrap_or(Json::Null))
        .with("series_total", series(&cluster.usage_total))
        .with("series_chopt", series(&cluster.usage_chopt))
        .with("series_external", series(&cluster.usage_external))
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::config::ChoptConfig;
    use chopt_core::hparam::{Assignment, Value};
    use chopt_core::nsml::SessionId;

    fn sessions() -> Vec<NsmlSession> {
        (0..3)
            .map(|i| {
                let mut hp = Assignment::new();
                hp.set("lr", Value::Float(0.01 * (i + 1) as f64));
                let mut s = NsmlSession::new(SessionId(i), hp, "m", 0.0);
                s.report(1, 50.0 + i as f64, 2.0);
                s.report(2, 55.0 + i as f64, 1.5);
                s
            })
            .collect()
    }

    #[test]
    fn parallel_doc_shape() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let doc = parallel_coords_doc(&cfg.space, &sessions(), Order::Descending, "run-1");
        let axes = doc.get("axes").unwrap().as_arr().unwrap();
        assert_eq!(axes.len(), cfg.space.defs.len() + 1);
        assert_eq!(
            axes.last().unwrap().get("name").unwrap().as_str(),
            Some("measure")
        );
        let lines = doc.get("lines").unwrap().as_arr().unwrap();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].get("measure").unwrap().as_f64(), Some(57.0));
        // Ids are strings (u64 through f64 corrupts past 2^53).
        assert_eq!(lines[1].get("id").unwrap().as_str(), Some("1"));
    }

    /// Regression for the export-format debt: a session id above 2^53
    /// survives every export document byte-exactly.
    #[test]
    fn export_docs_keep_ids_as_strings_past_f64_precision() {
        let big = (1u64 << 54) + 1;
        let mut s = NsmlSession::new(SessionId(big), Assignment::new(), "m", 0.0);
        s.report(1, 50.0, 2.0);
        let sessions = vec![s];
        let refs: Vec<&NsmlSession> = sessions.iter().collect();
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let expect = big.to_string();
        for doc in [
            parallel_coords_doc(&cfg.space, &sessions, Order::Descending, "x")
                .get("lines")
                .unwrap()
                .idx(0)
                .unwrap()
                .clone(),
            curves_doc(&sessions).get("curves").unwrap().idx(0).unwrap().clone(),
            summary_doc(&refs, Order::Descending)
                .get("rows")
                .unwrap()
                .idx(0)
                .unwrap()
                .clone(),
        ] {
            let text = doc.to_string_compact();
            let back = chopt_core::util::json::parse(&text).unwrap();
            assert_eq!(back.get("id").and_then(|v| v.as_str()), Some(expect.as_str()));
        }
    }

    #[test]
    fn curves_doc_shape() {
        let doc = curves_doc(&sessions());
        let c = doc.get("curves").unwrap().idx(0).unwrap();
        assert_eq!(c.get("epochs").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(c.get("loss").unwrap().idx(1).unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn summary_doc_shape() {
        let ss = sessions();
        let refs: Vec<&NsmlSession> = ss.iter().collect();
        let doc = summary_doc(&refs, Order::Descending);
        assert_eq!(doc.get("rows").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn cluster_doc_shape() {
        use chopt_cluster::{Cluster, Owner};
        let mut c = Cluster::new(8);
        c.allocate(Owner::Chopt(1), 3, 0.0).unwrap();
        c.allocate(Owner::External, 2, 10.0).unwrap();
        let doc = cluster_doc(&c, 20.0);
        assert_eq!(doc.get("total_gpus").unwrap().as_i64(), Some(8));
        assert_eq!(doc.get("used").unwrap().as_i64(), Some(5));
        assert_eq!(doc.get("chopt_held").unwrap().as_i64(), Some(3));
        assert!(doc.get("chopt_gpu_hours").unwrap().as_f64().unwrap() > 0.0);
        assert!(!doc.get("series_chopt").unwrap().as_arr().unwrap().is_empty());
        assert!(doc.get("window").unwrap().is_null());
    }

    #[test]
    fn cluster_doc_window_caps_series_and_carries_the_cut_level() {
        use chopt_cluster::{Cluster, Owner};
        let mut c = Cluster::new(8);
        // Change-points at t = 0, 10, 20, 30.
        c.allocate(Owner::Chopt(1), 1, 0.0).unwrap();
        c.allocate(Owner::Chopt(1), 1, 10.0).unwrap();
        c.allocate(Owner::Chopt(1), 1, 20.0).unwrap();
        c.allocate(Owner::Chopt(1), 1, 30.0).unwrap();
        // Window [25, 40]: the t=30 point plus the carried t=20 level.
        let doc = cluster_doc_windowed(&c, 40.0, Some(15.0));
        let series = doc.get("series_chopt").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].idx(0).unwrap().as_f64(), Some(20.0));
        assert_eq!(series[1].idx(0).unwrap().as_f64(), Some(30.0));
        assert_eq!(doc.get("window").unwrap().as_f64(), Some(15.0));
        // Integral-bearing scalars are unaffected by the window.
        assert_eq!(
            doc.get("chopt_gpu_hours").unwrap().as_f64(),
            cluster_doc(&c, 40.0).get("chopt_gpu_hours").unwrap().as_f64()
        );
        // A window wider than the run returns the whole series.
        let all = cluster_doc_windowed(&c, 40.0, Some(1e9));
        assert_eq!(all.get("series_chopt").unwrap().as_arr().unwrap().len(), 4);
    }
}
