//! Parallel-coordinates SVG renderer (Fig. 3 / Fig. 7).
//!
//! Each hyperparameter is a vertical axis (log scale for log-uniform
//! parameters, category slots for categoricals); the last axis is the
//! measure.  One polyline per session, colored by run; top-K sessions can
//! be highlighted (Fig. 4's masking).

use std::collections::HashSet;

use chopt_core::config::Order;
use chopt_core::hparam::Space;
use chopt_core::nsml::{NsmlSession, SessionId};

use crate::svg::{color, Svg};

const MARGIN: f64 = 50.0;
const WIDTH_PER_AXIS: f64 = 130.0;
const HEIGHT: f64 = 420.0;

/// One run (color group) of sessions.
pub struct RunGroup<'a> {
    pub label: &'a str,
    pub sessions: &'a [NsmlSession],
}

/// Render several runs over the union space (merged-session view).
pub fn render(
    space: &Space,
    runs: &[RunGroup<'_>],
    order: Order,
    highlight: &HashSet<SessionId>,
) -> Svg {
    let n_axes = space.defs.len() + 1;
    let width = MARGIN * 2.0 + WIDTH_PER_AXIS * (n_axes.max(2) - 1) as f64;
    let mut svg = Svg::new(width, HEIGHT);
    let x_of = |axis: usize| MARGIN + WIDTH_PER_AXIS * axis as f64;
    let y_top = 40.0;
    let y_bottom = HEIGHT - 40.0;

    // Measure range across all runs.
    let mut m_lo = f64::INFINITY;
    let mut m_hi = f64::NEG_INFINITY;
    for run in runs {
        for s in run.sessions {
            if let Some(m) = s.best_measure(order) {
                m_lo = m_lo.min(m);
                m_hi = m_hi.max(m);
            }
        }
    }
    if m_lo > m_hi {
        m_lo = 0.0;
        m_hi = 1.0;
    }
    if (m_hi - m_lo).abs() < 1e-12 {
        m_hi = m_lo + 1.0;
    }

    // Axes.
    for (i, d) in space.defs.iter().enumerate() {
        svg.line(x_of(i), y_top, x_of(i), y_bottom, "#888", 1.0);
        svg.text(x_of(i) - 20.0, y_top - 12.0, 11.0, &d.name);
    }
    let mx = x_of(space.defs.len());
    svg.line(mx, y_top, mx, y_bottom, "#444", 1.5);
    svg.text(mx - 25.0, y_top - 12.0, 11.0, "measure");
    svg.text(mx + 4.0, y_bottom, 9.0, &format!("{m_lo:.2}"));
    svg.text(mx + 4.0, y_top + 6.0, 9.0, &format!("{m_hi:.2}"));

    // Lines.
    for (ri, run) in runs.iter().enumerate() {
        let stroke = color(ri);
        for s in run.sessions {
            let mut pts = Vec::with_capacity(space.defs.len() + 1);
            let enc = space.encode(&s.hparams);
            for (i, &e) in enc.iter().enumerate() {
                // Inactive params pin to the bottom of the axis.
                let t = if e < 0.0 { 0.0 } else { e };
                let y = y_bottom - t * (y_bottom - y_top);
                pts.push((x_of(i), y));
            }
            if let Some(m) = s.best_measure(order) {
                let t = (m - m_lo) / (m_hi - m_lo);
                pts.push((mx, y_bottom - t * (y_bottom - y_top)));
            }
            let hl = highlight.contains(&s.id);
            let (w, op) = if hl {
                (2.2, 0.95)
            } else if highlight.is_empty() {
                (1.0, 0.45)
            } else {
                (0.7, 0.12)
            };
            svg.polyline(&pts, stroke, w, op);
        }
        // Legend.
        svg.rect(MARGIN + 120.0 * ri as f64, HEIGHT - 22.0, 10.0, 10.0, stroke);
        svg.text(
            MARGIN + 120.0 * ri as f64 + 14.0,
            HEIGHT - 13.0,
            10.0,
            run.label,
        );
    }

    // Per-axis density strips (the paper's distribution hint): quintile
    // tick marks of observed values.
    for (i, d) in space.defs.iter().enumerate() {
        let mut vals: Vec<f64> = Vec::new();
        for run in runs {
            for s in run.sessions {
                let e = space.encode(&s.hparams);
                if e[i] >= 0.0 {
                    vals.push(e[i]);
                }
            }
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        for q in [0.25, 0.5, 0.75] {
            if !vals.is_empty() {
                let v = chopt_core::util::stats::percentile_sorted(&vals, q);
                let y = y_bottom - v * (y_bottom - y_top);
                svg.line(x_of(i) - 4.0, y, x_of(i) + 4.0, y, "#bbb", 1.0);
            }
        }
        let _ = d;
    }

    svg
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::config::ChoptConfig;
    use chopt_core::hparam::{Assignment, Value};
    use chopt_core::util::rng::Rng;

    fn mk_sessions(n: usize, space: &Space) -> Vec<NsmlSession> {
        let mut rng = Rng::new(5);
        (0..n)
            .map(|i| {
                let hp = space.sample(&mut rng).unwrap();
                let mut s = NsmlSession::new(SessionId(i as u64), hp, "m", 0.0);
                s.report(1, 50.0 + rng.f64() * 30.0, 2.0);
                s
            })
            .collect()
    }

    #[test]
    fn renders_all_lines() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let sessions = mk_sessions(12, &cfg.space);
        let svg = render(
            &cfg.space,
            &[RunGroup {
                label: "run-1",
                sessions: &sessions,
            }],
            Order::Descending,
            &HashSet::new(),
        );
        let doc = svg.finish();
        assert_eq!(doc.matches("<polyline").count(), 12);
        assert!(doc.contains("measure"));
        assert!(doc.contains("lr"));
    }

    #[test]
    fn highlight_changes_weights() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let sessions = mk_sessions(5, &cfg.space);
        let mut hl = HashSet::new();
        hl.insert(SessionId(0));
        let doc = render(
            &cfg.space,
            &[RunGroup {
                label: "r",
                sessions: &sessions,
            }],
            Order::Descending,
            &hl,
        )
        .finish();
        assert!(doc.contains("stroke-width=\"2.2\""));
        assert!(doc.contains("stroke-width=\"0.7\""));
    }

    #[test]
    fn multiple_runs_get_distinct_colors() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        let a = mk_sessions(3, &cfg.space);
        let b = mk_sessions(3, &cfg.space);
        let doc = render(
            &cfg.space,
            &[
                RunGroup { label: "a", sessions: &a },
                RunGroup { label: "b", sessions: &b },
            ],
            Order::Descending,
            &HashSet::new(),
        )
        .finish();
        assert!(doc.contains(crate::svg::PALETTE[0]));
        assert!(doc.contains(crate::svg::PALETTE[1]));
    }

    #[test]
    fn handles_missing_params_and_empty() {
        let cfg = ChoptConfig::from_json_str(chopt_core::config::LISTING1_EXAMPLE).unwrap();
        // Session with only lr set (others constant in that run).
        let mut hp = Assignment::new();
        hp.set("lr", Value::Float(0.05));
        let mut s = NsmlSession::new(SessionId(9), hp, "m", 0.0);
        s.report(1, 60.0, 1.0);
        let doc = render(
            &cfg.space,
            &[RunGroup { label: "partial", sessions: &[s] }],
            Order::Descending,
            &HashSet::new(),
        )
        .finish();
        assert!(doc.contains("<polyline"));
        // Empty run set renders without panic.
        let empty = render(&cfg.space, &[], Order::Descending, &HashSet::new()).finish();
        assert!(empty.contains("<svg"));
    }
}
