//! `chopt-control` — the control plane and analytic visual tool (paper
//! §3.5, Figs 3–7).
//!
//! The paper ships a web UI; we ship its serving + rendering layer:
//!
//! * [`platform`] — the live layer over an engine ([`Platform`] /
//!   [`MultiPlatform`]): structured progress events, periodic
//!   snapshots, and the view documents `serve --live` republishes.
//! * [`stored`] — stored-run read models ([`StoredRun`] /
//!   [`ReplaySource`]) serving `/api/v1` from a run directory with
//!   live-identical bodies, plus `?at_event=` scrubbing.
//! * [`export`] — session results → JSON documents (the axes/lines format
//!   a parallel-coordinates front end consumes).
//! * [`parallel_coords`] — SVG parallel-coordinates renderer (Fig. 3),
//!   with top-K highlighting (Fig. 4).
//! * [`plots`] — scatter (parameter analytic view), histogram, and
//!   learning-duration bars (Fig. 5 left).
//! * [`cluster_view`] — 2-D PCA projection of hyperparameter vectors
//!   (stand-in for the t-SNE clustered view of Fig. 5).
//! * [`hierarchy`] — PBT parent→child lineage as a node-link SVG (Fig. 5
//!   right).
//! * [`server`] — dependency-free HTTP server exposing the JSON and SVGs
//!   plus an embedded HTML viewer.
//! * [`api`] — the versioned `/api/v1` command + query surface the
//!   server dispatches through (typed routes, envelope, command bodies,
//!   and the `RunSource`/`CommandSink` split that lets live, stored, and
//!   replayed runs serve the same read model).
//! * [`sse`] — the progress-event feed behind `GET /api/v1/events` and
//!   the broadcast writer pool that fans it out to subscribers (SSE
//!   push with `Last-Event-ID` resume, so dashboards stop polling).
//! * [`fanout`] — the sharded control plane's read side: an aggregating
//!   [`fanout::FanoutSource`] that partitions one manifest across
//!   engine-worker shards and re-merges their documents behind the
//!   unchanged `/api/v1` surface (`--shards N`).
//! * [`report`] — terminal leaderboard/session tables.

pub mod api;
pub mod cluster_view;
pub mod export;
pub mod fanout;
pub mod hierarchy;
pub mod parallel_coords;
pub mod platform;
pub mod plots;
pub mod report;
pub mod server;
pub mod sse;
pub mod stored;
mod svg;

pub use platform::{MultiPlatform, Platform};
pub use stored::{ReplaySource, StoredRun};
pub use svg::Svg;
