//! Hierarchical view (Fig. 5 right): PBT parent→child lineage as a
//! layered node-link diagram.

use std::collections::HashMap;

use chopt_core::nsml::{NsmlSession, SessionId};

use crate::svg::{color, Svg};

/// Depth of each node in the lineage forest (roots at 0).
pub fn lineage_depths(sessions: &[NsmlSession]) -> HashMap<SessionId, usize> {
    let parent: HashMap<SessionId, Option<SessionId>> =
        sessions.iter().map(|s| (s.id, s.parent)).collect();
    let mut depth: HashMap<SessionId, usize> = HashMap::new();
    fn depth_of(
        id: SessionId,
        parent: &HashMap<SessionId, Option<SessionId>>,
        depth: &mut HashMap<SessionId, usize>,
        guard: usize,
    ) -> usize {
        if let Some(&d) = depth.get(&id) {
            return d;
        }
        if guard > 64 {
            return 0; // cycle guard (shouldn't happen)
        }
        let d = match parent.get(&id).copied().flatten() {
            Some(p) if parent.contains_key(&p) => {
                depth_of(p, parent, depth, guard + 1) + 1
            }
            _ => 0,
        };
        depth.insert(id, d);
        d
    }
    for s in sessions {
        depth_of(s.id, &parent, &mut depth, 0);
    }
    depth
}

/// Render the node-link diagram: layers left→right by lineage depth.
pub fn render(sessions: &[NsmlSession]) -> Svg {
    let depths = lineage_depths(sessions);
    let max_depth = depths.values().copied().max().unwrap_or(0);
    let mut by_depth: Vec<Vec<SessionId>> = vec![Vec::new(); max_depth + 1];
    let mut order: Vec<&NsmlSession> = sessions.iter().collect();
    order.sort_by_key(|s| s.id);
    for s in &order {
        by_depth[depths[&s.id]].push(s.id);
    }
    let width = 140.0 * (max_depth + 1) as f64 + 80.0;
    let tallest = by_depth.iter().map(|v| v.len()).max().unwrap_or(1);
    let height = 40.0 * tallest as f64 + 80.0;
    let mut svg = Svg::new(width, height);
    svg.text(20.0, 18.0, 12.0, "session lineage (parent -> child)");

    let mut pos: HashMap<SessionId, (f64, f64)> = HashMap::new();
    for (d, ids) in by_depth.iter().enumerate() {
        for (i, &id) in ids.iter().enumerate() {
            let x = 60.0 + 140.0 * d as f64;
            let y = 50.0 + 40.0 * i as f64;
            pos.insert(id, (x, y));
        }
    }
    // Edges first.
    for s in &order {
        if let Some(p) = s.parent {
            if let (Some(&(x1, y1)), Some(&(x2, y2))) = (pos.get(&p), pos.get(&s.id)) {
                svg.line(x1 + 10.0, y1, x2 - 10.0, y2, "#999", 1.0);
            }
        }
    }
    for s in &order {
        let (x, y) = pos[&s.id];
        let c = if s.revivals > 0 { color(2) } else { color(0) };
        svg.circle(x, y, 8.0, c, 0.9);
        svg.text(x - 10.0, y - 12.0, 8.0, &format!("#{}", s.id.0));
    }
    svg
}

#[cfg(test)]
mod tests {
    use super::*;
    use chopt_core::hparam::Assignment;

    fn s(id: u64, parent: Option<u64>) -> NsmlSession {
        let mut x = NsmlSession::new(SessionId(id), Assignment::new(), "m", 0.0);
        x.parent = parent.map(SessionId);
        x
    }

    #[test]
    fn depths_follow_lineage() {
        let sessions = vec![s(1, None), s(2, Some(1)), s(3, Some(2)), s(4, None)];
        let d = lineage_depths(&sessions);
        assert_eq!(d[&SessionId(1)], 0);
        assert_eq!(d[&SessionId(2)], 1);
        assert_eq!(d[&SessionId(3)], 2);
        assert_eq!(d[&SessionId(4)], 0);
    }

    #[test]
    fn missing_parent_is_root() {
        let sessions = vec![s(5, Some(99))]; // parent not in set
        assert_eq!(lineage_depths(&sessions)[&SessionId(5)], 0);
    }

    #[test]
    fn renders_edges_and_nodes() {
        let sessions = vec![s(1, None), s(2, Some(1)), s(3, Some(1))];
        let doc = render(&sessions).finish();
        assert_eq!(doc.matches("<circle").count(), 3);
        assert_eq!(doc.matches("<line").count(), 2);
    }
}
