//! The sharded control plane's read side: an aggregating
//! [`RunSource`] + [`CommandSink`] over N engine-worker shards.
//!
//! [`FanoutSource`] partitions the studies of one manifest across a
//! [`ShardSupervisor`] of long-lived worker threads, each owning its
//! own `MultiPlatform` (and therefore its own `StudyScheduler`), and
//! re-merges their documents behind the unchanged `/api/v1` surface —
//! a dashboard cannot tell a sharded run from a single-scheduler one.
//!
//! ## Topology
//!
//! * Every shard gets a **full-size cluster** (the manifest's
//!   `cluster_gpus`) with a subset of the studies.  With `borrow:
//!   false` (required), fair-share isolation makes each study's
//!   behavior a pure function of its own quota — which is what makes
//!   the sharded run *bit-identical* to the single-scheduler run per
//!   study.  Global capacity is enforced by the one shared-state
//!   arbiter, the `QuotaLedger` broker thread: every admission and
//!   quota change is a message through its channel, so shards never
//!   share mutable state.
//! * New studies are admitted through a bounded [`SubmissionQueue`]
//!   (spill + retry on overflow) to the least-loaded shard by reserved
//!   quota ([`ShardPlan`]); each admission is recorded by the owning
//!   shard's scheduler as a replay input, so snapshots restore by
//!   replay exactly as single-scheduler snapshots do.
//! * Trainer factories are **slot-remapped**: shard-local study index
//!   `i` resolves through a shared slot map to the global slot the
//!   study would have had in the single-scheduler run, so seed-by-slot
//!   factories (`surrogate::default_multi_factory`) build identical
//!   trainers under any shard count.
//!
//! ## Merge rules (deterministic, shard-count-invariant)
//!
//! * `t` = max over shard clocks (equals the single-scheduler clock);
//!   counters are summed from raw per-shard integers, utilization is
//!   re-derived as `Σ used / cluster_gpus` — never from rendered
//!   floats.
//! * Study rows interleave in **global slot order** (manifest order,
//!   then admission order), so the merged `fair_share`/`studies`
//!   arrays are byte-identical to the single-scheduler documents.
//! * SSE records are drained per barrier from private per-shard feeds
//!   and re-published sorted by `(t, global slot, per-shard order)` —
//!   the same canonical order at every shard count (including 1).
//! * `?at_event=` scrubbing rounds down to the nearest **barrier
//!   mark** (a recorded vector of per-shard event counts), then
//!   replays each shard's snapshot to its component and re-merges.
//!
//! ## Documented divergences from a single scheduler
//!
//! * `status.events_processed` (and the response envelope's
//!   generation) is the *sum* of per-shard counts: master-tick events
//!   replicate per shard, so the sum exceeds the single-scheduler
//!   count.  Per-study state, documents, and event logs are still
//!   bit-identical.
//! * The cluster usage **series** is a deterministic step-function
//!   merge of per-shard series, not the single-scheduler byte stream.
//! * A submission routed to a fully-drained shard activates at its
//!   submission time instead of the next global master tick, and
//!   command `effective_at` clamps against the owning shard's clock.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use chopt_cluster::{Owner, QuotaBroker, QuotaClient, QuotaLedger};
use chopt_core::events::SimTime;
use chopt_core::trainer::Trainer;
use chopt_core::util::json::Value as Json;
use chopt_engine::coordinator::{StudyManifest, StudySpec};
use chopt_engine::shard::{Admission, ShardPlan, ShardSupervisor, SubmissionQueue};

use crate::api::{ApiCommand, ApiError, ApiQuery, CommandSink, RunSource};
use crate::platform::MultiPlatform;
use crate::sse::{EventFeed, DEFAULT_FEED_CAPACITY};

/// A shared trainer factory keyed by **global** study slot; cloned into
/// every shard (and every scrub replay), so restore-by-replay always
/// resolves to the one factory the run was started with.
pub type TrainerFactory = Arc<dyn Fn(usize, u64) -> Box<dyn Trainer + Send> + Send + Sync>;

/// The rejection every invalid admission maps to — byte-identical to
/// `MultiPlatform`'s `submit_study` rejection so clients see one
/// message regardless of topology.
const REJECT: &str = "study rejected (duplicate name, bad quota/priority, or quota does not fit)";

/// Construction options for [`FanoutSource::new`].
pub struct FanoutConfig {
    /// Engine-worker shard count (`--shards N`); clamped to >= 1.
    pub shards: usize,
    /// Bounded submission-queue capacity; overflow spills + retries.
    pub queue_capacity: usize,
    /// Per-shard `--step-threads` (intra-shard windowed stepping).
    pub step_threads: usize,
    /// Stream per-study progress into `dir/events-<study>.jsonl`
    /// (shards share the directory; study names are globally unique).
    pub log_dir: Option<PathBuf>,
    /// Publish the *merged* progress stream into this feed.
    pub feed: Option<Arc<EventFeed>>,
    /// Write a composite snapshot to `path` every `every` virtual
    /// seconds (and once at completion).
    pub snapshot: Option<(PathBuf, SimTime)>,
}

impl Default for FanoutConfig {
    fn default() -> FanoutConfig {
        FanoutConfig {
            shards: 2,
            queue_capacity: 64,
            step_threads: 1,
            log_dir: None,
            feed: None,
            snapshot: None,
        }
    }
}

/// One admission barrier: the merged event count, its per-shard
/// components (the scrub target for `?at_event=`), and the merged
/// clock at that instant.
#[derive(Debug, Clone)]
struct Mark {
    total: u64,
    per_shard: Vec<u64>,
    t: SimTime,
}

/// The aggregating run source over engine-worker shards.
pub struct FanoutSource {
    sup: ShardSupervisor<MultiPlatform<'static>>,
    plan: ShardPlan,
    queue: SubmissionQueue,
    /// Keeps the ledger broker thread alive for the run's lifetime.
    _broker: QuotaBroker,
    quota: QuotaClient,
    factory: TrainerFactory,
    /// Shard → (local study index → global slot); shared with that
    /// shard's trainer factory.
    slots: Vec<Arc<Mutex<Vec<usize>>>>,
    /// Global slot → study name, admission order.
    names: Vec<String>,
    slot_of: HashMap<String, usize>,
    total_gpus: usize,
    /// Private per-shard feeds (only when a merged feed is attached).
    shard_feeds: Vec<Arc<EventFeed>>,
    feed_cursors: Vec<u64>,
    feed: Option<Arc<EventFeed>>,
    marks: Vec<Mark>,
    cached_now: SimTime,
    cached_generation: u64,
    generation_gauge: Option<Arc<AtomicU64>>,
    snapshot_path: Option<PathBuf>,
    snapshot_every: SimTime,
    last_snapshot_t: SimTime,
    /// Queue drains refused by validation (duplicates, bad quota, …).
    rejected: u64,
}

/// Wrap the global factory for one shard: local index → global slot.
fn remap(
    factory: TrainerFactory,
    slots: Arc<Mutex<Vec<usize>>>,
) -> impl FnMut(usize, u64) -> Box<dyn Trainer + Send> + 'static {
    move |local, id| {
        let global = slots.lock().unwrap().get(local).copied().unwrap_or(local);
        (factory)(global, id)
    }
}

/// The scheduler's study-name rule, mirrored so a fan-out refusal is
/// indistinguishable from a scheduler refusal.
fn valid_study_name(name: &str) -> bool {
    !name.is_empty()
        && !name.starts_with('.')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

fn num(doc: &Json, key: &str) -> f64 {
    doc.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

impl FanoutSource {
    /// Partition `manifest` across `cfg.shards` workers and start them.
    ///
    /// Sharded runs require hard isolation: `borrow: true`, an external
    /// load trace, and scenario demand/fault sources are all
    /// cluster-global couplings and are rejected.  Submissions-only
    /// scenarios are accepted — their entries pre-load the bounded
    /// submission queue.
    pub fn new(
        manifest: StudyManifest,
        factory: TrainerFactory,
        cfg: FanoutConfig,
    ) -> anyhow::Result<FanoutSource> {
        anyhow::ensure!(
            !manifest.borrow,
            "sharded runs require 'borrow: false' — cross-study borrowing couples \
             every study through one allocator and cannot be partitioned"
        );
        anyhow::ensure!(
            manifest.trace.is_none(),
            "sharded runs do not support an external load trace (cluster-global demand)"
        );
        let mut queue = SubmissionQueue::new(cfg.queue_capacity);
        if let Some(sc) = &manifest.scenario {
            anyhow::ensure!(
                sc.sources.is_empty(),
                "sharded runs accept submissions-only scenarios; demand/fault sources \
                 are cluster-global"
            );
            for (i, sub) in sc.submissions.iter().enumerate() {
                let spec = StudySpec::from_json(&sub.spec, manifest.studies.len() + i)?;
                // Overflow spills — deferred admission, not an error.
                let _ = queue.submit(spec, sub.at);
            }
        }

        let shards = cfg.shards.max(1);
        let total_gpus = manifest.cluster_gpus;
        let mut plan = ShardPlan::new(shards);
        let mut ledger = QuotaLedger::new(total_gpus);
        let mut names = Vec::new();
        let mut slot_of = HashMap::new();
        let mut shard_specs: Vec<Vec<StudySpec>> = vec![Vec::new(); shards];
        for (slot, spec) in manifest.studies.iter().enumerate() {
            anyhow::ensure!(
                ledger.lease(&spec.name, spec.quota),
                "manifest study '{}' does not fit the quota ledger \
                 (duplicate name, zero quota, or sum of quotas over cluster_gpus)",
                spec.name
            );
            let k = plan.assign(spec.quota);
            names.push(spec.name.clone());
            slot_of.insert(spec.name.clone(), slot);
            shard_specs[k].push(spec.clone());
        }
        let (broker, quota) = QuotaBroker::with_ledger(ledger);

        if let Some(dir) = &cfg.log_dir {
            std::fs::create_dir_all(dir)?;
        }
        let slots: Vec<Arc<Mutex<Vec<usize>>>> = (0..shards)
            .map(|k| Arc::new(Mutex::new(plan.slots_of(k))))
            .collect();
        let shard_feeds: Vec<Arc<EventFeed>> = if cfg.feed.is_some() {
            (0..shards).map(|_| EventFeed::new(DEFAULT_FEED_CAPACITY)).collect()
        } else {
            Vec::new()
        };

        let inits = shard_specs
            .into_iter()
            .enumerate()
            .map(|(k, studies)| {
                let mut m = manifest.clone();
                m.studies = studies;
                // A shard must stay window-steppable and replay-pure:
                // no scenario, no trace (both enforced above anyway).
                m.scenario = None;
                m.trace = None;
                let factory = factory.clone();
                let slot_map = slots[k].clone();
                let feed = shard_feeds.get(k).cloned();
                let log_dir = cfg.log_dir.clone();
                let step_threads = cfg.step_threads;
                Box::new(move || {
                    let mut mp = MultiPlatform::new(m, remap(factory, slot_map));
                    if let Some(dir) = log_dir {
                        mp = mp.with_event_logs(dir).expect("open shard event-log dir");
                    }
                    if let Some(f) = feed {
                        mp = mp.with_progress_feed(f);
                    }
                    if step_threads > 1 {
                        mp.set_step_threads(step_threads);
                    }
                    mp
                }) as Box<dyn FnOnce() -> MultiPlatform<'static> + Send>
            })
            .collect();

        let feed_cursors = vec![0; shard_feeds.len()];
        let (snapshot_path, snapshot_every) = match cfg.snapshot {
            Some((p, e)) => (Some(p), e.max(1.0)),
            None => (None, 3600.0),
        };
        let mut src = FanoutSource {
            sup: ShardSupervisor::start(inits),
            plan,
            queue,
            _broker: broker,
            quota,
            factory,
            slots,
            names,
            slot_of,
            total_gpus,
            shard_feeds,
            feed_cursors,
            feed: cfg.feed,
            marks: Vec::new(),
            cached_now: 0.0,
            cached_generation: 0,
            generation_gauge: None,
            snapshot_path,
            snapshot_every,
            last_snapshot_t: 0.0,
            rejected: 0,
        };
        src.barrier();
        Ok(src)
    }

    /// Rebuild a fan-out from a composite snapshot written by
    /// [`FanoutSource::snapshot_now`]: each shard restores by replay
    /// from its embedded `multi_study` snapshot, the placement plan and
    /// queue backlog come back verbatim, and the quota ledger is
    /// re-leased from the plan.
    pub fn restore_doc(
        doc: &Json,
        factory: TrainerFactory,
        cfg: FanoutConfig,
    ) -> anyhow::Result<FanoutSource> {
        let kind = doc.get("kind").and_then(|v| v.as_str());
        anyhow::ensure!(
            kind == Some("sharded_multi_study"),
            "not a sharded snapshot (kind {kind:?}); single-scheduler snapshots \
             restore through MultiPlatform"
        );
        let plan = ShardPlan::from_json(
            doc.get("plan")
                .ok_or_else(|| anyhow::anyhow!("sharded snapshot missing 'plan'"))?,
        )?;
        let queue = SubmissionQueue::from_json(
            doc.get("queue")
                .ok_or_else(|| anyhow::anyhow!("sharded snapshot missing 'queue'"))?,
        )?;
        let marks: Vec<Mark> = doc
            .get("marks")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|m| Mark {
                        total: num(m, "events") as u64,
                        per_shard: m
                            .get("per_shard")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().map(|v| v.as_f64().unwrap_or(0.0) as u64).collect())
                            .unwrap_or_default(),
                        t: num(m, "t"),
                    })
                    .collect()
            })
            .unwrap_or_default();
        let shard_docs = doc
            .get("shards")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("sharded snapshot missing 'shards'"))?;
        anyhow::ensure!(
            shard_docs.len() == plan.shards(),
            "sharded snapshot has {} shard snapshots for a {}-shard plan",
            shard_docs.len(),
            plan.shards()
        );
        let total_gpus = shard_docs
            .first()
            .and_then(|d| d.get("manifest"))
            .and_then(|m| m.get("cluster_gpus"))
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("shard snapshot missing manifest cluster_gpus"))?;

        if let Some(dir) = &cfg.log_dir {
            std::fs::create_dir_all(dir)?;
        }
        let shards = plan.shards();
        let slots: Vec<Arc<Mutex<Vec<usize>>>> = (0..shards)
            .map(|k| Arc::new(Mutex::new(plan.slots_of(k))))
            .collect();
        let shard_feeds: Vec<Arc<EventFeed>> = if cfg.feed.is_some() {
            (0..shards).map(|_| EventFeed::new(DEFAULT_FEED_CAPACITY)).collect()
        } else {
            Vec::new()
        };
        let inits = shard_docs
            .iter()
            .enumerate()
            .map(|(k, shard_doc)| {
                let shard_doc = shard_doc.clone();
                let factory = factory.clone();
                let slot_map = slots[k].clone();
                let feed = shard_feeds.get(k).cloned();
                let log_dir = cfg.log_dir.clone();
                let step_threads = cfg.step_threads;
                Box::new(move || {
                    let mut mp =
                        MultiPlatform::restore_doc(&shard_doc, remap(factory, slot_map))
                            .expect("restore shard snapshot by replay");
                    if let Some(dir) = log_dir {
                        mp = mp.with_event_logs(dir).expect("open shard event-log dir");
                    }
                    if let Some(f) = feed {
                        mp = mp.with_progress_feed(f);
                    }
                    if step_threads > 1 {
                        mp.set_step_threads(step_threads);
                    }
                    mp
                }) as Box<dyn FnOnce() -> MultiPlatform<'static> + Send>
            })
            .collect();
        let sup: ShardSupervisor<MultiPlatform<'static>> = ShardSupervisor::start(inits);

        // Global slot → name, re-derived from the restored shards (each
        // shard keeps its studies in global relative order).
        let per_shard_names: Vec<Vec<String>> = sup.run_all(|_, mp| {
            mp.scheduler()
                .studies()
                .iter()
                .map(|st| st.name().to_string())
                .collect()
        });
        let mut names = Vec::new();
        let mut slot_of = HashMap::new();
        let mut next = vec![0usize; shards];
        let mut ledger = QuotaLedger::new(total_gpus);
        for slot in 0..plan.len() {
            let k = plan.owner_of(slot).unwrap_or(0);
            let name = per_shard_names
                .get(k)
                .and_then(|ns| ns.get(next[k]))
                .ok_or_else(|| anyhow::anyhow!("shard {k} snapshot is missing slot {slot}"))?
                .clone();
            next[k] += 1;
            anyhow::ensure!(
                ledger.lease(&name, plan.slot_quota(slot).unwrap_or(0)),
                "restored study '{name}' does not fit the quota ledger"
            );
            slot_of.insert(name.clone(), slot);
            names.push(name);
        }
        let (broker, quota) = QuotaBroker::with_ledger(ledger);

        let feed_cursors = vec![0; shard_feeds.len()];
        let (snapshot_path, snapshot_every) = match cfg.snapshot {
            Some((p, e)) => (Some(p), e.max(1.0)),
            None => (None, 3600.0),
        };
        let mut src = FanoutSource {
            sup,
            plan,
            queue,
            _broker: broker,
            quota,
            factory,
            slots,
            names,
            slot_of,
            total_gpus,
            shard_feeds,
            feed_cursors,
            feed: cfg.feed,
            marks,
            cached_now: 0.0,
            cached_generation: 0,
            generation_gauge: None,
            snapshot_path,
            snapshot_every,
            last_snapshot_t: 0.0,
            rejected: 0,
        };
        src.barrier();
        src.last_snapshot_t = src.cached_now;
        Ok(src)
    }

    // -- driving -----------------------------------------------------------

    /// Merged virtual clock: the max across shard clocks, which equals
    /// the single-scheduler clock (the globally-last event lives on
    /// some shard).
    pub fn now(&self) -> SimTime {
        self.cached_now
    }

    pub fn shards(&self) -> usize {
        self.sup.len()
    }

    /// Admitted studies, global slot order.
    pub fn study_names(&self) -> &[String] {
        &self.names
    }

    /// (queued, spilled, lifetime admitted, lifetime spilled, rejected).
    pub fn queue_stats(&self) -> (usize, usize, u64, u64, u64) {
        let (admitted, spilled) = self.queue.stats();
        (self.queue.len(), self.queue.spill_len(), admitted, spilled, self.rejected)
    }

    /// Recorded admission barriers as `(merged_events, t)` — the valid
    /// scrub targets for `?at_event=`.
    pub fn barrier_marks(&self) -> Vec<(u64, SimTime)> {
        self.marks.iter().map(|m| (m.total, m.t)).collect()
    }

    /// The run is over when every shard is drained **and** no
    /// submission is waiting for a future barrier.
    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.sup.run_all(|_, mp| mp.is_done()).into_iter().all(|d| d)
    }

    /// Publish the merged event count into `gauge` after every barrier
    /// — same contract as `MultiPlatform::set_generation_gauge`.
    pub fn set_generation_gauge(&mut self, gauge: Arc<AtomicU64>) {
        gauge.store(self.cached_generation, Ordering::Release);
        self.generation_gauge = Some(gauge);
    }

    /// Advance every shard to virtual time `target`, splitting the
    /// advance at each queued submission time so a study is admitted
    /// *exactly* at its requested time — the rule that keeps sharded
    /// admission bit-identical to a single-scheduler driver performing
    /// the same splits.  Returns events stepped + studies admitted.
    pub fn run_until(&mut self, target: SimTime) -> u64 {
        let mut n = 0u64;
        let mut cursor = self.cached_now;
        loop {
            n += self.admit_ready(cursor);
            let split = self.queue.next_ready_at().filter(|&a| a <= target);
            let stop = split.unwrap_or(target);
            if stop > cursor {
                let stepped: u64 = self.sup.run_all(move |_, mp| mp.run_until(stop)).iter().sum();
                n += stepped;
            }
            cursor = cursor.max(stop);
            if split.is_none() {
                break;
            }
        }
        self.barrier();
        n
    }

    /// Advance by `dt`; on an idle gap, jump to the next actionable
    /// instant (earliest shard event or queued submission) so callers
    /// looping on `advance` always make progress.  Returns 0 only when
    /// the run is over.
    pub fn advance(&mut self, dt: SimTime) -> u64 {
        let target = self.cached_now + dt;
        let n = self.run_until(target);
        if n > 0 {
            return n;
        }
        let next_ev = self
            .sup
            .run_all(|_, mp| mp.scheduler().next_event_time())
            .into_iter()
            .flatten()
            .fold(f64::INFINITY, f64::min);
        let next_sub = self.queue.next_ready_at().unwrap_or(f64::INFINITY);
        let next = next_ev.min(next_sub);
        if !next.is_finite() {
            return 0;
        }
        self.run_until(next.max(target))
    }

    /// Drive to completion in `chunk`-sized slices.
    pub fn run_to_completion(&mut self, chunk: SimTime) -> u64 {
        let chunk = chunk.max(1.0);
        let mut n = 0;
        loop {
            let stepped = self.advance(chunk);
            n += stepped;
            if stepped == 0 || self.is_done() {
                break;
            }
        }
        if self.snapshot_path.is_some() {
            let _ = self.snapshot_now();
        }
        n
    }

    /// Enqueue a study for admission at `at` (the scenario-driven and
    /// API submission path).  Returns the admission verdict; validation
    /// happens at drain time so refusals match `submit_study`'s.
    pub fn enqueue(&mut self, spec: StudySpec, at: SimTime) -> Admission {
        self.queue.submit(spec, at)
    }

    fn admit_ready(&mut self, t: SimTime) -> u64 {
        let mut n = 0;
        for sub in self.queue.drain_ready(t) {
            match self.admit(sub.spec, sub.at) {
                Ok(_) => n += 1,
                Err(_) => self.rejected += 1,
            }
        }
        n
    }

    /// Admit one study: validate, lease global quota through the
    /// ledger broker, place on the least-loaded shard, and record the
    /// submission as that shard's replay input.
    fn admit(&mut self, spec: StudySpec, at: SimTime) -> Result<SimTime, ApiError> {
        let name = spec.name.clone();
        if !valid_study_name(&name)
            || spec.quota == 0
            || !(spec.priority.is_finite() && spec.priority > 0.0)
            || self.slot_of.contains_key(&name)
        {
            return Err(ApiError::BadRequest(REJECT.into()));
        }
        if !self.quota.lease(&name, spec.quota) {
            return Err(ApiError::BadRequest(REJECT.into()));
        }
        let shard = self.plan.peek(spec.quota);
        let slot = self.names.len();
        self.slots[shard].lock().unwrap().push(slot);
        let quota = spec.quota;
        let effective = self.sup.run_on(shard, move |mp| mp.submit_study(spec, at));
        match effective {
            Some(t) => {
                self.plan.place(shard, quota);
                self.slot_of.insert(name.clone(), slot);
                self.names.push(name);
                Ok(t)
            }
            None => {
                // Shard refused (e.g. horizon reached): unwind the
                // placement and the lease.
                self.slots[shard].lock().unwrap().pop();
                self.quota.release(&name);
                Err(ApiError::BadRequest(REJECT.into()))
            }
        }
    }

    /// Post-step bookkeeping: refresh the merged clock/generation,
    /// record the scrub mark, publish SSE in canonical order, keep the
    /// generation gauge and periodic snapshots honest.
    fn barrier(&mut self) {
        let stats = self.sup.run_all(|_, mp| (mp.scheduler().events_processed(), mp.now()));
        let total: u64 = stats.iter().map(|&(e, _)| e).sum();
        self.cached_now = stats.iter().map(|&(_, t)| t).fold(self.cached_now, f64::max);
        self.cached_generation = total;
        if self.marks.last().map(|m| m.total) != Some(total) {
            self.marks.push(Mark {
                total,
                per_shard: stats.iter().map(|&(e, _)| e).collect(),
                t: self.cached_now,
            });
        }
        if let Some(gauge) = &self.generation_gauge {
            gauge.store(total, Ordering::Release);
        }
        self.merge_feed();
        self.maybe_snapshot();
    }

    /// Drain each shard's private feed and re-publish sorted by
    /// `(t, global slot, per-shard order)` — studies are disjoint
    /// across shards, so ties within `(t, slot)` come from one shard
    /// and the stable sort preserves its local order.
    fn merge_feed(&mut self) {
        let Some(out) = self.feed.clone() else { return };
        let mut records: Vec<(f64, usize, String)> = Vec::new();
        for (k, feed) in self.shard_feeds.iter().enumerate() {
            let (_missed, items) = feed.read_after(self.feed_cursors[k]);
            for (seq, line) in items {
                self.feed_cursors[k] = seq;
                let (t, slot) = match chopt_core::util::json::parse(&line) {
                    Ok(doc) => (
                        num(&doc, "t"),
                        doc.get("study")
                            .and_then(|v| v.as_str())
                            .and_then(|s| self.slot_of.get(s).copied())
                            .unwrap_or(usize::MAX),
                    ),
                    Err(_) => (f64::MAX, usize::MAX),
                };
                records.push((t, slot, line));
            }
        }
        records.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (_, _, line) in records {
            out.publish(line);
        }
    }

    fn maybe_snapshot(&mut self) {
        if self.snapshot_path.is_some()
            && self.cached_now - self.last_snapshot_t >= self.snapshot_every
        {
            let _ = self.snapshot_now();
        }
    }

    // -- snapshots ---------------------------------------------------------

    /// The composite snapshot: per-shard `multi_study` snapshots plus
    /// the placement plan, the unadmitted queue backlog, and the scrub
    /// marks.
    pub fn snapshot_json(&self) -> Json {
        let shards = self.sup.run_all(|_, mp| mp.scheduler().snapshot_json());
        let marks: Vec<Json> = self
            .marks
            .iter()
            .map(|m| {
                Json::obj()
                    .with("events", Json::Num(m.total as f64))
                    .with(
                        "per_shard",
                        Json::Arr(m.per_shard.iter().map(|&e| Json::Num(e as f64)).collect()),
                    )
                    .with("t", Json::Num(m.t))
            })
            .collect();
        Json::obj()
            .with("version", Json::Num(1.0))
            .with("kind", Json::Str("sharded_multi_study".into()))
            .with("plan", self.plan.to_json())
            .with("queue", self.queue.to_json())
            .with("marks", Json::Arr(marks))
            .with("shards", Json::Arr(shards))
    }

    /// Write (and return) the composite snapshot right now.
    pub fn snapshot_now(&mut self) -> std::io::Result<Json> {
        let doc = self.snapshot_json();
        if let Some(path) = &self.snapshot_path {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, doc.to_string_pretty())?;
        }
        self.last_snapshot_t = self.cached_now;
        Ok(doc)
    }

    // -- merged reads ------------------------------------------------------

    /// Per-shard answers for a cluster-wide query: the shard's own
    /// document plus the raw integers the merge re-derives utilization
    /// from.  `mark` switches to scrub replays at that barrier.
    fn gather(
        &self,
        q: &ApiQuery,
        mark: Option<&Mark>,
    ) -> Result<Vec<(Json, usize, usize)>, ApiError> {
        let q2 = q.clone();
        let answers: Vec<Result<(Json, usize, usize), ApiError>> = match mark {
            None => self.sup.run_all(move |_, mp| {
                let used = mp.scheduler().cluster().used();
                let ext = mp.scheduler().cluster().held_by(Owner::External);
                mp.query(&q2).map(|d| (d, used, ext))
            }),
            Some(m) => {
                let per = m.per_shard.clone();
                let factory = self.factory.clone();
                let slots = self.slots.clone();
                self.sup.run_all(move |k, mp| {
                    let snap = mp.scheduler().snapshot_json();
                    let scrub = MultiPlatform::restore_doc_at(
                        &snap,
                        remap(factory.clone(), slots[k].clone()),
                        per.get(k).copied().unwrap_or(0),
                    )
                    .map_err(|e| ApiError::BadRequest(format!("scrub replay failed: {e:#}")))?;
                    let used = scrub.scheduler().cluster().used();
                    let ext = scrub.scheduler().cluster().held_by(Owner::External);
                    scrub.query(&q2).map(|d| (d, used, ext))
                })
            }
        };
        answers.into_iter().collect()
    }

    /// Route a per-study query to its owning shard (scrub-replayed at
    /// `mark` when given).
    fn shard_query(&self, shard: usize, q: &ApiQuery, mark: Option<&Mark>) -> Result<Json, ApiError> {
        let q2 = q.clone();
        match mark {
            None => self.sup.run_on(shard, move |mp| mp.query(&q2)),
            Some(m) => {
                let upto = m.per_shard.get(shard).copied().unwrap_or(0);
                let factory = self.factory.clone();
                let slot_map = self.slots[shard].clone();
                self.sup.run_on(shard, move |mp| {
                    let snap = mp.scheduler().snapshot_json();
                    let scrub = MultiPlatform::restore_doc_at(&snap, remap(factory, slot_map), upto)
                        .map_err(|e| {
                            ApiError::BadRequest(format!("scrub replay failed: {e:#}"))
                        })?;
                    scrub.query(&q2)
                })
            }
        }
    }

    /// Interleave per-shard study rows back into global slot order.
    /// Scrub replays may hold fewer rows per shard (admissions after
    /// the mark); exhausted shards are skipped, which is exactly the
    /// set of studies that existed at the mark.
    fn merged_rows(&self, docs: &[&Json], key: &str) -> Vec<Json> {
        let arrs: Vec<&[Json]> = docs
            .iter()
            .map(|d| d.get(key).and_then(|v| v.as_arr()).unwrap_or(&[]))
            .collect();
        let mut next = vec![0usize; arrs.len()];
        let mut rows = Vec::new();
        for slot in 0..self.plan.len() {
            let k = self.plan.owner_of(slot).unwrap_or(0);
            if let Some(row) = arrs.get(k).and_then(|a| a.get(next[k])) {
                rows.push(row.clone());
                next[k] += 1;
            }
        }
        rows
    }

    fn utilization_of(&self, used: usize) -> f64 {
        if self.total_gpus == 0 {
            0.0
        } else {
            used as f64 / self.total_gpus as f64
        }
    }

    fn merge_status(&self, pieces: &[(Json, usize, usize)], live: bool) -> Json {
        let docs: Vec<&Json> = pieces.iter().map(|(d, _, _)| d).collect();
        let t = docs.iter().map(|d| num(d, "t")).fold(0.0, f64::max);
        let sum = |key: &str| docs.iter().map(|d| num(d, key)).sum::<f64>();
        let used: usize = pieces.iter().map(|&(_, u, _)| u).sum();
        let all_done = docs
            .iter()
            .all(|d| d.get("done").and_then(|v| v.as_bool()).unwrap_or(false));
        // The queue backlog only gates the *live* run loop; the shard
        // AND mirrors the single scheduler's own is_done flag.
        let _ = live;
        let injected = |key: &str| {
            docs.iter()
                .map(|d| {
                    d.get("injected_failures")
                        .and_then(|f| f.get(key))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0)
                })
                .sum::<f64>()
        };
        Json::obj()
            .with("t", Json::Num(t))
            .with("events_processed", Json::Num(sum("events_processed")))
            .with("done", Json::Bool(all_done))
            .with("studies", Json::Num(sum("studies")))
            .with("studies_started", Json::Num(sum("studies_started")))
            .with("studies_done", Json::Num(sum("studies_done")))
            .with("studies_degraded", Json::Num(sum("studies_degraded")))
            .with("studies_quarantined", Json::Num(sum("studies_quarantined")))
            .with(
                "injected_failures",
                Json::obj()
                    .with("applied", Json::Num(injected("applied")))
                    .with("skipped", Json::Num(injected("skipped"))),
            )
            .with("utilization", Json::Num(self.utilization_of(used)))
            .with("progress_events", Json::Num(sum("progress_events")))
            // Control-plane gauges, appended at the end so every older
            // status key keeps its byte position (the shard-determinism
            // fingerprint neutralizes these, not reorders around them).
            // They read the aggregator's *current* queue/ledger state —
            // under `?at_event` scrubs the run-level keys rewind but
            // these gauges do not (the queue is not replay-indexed).
            .with("submission_queue", {
                let (depth, spilled, admitted, spill_total, rejected) = self.queue_stats();
                Json::obj()
                    .with("depth", Json::Num(depth as f64))
                    .with("spilled", Json::Num(spilled as f64))
                    .with("admitted", Json::Num(admitted as f64))
                    .with("spill_total", Json::Num(spill_total as f64))
                    .with("rejected", Json::Num(rejected as f64))
            })
            .with("quota_ledger", {
                let stat = self.quota.stat();
                Json::obj()
                    .with("total_gpus", Json::Num(stat.total as f64))
                    .with("reserved", Json::Num(stat.reserved as f64))
                    .with("studies", Json::Num(stat.studies as f64))
            })
    }

    fn merge_fair_share(&self, pieces: &[(Json, usize, usize)]) -> Json {
        let docs: Vec<&Json> = pieces.iter().map(|(d, _, _)| d).collect();
        let t = docs.iter().map(|d| num(d, "t")).fold(0.0, f64::max);
        let used: usize = pieces.iter().map(|&(_, u, _)| u).sum();
        // Sharded runs reject external demand, so every shard reports
        // 0; max (not sum) keeps the invariant under a hypothetical
        // shard-replicated trace.
        let external: usize = pieces.iter().map(|&(_, _, e)| e).max().unwrap_or(0);
        let rows = self.merged_rows(&docs, "studies");
        Json::obj()
            .with("t", Json::Num(t))
            .with("cluster_gpus", Json::Num(self.total_gpus as f64))
            .with("used", Json::Num(used as f64))
            .with("external", Json::Num(external as f64))
            .with("utilization", Json::Num(self.utilization_of(used)))
            .with("studies", Json::Arr(rows))
    }

    fn merge_studies(&self, pieces: &[(Json, usize, usize)]) -> Json {
        let docs: Vec<&Json> = pieces.iter().map(|(d, _, _)| d).collect();
        let t = docs.iter().map(|d| num(d, "t")).fold(0.0, f64::max);
        let rows = self.merged_rows(&docs, "studies");
        Json::obj()
            .with("t", Json::Num(t))
            .with("count", Json::Num(rows.len() as f64))
            .with("studies", Json::Arr(rows))
    }

    /// Step-function sum of per-shard change-point series: walk all
    /// change points in time order, maintain each shard's current
    /// level, and emit the summed level at every distinct time.
    fn merge_series(arrs: &[&[Json]]) -> Json {
        let mut pts: Vec<(f64, usize, f64)> = Vec::new();
        for (k, arr) in arrs.iter().enumerate() {
            for p in arr.iter() {
                let pair = p.as_arr().unwrap_or(&[]);
                let t = pair.first().and_then(|v| v.as_f64()).unwrap_or(0.0);
                let v = pair.get(1).and_then(|v| v.as_f64()).unwrap_or(0.0);
                pts.push((t, k, v));
            }
        }
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut cur = vec![0.0f64; arrs.len()];
        let mut out = Vec::new();
        let mut i = 0;
        while i < pts.len() {
            let t = pts[i].0;
            while i < pts.len() && pts[i].0 == t {
                cur[pts[i].1] = pts[i].2;
                i += 1;
            }
            out.push(Json::Arr(vec![Json::Num(t), Json::Num(cur.iter().sum())]));
        }
        Json::Arr(out)
    }

    fn merge_cluster(&self, pieces: &[(Json, usize, usize)]) -> Json {
        let docs: Vec<&Json> = pieces.iter().map(|(d, _, _)| d).collect();
        let t = docs.iter().map(|d| num(d, "t")).fold(0.0, f64::max);
        let used: usize = pieces.iter().map(|&(_, u, _)| u).sum();
        let sum = |key: &str| docs.iter().map(|d| num(d, key)).sum::<f64>();
        let series = |key: &str| {
            let arrs: Vec<&[Json]> = docs
                .iter()
                .map(|d| d.get(key).and_then(|v| v.as_arr()).unwrap_or(&[]))
                .collect();
            FanoutSource::merge_series(&arrs)
        };
        Json::obj()
            .with("t", Json::Num(t))
            .with("total_gpus", Json::Num(self.total_gpus as f64))
            .with("used", Json::Num(used as f64))
            .with("chopt_held", Json::Num(sum("chopt_held")))
            .with("utilization", Json::Num(self.utilization_of(used)))
            .with("chopt_gpu_hours", Json::Num(sum("chopt_gpu_hours")))
            .with(
                "window",
                docs.first()
                    .and_then(|d| d.get("window"))
                    .cloned()
                    .unwrap_or(Json::Null),
            )
            .with("series_total", series("series_total"))
            .with("series_chopt", series("series_chopt"))
            .with("series_external", series("series_external"))
    }

    fn query_with(&self, q: &ApiQuery, mark: Option<&Mark>) -> Result<Json, ApiError> {
        match q {
            ApiQuery::Status => Ok(self.merge_status(&self.gather(q, mark)?, mark.is_none())),
            ApiQuery::Cluster { .. } => Ok(self.merge_cluster(&self.gather(q, mark)?)),
            ApiQuery::FairShare => Ok(self.merge_fair_share(&self.gather(q, mark)?)),
            ApiQuery::Studies => Ok(self.merge_studies(&self.gather(q, mark)?)),
            ApiQuery::StudySessions { study, .. }
            | ApiQuery::StudyLeaderboard { study, .. }
            | ApiQuery::StudyParallel { study }
            | ApiQuery::StudyCurves { study, .. } => {
                let slot = *self
                    .slot_of
                    .get(study)
                    .ok_or_else(|| ApiError::NotFound(format!("unknown study '{study}'")))?;
                let shard = self.plan.owner_of(slot).unwrap_or(0);
                let mut doc = self.shard_query(shard, q, mark)?;
                if matches!(q, ApiQuery::StudyLeaderboard { .. }) {
                    // The shard stamps its local clock; rewrite in
                    // place (key order preserved) to the merged one.
                    let t = mark.map(|m| m.t).unwrap_or(self.cached_now);
                    doc.set("t", Json::Num(t));
                }
                Ok(doc)
            }
            ApiQuery::Sessions { .. }
            | ApiQuery::Leaderboard { .. }
            | ApiQuery::Parallel
            | ApiQuery::Curves { .. } => Err(ApiError::NotFound(
                "single-study endpoint; use /api/v1/studies/<name>/…".into(),
            )),
            ApiQuery::Sweep | ApiQuery::SweepCell { .. } => Err(ApiError::NotFound(
                "sweep endpoint; serve a sweep directory (chopt serve --sweep)".into(),
            )),
        }
    }
}

impl RunSource for FanoutSource {
    /// Sum of per-shard processed-event counts (monotone; larger than
    /// the single-scheduler count — ticks replicate per shard).
    fn generation(&self) -> u64 {
        self.cached_generation
    }

    fn query(&self, q: &ApiQuery) -> Result<Json, ApiError> {
        self.query_with(q, None)
    }

    /// `?at_event=` across the sharded topology: round `at` down to the
    /// nearest recorded barrier mark, scrub-replay every shard to its
    /// per-shard component, and re-merge with the same rules as live.
    fn query_at(&self, q: &ApiQuery, at: u64) -> Result<(u64, Json), ApiError> {
        let mark = self
            .marks
            .iter()
            .rev()
            .find(|m| m.total <= at)
            .cloned()
            .ok_or_else(|| {
                ApiError::BadRequest("no recorded barrier at or before that event".into())
            })?;
        let doc = self.query_with(q, Some(&mark))?;
        Ok((mark.total, doc))
    }
}

impl CommandSink for FanoutSource {
    fn command(&mut self, c: &ApiCommand) -> Result<Json, ApiError> {
        let ack = |kind: &str, at: SimTime| {
            Json::obj()
                .with("applied", Json::Bool(true))
                .with("command", Json::Str(kind.to_string()))
                .with("effective_at", Json::Num(at))
        };
        let rejected = |msg: &str| ApiError::BadRequest(msg.to_string());
        // Route a study-scoped command to its owning shard verbatim;
        // the shard's own CommandSink supplies the ack/error bytes.
        let route = |study: &str, c: &ApiCommand| -> Option<Result<Json, ApiError>> {
            let slot = *self.slot_of.get(study)?;
            let shard = self.plan.owner_of(slot)?;
            let c2 = c.clone();
            Some(self.sup.run_on(shard, move |mp| mp.command(&c2)))
        };
        match c {
            ApiCommand::SubmitStudy { spec, at } => {
                let spec = StudySpec::from_json(spec, self.names.len())
                    .map_err(|e| ApiError::BadRequest(format!("bad study spec: {e:#}")))?;
                // Refuse what the scheduler would refuse *now*, before
                // parking it in the queue.
                if !valid_study_name(&spec.name)
                    || spec.quota == 0
                    || !(spec.priority.is_finite() && spec.priority > 0.0)
                    || self.slot_of.contains_key(&spec.name)
                {
                    return Err(rejected(REJECT));
                }
                let name = spec.name.clone();
                let requested = at.unwrap_or(self.cached_now);
                match self.queue.submit(spec, requested) {
                    Admission::Spilled => {
                        // Deferred admission: parked on the spill list,
                        // retried as the queue drains.
                        Ok(ack(c.name(), requested).with("spilled", Json::Bool(true)))
                    }
                    Admission::Queued if requested > self.cached_now => {
                        // Future-dated: admitted at the barrier that
                        // reaches its requested time.
                        Ok(ack(c.name(), requested).with("queued", Json::Bool(true)))
                    }
                    Admission::Queued => {
                        // Due now: drain everything due (arrival order)
                        // and answer for this entry.
                        let mut effective = None;
                        for sub in self.queue.drain_ready(self.cached_now) {
                            let ours = sub.spec.name == name;
                            match self.admit(sub.spec, sub.at) {
                                Ok(t) if ours => effective = Some(t),
                                Err(e) if ours => return Err(e),
                                Ok(_) => {}
                                Err(_) => self.rejected += 1,
                            }
                        }
                        let at = effective.ok_or_else(|| rejected(REJECT))?;
                        Ok(ack(c.name(), at))
                    }
                }
            }
            ApiCommand::PauseStudy { study }
            | ApiCommand::ResumeStudy { study }
            | ApiCommand::StopStudy { study } => route(study, c)
                .unwrap_or_else(|| Err(rejected("unknown or finished study"))),
            ApiCommand::SetQuota { study, quota, .. } => {
                let msg = "rejected (unknown study, quota does not fit, or priority ≤ 0)";
                let Some(&slot) = self.slot_of.get(study) else {
                    return Err(rejected(msg));
                };
                let old = self.plan.slot_quota(slot).unwrap_or(0);
                if let Some(q) = quota {
                    // The ledger is the global arbiter: a quota change
                    // must fit beside every other shard's reservations,
                    // not just this shard's.
                    if !self.quota.adjust(study, *q) {
                        return Err(rejected(msg));
                    }
                }
                let res = route(study, c).unwrap_or_else(|| Err(rejected(msg)));
                match &res {
                    Ok(_) => {
                        if let Some(q) = quota {
                            self.plan.set_slot_quota(slot, *q);
                        }
                    }
                    Err(_) => {
                        // Shard refused (e.g. bad priority): unwind the
                        // ledger to the old reservation.
                        if quota.is_some() {
                            let _ = self.quota.adjust(study, old);
                        }
                    }
                }
                res
            }
            ApiCommand::PauseSession { study, .. } => {
                let study = study.as_deref().ok_or_else(|| {
                    rejected("session commands need a 'study' on a multi-study run")
                })?;
                route(study, c)
                    .unwrap_or_else(|| Err(rejected("session is not live in that study")))
            }
            ApiCommand::ResumeSession { study, .. } => {
                let study = study.as_deref().ok_or_else(|| {
                    rejected("session commands need a 'study' on a multi-study run")
                })?;
                route(study, c)
                    .unwrap_or_else(|| Err(rejected("session is not paused in that study")))
            }
            ApiCommand::StopSession { study, .. } => {
                let study = study.as_deref().ok_or_else(|| {
                    rejected("session commands need a 'study' on a multi-study run")
                })?;
                route(study, c).unwrap_or_else(|| {
                    Err(rejected("session is not live or paused in that study"))
                })
            }
            ApiCommand::Submit { .. } => Err(ApiError::NotFound(
                "single-study command; use 'submit_study' on a multi-study run".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn study_json(name: &str, quota: usize, seed: u64) -> String {
        format!(
            r#"{{"name": "{name}", "quota": {quota}, "config": {{
              "h_params": {{
                "lr": {{"parameters": [0.005, 0.09], "distribution": "log_uniform",
                        "type": "float", "p_range": [0.001, 0.2]}}
              }},
              "measure": "test/accuracy", "order": "descending", "step": 10,
              "population": 3, "tune": {{"random": {{}}}},
              "termination": {{"max_session_number": 5}},
              "model": "surrogate:resnet", "max_epochs": 40, "max_gpus": 2,
              "seed": {seed}
            }}}}"#
        )
    }

    fn manifest(n: usize, gpus: usize) -> StudyManifest {
        let studies: Vec<String> = (0..n).map(|i| study_json(&format!("s{i}"), 2, 100 + i as u64)).collect();
        StudyManifest::from_json_str(&format!(
            r#"{{"cluster_gpus": {gpus}, "borrow": false, "studies": [{}]}}"#,
            studies.join(",")
        ))
        .unwrap()
    }

    fn factory() -> TrainerFactory {
        Arc::new(chopt_core::trainer::surrogate::default_multi_factory)
    }

    #[test]
    fn sharded_run_merges_all_studies_and_finishes() {
        let mut fan = FanoutSource::new(
            manifest(4, 8),
            factory(),
            FanoutConfig { shards: 2, ..FanoutConfig::default() },
        )
        .unwrap();
        fan.run_to_completion(5_000.0);
        assert!(fan.is_done());
        let studies = fan.query(&ApiQuery::Studies).unwrap();
        assert_eq!(num(&studies, "count") as usize, 4);
        let names: Vec<&str> = studies
            .get("studies")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|r| r.get("study").and_then(|v| v.as_str()).unwrap())
            .collect();
        // Merged directory interleaves back into manifest order.
        assert_eq!(names, ["s0", "s1", "s2", "s3"]);
        let status = fan.query(&ApiQuery::Status).unwrap();
        assert_eq!(status.get("done"), Some(&Json::Bool(true)));
        // Per-study queries route to the owning shard.
        for n in ["s0", "s1", "s2", "s3"] {
            let lb = fan
                .query(&ApiQuery::StudyLeaderboard { study: n.into(), k: 3 })
                .unwrap();
            assert_eq!(lb.get("study").and_then(|v| v.as_str()), Some(n));
            assert_eq!(num(&lb, "t"), fan.now());
        }
        let err = fan
            .query(&ApiQuery::StudyLeaderboard { study: "nope".into(), k: 3 })
            .unwrap_err();
        assert!(matches!(err, ApiError::NotFound(_)));
    }

    #[test]
    fn sharded_docs_match_single_scheduler_bytes() {
        let m = manifest(4, 8);
        let mut single = MultiPlatform::new(m.clone(), |study, id| {
            chopt_core::trainer::surrogate::default_multi_factory(study, id)
        });
        single.run_to_completion(5_000.0);
        for shards in [1usize, 3] {
            let mut fan = FanoutSource::new(
                m.clone(),
                factory(),
                FanoutConfig { shards, ..FanoutConfig::default() },
            )
            .unwrap();
            fan.run_to_completion(5_000.0);
            for q in [ApiQuery::FairShare, ApiQuery::Studies] {
                assert_eq!(
                    fan.query(&q).unwrap().to_string_compact(),
                    single.query(&q).unwrap().to_string_compact(),
                    "{q:?} diverged at {shards} shards"
                );
            }
        }
    }

    #[test]
    fn submission_command_admits_through_the_queue() {
        let mut fan = FanoutSource::new(
            manifest(2, 8),
            factory(),
            FanoutConfig { shards: 2, ..FanoutConfig::default() },
        )
        .unwrap();
        fan.advance(50.0);
        let spec = chopt_core::util::json::parse(&study_json("late", 2, 777)).unwrap();
        let ack = fan
            .command(&ApiCommand::SubmitStudy { spec: spec.clone(), at: None })
            .unwrap();
        assert_eq!(ack.get("applied"), Some(&Json::Bool(true)));
        assert!(ack.get("queued").is_none(), "due-now submission admits immediately");
        // Duplicate name is refused with the scheduler's message.
        let err = fan
            .command(&ApiCommand::SubmitStudy { spec, at: None })
            .unwrap_err();
        assert!(matches!(err, ApiError::BadRequest(ref m) if m == REJECT));
        fan.run_to_completion(5_000.0);
        let studies = fan.query(&ApiQuery::Studies).unwrap();
        assert_eq!(num(&studies, "count") as usize, 3);
        let (_, _, admitted, _, rejected) = fan.queue_stats();
        assert_eq!((admitted, rejected), (1, 0));
    }

    #[test]
    fn at_event_scrubs_to_barrier_marks() {
        let mut fan = FanoutSource::new(
            manifest(3, 6),
            factory(),
            FanoutConfig { shards: 2, ..FanoutConfig::default() },
        )
        .unwrap();
        fan.run_to_completion(500.0);
        let marks = fan.barrier_marks();
        assert!(marks.len() >= 2);
        let (mid_events, _) = marks[marks.len() / 2];
        let (eff, doc) = fan.query_at(&ApiQuery::Studies, mid_events).unwrap();
        assert_eq!(eff, mid_events);
        assert!(num(&doc, "count") as usize <= 3);
        // Scrubbing to the final mark reproduces the live document.
        let (last_events, _) = *marks.last().unwrap();
        let (eff, doc) = fan.query_at(&ApiQuery::Studies, last_events + 10).unwrap();
        assert_eq!(eff, last_events);
        assert_eq!(
            doc.to_string_compact(),
            fan.query(&ApiQuery::Studies).unwrap().to_string_compact()
        );
    }

    #[test]
    fn composite_snapshot_restores_by_replay() {
        let m = manifest(3, 6);
        let mut fan = FanoutSource::new(
            m,
            factory(),
            FanoutConfig { shards: 2, ..FanoutConfig::default() },
        )
        .unwrap();
        fan.run_to_completion(5_000.0);
        let snap = fan.snapshot_json();
        assert_eq!(snap.get("kind").and_then(|v| v.as_str()), Some("sharded_multi_study"));
        let back = FanoutSource::restore_doc(
            &snap,
            factory(),
            FanoutConfig { shards: 2, ..FanoutConfig::default() },
        )
        .unwrap();
        assert_eq!(back.study_names(), fan.study_names());
        assert_eq!(back.generation(), fan.generation());
        for q in [ApiQuery::FairShare, ApiQuery::Studies, ApiQuery::Status] {
            assert_eq!(
                back.query(&q).unwrap().to_string_compact(),
                fan.query(&q).unwrap().to_string_compact(),
                "{q:?} diverged after restore"
            );
        }
    }
}
