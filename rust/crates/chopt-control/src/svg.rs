//! Tiny SVG document builder shared by all renderers.

/// SVG document accumulator.
#[derive(Debug, Clone)]
pub struct Svg {
    pub width: f64,
    pub height: f64,
    body: String,
}

impl Svg {
    pub fn new(width: f64, height: f64) -> Svg {
        Svg {
            width,
            height,
            body: String::new(),
        }
    }

    pub fn line(&mut self, x1: f64, y1: f64, x2: f64, y2: f64, stroke: &str, width: f64) {
        self.body.push_str(&format!(
            r#"<line x1="{x1:.2}" y1="{y1:.2}" x2="{x2:.2}" y2="{y2:.2}" stroke="{stroke}" stroke-width="{width}"/>"#
        ));
    }

    /// Polyline through points.
    pub fn polyline(&mut self, pts: &[(f64, f64)], stroke: &str, width: f64, opacity: f64) {
        if pts.is_empty() {
            return;
        }
        let path: String = pts
            .iter()
            .map(|(x, y)| format!("{x:.2},{y:.2}"))
            .collect::<Vec<_>>()
            .join(" ");
        self.body.push_str(&format!(
            r#"<polyline points="{path}" fill="none" stroke="{stroke}" stroke-width="{width}" stroke-opacity="{opacity:.3}"/>"#
        ));
    }

    pub fn circle(&mut self, cx: f64, cy: f64, r: f64, fill: &str, opacity: f64) {
        self.body.push_str(&format!(
            r#"<circle cx="{cx:.2}" cy="{cy:.2}" r="{r:.2}" fill="{fill}" fill-opacity="{opacity:.3}"/>"#
        ));
    }

    pub fn rect(&mut self, x: f64, y: f64, w: f64, h: f64, fill: &str) {
        self.body.push_str(&format!(
            r#"<rect x="{x:.2}" y="{y:.2}" width="{w:.2}" height="{h:.2}" fill="{fill}"/>"#
        ));
    }

    pub fn text(&mut self, x: f64, y: f64, size: f64, content: &str) {
        let escaped = content
            .replace('&', "&amp;")
            .replace('<', "&lt;")
            .replace('>', "&gt;");
        self.body.push_str(&format!(
            r#"<text x="{x:.2}" y="{y:.2}" font-size="{size:.1}" font-family="monospace">{escaped}</text>"#
        ));
    }

    pub fn finish(&self) -> String {
        format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{:.0}" height="{:.0}" viewBox="0 0 {:.0} {:.0}"><rect width="100%" height="100%" fill="white"/>{}</svg>"#,
            self.width, self.height, self.width, self.height, self.body
        )
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.finish())
    }
}

/// A categorical color cycle (run colors in Fig. 7: purple, red, ...).
pub const PALETTE: [&str; 8] = [
    "#7b4fa6", "#d62728", "#2ca02c", "#1f77b4", "#ff7f0e", "#17becf", "#e377c2", "#8c564b",
];

pub fn color(i: usize) -> &'static str {
    PALETTE[i % PALETTE.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_valid_svg() {
        let mut s = Svg::new(100.0, 50.0);
        s.line(0.0, 0.0, 100.0, 50.0, "#000", 1.0);
        s.circle(10.0, 10.0, 2.0, "red", 0.5);
        s.text(5.0, 5.0, 10.0, "a<b&c");
        let doc = s.finish();
        assert!(doc.starts_with("<svg"));
        assert!(doc.ends_with("</svg>"));
        assert!(doc.contains("&lt;b&amp;c"));
        assert!(doc.contains("<line"));
    }

    #[test]
    fn palette_cycles() {
        assert_eq!(color(0), color(8));
    }
}
