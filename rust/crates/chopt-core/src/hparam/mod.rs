//! Hyperparameter space model (paper §3.4.1).
//!
//! Mirrors the Listing-1 configuration: each parameter has `parameters`
//! (the initial sampling range or category list), a `distribution`, a
//! `type`, and `p_range` (the hard bounds PBT perturbation may explore).
//! Hierarchical spaces are expressed with *conditions* (a child parameter
//! is only active when its parent takes one of the listed values) and
//! *conjunctions* (joint constraints that sampled assignments must
//! satisfy).

mod space;
mod value;

pub use space::{Condition, Conjunction, ParamDef, Space, SpaceError};
pub use value::{Assignment, Dist, ParamType, Value};
