//! Hyperparameter values, types, distributions, and assignments.

use std::fmt;

use crate::util::json::Value as Json;

/// A single hyperparameter value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Float(f64),
    Int(i64),
    Str(String),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            Value::Str(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Value::Float(f) => Json::Num(*f),
            Value::Int(i) => Json::Num(*i as f64),
            Value::Str(s) => Json::Str(s.clone()),
        }
    }

    pub fn from_json(j: &Json, ptype: ParamType) -> Option<Value> {
        match (j, ptype) {
            (Json::Num(n), ParamType::Float) => Some(Value::Float(*n)),
            (Json::Num(n), ParamType::Int) => Some(Value::Int(*n as i64)),
            (Json::Str(s), ParamType::Str) => Some(Value::Str(s.clone())),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Float(v) => write!(f, "{v:.6}"),
            Value::Int(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "{v}"),
        }
    }
}

/// Declared parameter type (`'type'` in Listing 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamType {
    Float,
    Int,
    Str,
}

impl ParamType {
    pub fn parse(s: &str) -> Option<ParamType> {
        match s {
            "float" => Some(ParamType::Float),
            "int" => Some(ParamType::Int),
            "str" | "string" => Some(ParamType::Str),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ParamType::Float => "float",
            ParamType::Int => "int",
            ParamType::Str => "str",
        }
    }
}

/// Sampling distribution (`'distribution'` in Listing 1).
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    Uniform,
    LogUniform,
    /// Gaussian clipped to the sampling range.
    Gaussian,
    Categorical,
}

impl Dist {
    pub fn parse(s: &str) -> Option<Dist> {
        match s {
            "uniform" => Some(Dist::Uniform),
            // The paper's listing spells it 'log\_uniform' (LaTeX escape).
            "log_uniform" | "log\\_uniform" | "loguniform" => Some(Dist::LogUniform),
            "gaussian" | "normal" => Some(Dist::Gaussian),
            "categorical" => Some(Dist::Categorical),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dist::Uniform => "uniform",
            Dist::LogUniform => "log_uniform",
            Dist::Gaussian => "gaussian",
            Dist::Categorical => "categorical",
        }
    }
}

/// One sampled configuration: ordered (name, value) pairs.  Order follows
/// the space definition so viz axes and interchange stay stable.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Assignment {
    pairs: Vec<(String, Value)>,
}

impl Assignment {
    pub fn new() -> Assignment {
        Assignment { pairs: Vec::new() }
    }

    pub fn set(&mut self, name: &str, value: Value) {
        if let Some(slot) = self.pairs.iter_mut().find(|(n, _)| n == name) {
            slot.1 = value;
        } else {
            self.pairs.push((name.to_string(), value));
        }
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.pairs.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub fn f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.as_f64())
    }

    pub fn i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(|v| v.as_i64())
    }

    pub fn str(&self, name: &str) -> Option<&str> {
        self.get(name).and_then(|v| v.as_str())
    }

    pub fn remove(&mut self, name: &str) -> Option<Value> {
        let idx = self.pairs.iter().position(|(n, _)| n == name)?;
        Some(self.pairs.remove(idx).1)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.get(name).is_some()
    }

    pub fn iter(&self) -> impl Iterator<Item = &(String, Value)> {
        self.pairs.iter()
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in &self.pairs {
            obj.set(k, v.to_json());
        }
        obj
    }

    /// Compact one-line rendering for logs/leaderboards.
    pub fn render(&self) -> String {
        self.pairs
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

impl FromIterator<(String, Value)> for Assignment {
    fn from_iter<T: IntoIterator<Item = (String, Value)>>(iter: T) -> Self {
        let mut a = Assignment::new();
        for (k, v) in iter {
            a.set(&k, v);
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Float(2.0).as_i64(), Some(2));
        assert_eq!(Value::Float(2.5).as_i64(), None);
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Str("relu".into()).as_str(), Some("relu"));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
    }

    #[test]
    fn dist_parse_accepts_paper_spelling() {
        assert_eq!(Dist::parse("log\\_uniform"), Some(Dist::LogUniform));
        assert_eq!(Dist::parse("log_uniform"), Some(Dist::LogUniform));
        assert_eq!(Dist::parse("nope"), None);
    }

    #[test]
    fn assignment_set_get_replace() {
        let mut a = Assignment::new();
        a.set("lr", Value::Float(0.1));
        a.set("act", Value::Str("relu".into()));
        a.set("lr", Value::Float(0.2)); // replace
        assert_eq!(a.len(), 2);
        assert_eq!(a.f64("lr"), Some(0.2));
        assert_eq!(a.str("act"), Some("relu"));
        assert!(a.render().contains("lr=0.2"));
    }

    #[test]
    fn assignment_json_roundtrip_values() {
        let mut a = Assignment::new();
        a.set("depth", Value::Int(20));
        let j = a.to_json();
        assert_eq!(j.get("depth").unwrap().as_i64(), Some(20));
    }
}
