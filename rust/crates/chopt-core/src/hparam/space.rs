//! Hyperparameter space: definitions, sampling, PBT perturbation,
//! conditions (hierarchical spaces) and conjunctions (joint constraints).

use crate::util::json::Value as Json;
use crate::util::rng::Rng;

use super::value::{Assignment, Dist, ParamType, Value};

#[derive(Debug, thiserror::Error)]
pub enum SpaceError {
    #[error("parameter '{0}': {1}")]
    BadParam(String, String),
    #[error("condition references unknown parameter '{0}'")]
    UnknownParam(String),
    #[error("could not satisfy conjunctions after {0} resamples")]
    Unsatisfiable(usize),
}

/// One tunable parameter (Listing 1 entry).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub ptype: ParamType,
    pub dist: Dist,
    /// Initial sampling range `[lo, hi]` (numeric) or category list.
    pub parameters: Vec<Value>,
    /// Hard exploration bounds for perturbation; empty = use `parameters`.
    pub p_range: Vec<f64>,
}

impl ParamDef {
    /// Numeric sampling bounds (lo, hi) from `parameters`.
    fn sample_bounds(&self) -> Option<(f64, f64)> {
        if self.parameters.len() == 2 {
            let lo = self.parameters[0].as_f64()?;
            let hi = self.parameters[1].as_f64()?;
            Some((lo.min(hi), lo.max(hi)))
        } else {
            None
        }
    }

    /// Hard clamp bounds (p_range, falling back to the sampling range).
    pub fn hard_bounds(&self) -> Option<(f64, f64)> {
        if self.p_range.len() == 2 {
            Some((
                self.p_range[0].min(self.p_range[1]),
                self.p_range[0].max(self.p_range[1]),
            ))
        } else {
            self.sample_bounds()
        }
    }

    /// Draw an initial value.
    pub fn sample(&self, rng: &mut Rng) -> Value {
        match (&self.dist, self.ptype) {
            (Dist::Categorical, _) => {
                debug_assert!(!self.parameters.is_empty());
                self.parameters[rng.index(self.parameters.len())].clone()
            }
            (dist, ParamType::Float) => {
                let (lo, hi) = self.sample_bounds().expect("numeric bounds");
                Value::Float(sample_numeric(dist, lo, hi, rng))
            }
            (dist, ParamType::Int) => {
                let (lo, hi) = self.sample_bounds().expect("numeric bounds");
                let v = sample_numeric(dist, lo, hi + 1.0 - 1e-9, rng);
                Value::Int((v.floor() as i64).clamp(lo as i64, hi as i64))
            }
            (_, ParamType::Str) => {
                // Non-categorical string spaces degenerate to choice.
                self.parameters[rng.index(self.parameters.len())].clone()
            }
        }
    }

    /// PBT "perturb" explore: scale numeric values by one of `factors`,
    /// clamp to hard bounds; categorical values (of any type) resample
    /// with prob 0.25.
    pub fn perturb(&self, current: &Value, rng: &mut Rng, factors: &[f64]) -> Value {
        if self.dist == Dist::Categorical {
            return if rng.bool(0.25) {
                self.parameters[rng.index(self.parameters.len())].clone()
            } else {
                current.clone()
            };
        }
        match (current, self.ptype) {
            (Value::Float(f), _) => {
                let factor = *rng.choose(factors);
                let (lo, hi) = self.hard_bounds().expect("numeric bounds");
                Value::Float((f * factor).clamp(lo, hi))
            }
            (Value::Int(i), _) => {
                let factor = *rng.choose(factors);
                let (lo, hi) = self.hard_bounds().expect("numeric bounds");
                let mut v = ((*i as f64) * factor).round() as i64;
                // Small ints stagnate under multiplicative perturbation:
                // round(1 × 1.2) = round(1 × 0.8) = 1, so values like a
                // batch size of 1–2 never move.  Guarantee a ±1 step in
                // the factor's direction whenever rounding swallowed it;
                // the hard bounds still win at the edges.
                if v == *i && factor != 1.0 {
                    v = if factor > 1.0 { *i + 1 } else { *i - 1 };
                }
                let (ilo, ihi) = (lo.ceil() as i64, hi.floor() as i64);
                Value::Int(v.clamp(ilo, ihi.max(ilo)))
            }
            (Value::Str(_), _) => {
                if rng.bool(0.25) {
                    self.parameters[rng.index(self.parameters.len())].clone()
                } else {
                    current.clone()
                }
            }
        }
    }

    /// Validate structural consistency.
    pub fn validate(&self) -> Result<(), SpaceError> {
        let bad = |m: &str| Err(SpaceError::BadParam(self.name.clone(), m.to_string()));
        if self.parameters.is_empty() {
            return bad("empty 'parameters'");
        }
        match self.dist {
            Dist::Categorical => {}
            _ => {
                if self.ptype == ParamType::Str {
                    return bad("non-categorical distribution over strings");
                }
                if self.parameters.len() != 2 {
                    return bad("numeric 'parameters' must be [lo, hi]");
                }
                let (lo, hi) = self.sample_bounds().ok_or_else(|| {
                    SpaceError::BadParam(self.name.clone(), "non-numeric bounds".into())
                })?;
                if !(lo <= hi) {
                    return bad("lo > hi");
                }
                if self.dist == Dist::LogUniform && lo <= 0.0 {
                    return bad("log_uniform requires lo > 0");
                }
                if self.p_range.len() != 0 && self.p_range.len() != 2 {
                    return bad("p_range must be [] or [lo, hi]");
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with(
                "parameters",
                Json::Arr(self.parameters.iter().map(|v| v.to_json()).collect()),
            )
            .with("distribution", Json::Str(self.dist.name().to_string()))
            .with("type", Json::Str(self.ptype.name().to_string()))
            .with("p_range", Json::from_f64_slice(&self.p_range))
    }

    pub fn from_json(name: &str, j: &Json) -> Result<ParamDef, SpaceError> {
        let err = |m: &str| SpaceError::BadParam(name.to_string(), m.to_string());
        let dist_s = j
            .get("distribution")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err("missing 'distribution'"))?;
        let dist = Dist::parse(dist_s).ok_or_else(|| err("unknown distribution"))?;
        let ptype_s = j
            .get("type")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err("missing 'type'"))?;
        let ptype = ParamType::parse(ptype_s).ok_or_else(|| err("unknown type"))?;
        let parameters = j
            .get("parameters")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| err("missing 'parameters'"))?
            .iter()
            .map(|v| Value::from_json(v, ptype).ok_or_else(|| err("bad parameter value")))
            .collect::<Result<Vec<_>, _>>()?;
        let p_range = match j.get("p_range").and_then(|v| v.as_arr()) {
            None => Vec::new(),
            Some(items) => items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| err("non-numeric p_range")))
                .collect::<Result<Vec<_>, _>>()?,
        };
        let def = ParamDef {
            name: name.to_string(),
            ptype,
            dist,
            parameters,
            p_range,
        };
        def.validate()?;
        Ok(def)
    }
}

fn sample_numeric(dist: &Dist, lo: f64, hi: f64, rng: &mut Rng) -> f64 {
    match dist {
        Dist::Uniform => rng.uniform(lo, hi),
        Dist::LogUniform => rng.log_uniform(lo.max(1e-300), hi),
        Dist::Gaussian => {
            // Mean at the center, std spanning the range; clipped.
            let mean = 0.5 * (lo + hi);
            let std = (hi - lo) / 4.0;
            rng.gaussian(mean, std).clamp(lo, hi)
        }
        Dist::Categorical => unreachable!("categorical handled by caller"),
    }
}

/// Hierarchical-space condition: `child` is active iff `parent`'s value is
/// in `values` (paper §3.4.1: momentum only exists when optimizer == sgd).
#[derive(Debug, Clone, PartialEq)]
pub struct Condition {
    pub child: String,
    pub parent: String,
    pub values: Vec<Value>,
}

/// Joint constraint: the assignment must satisfy at least one of the
/// listed (param -> allowed values) combinations.
#[derive(Debug, Clone, PartialEq)]
pub struct Conjunction {
    /// (param name, allowed values) — all must hold simultaneously.
    pub clauses: Vec<(String, Vec<Value>)>,
}

impl Conjunction {
    pub fn satisfied(&self, a: &Assignment) -> bool {
        self.clauses.iter().all(|(name, allowed)| {
            a.get(name)
                .map(|v| allowed.iter().any(|av| values_match(av, v)))
                .unwrap_or(true) // inactive params don't violate
        })
    }
}

fn values_match(a: &Value, b: &Value) -> bool {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => (x - y).abs() < 1e-12,
        _ => a == b,
    }
}

/// The full hyperparameter space.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Space {
    pub defs: Vec<ParamDef>,
    pub conditions: Vec<Condition>,
    pub conjunctions: Vec<Conjunction>,
}

impl Space {
    pub fn def(&self, name: &str) -> Option<&ParamDef> {
        self.defs.iter().find(|d| d.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.defs.iter().map(|d| d.name.as_str()).collect()
    }

    pub fn validate(&self) -> Result<(), SpaceError> {
        for d in &self.defs {
            d.validate()?;
        }
        for c in &self.conditions {
            for p in [&c.child, &c.parent] {
                if self.def(p).is_none() {
                    return Err(SpaceError::UnknownParam(p.clone()));
                }
            }
        }
        for cj in &self.conjunctions {
            for (name, _) in &cj.clauses {
                if self.def(name).is_none() {
                    return Err(SpaceError::UnknownParam(name.clone()));
                }
            }
        }
        Ok(())
    }

    /// Is `name` active under `a` (all its conditions satisfied)?
    pub fn active(&self, name: &str, a: &Assignment) -> bool {
        self.conditions
            .iter()
            .filter(|c| c.child == name)
            .all(|c| {
                a.get(&c.parent)
                    .map(|v| c.values.iter().any(|cv| values_match(cv, v)))
                    .unwrap_or(false)
            })
    }

    /// Sample a full assignment: iterate to fixpoint so parents activate
    /// children regardless of definition order; resample until all
    /// conjunctions hold.
    pub fn sample(&self, rng: &mut Rng) -> Result<Assignment, SpaceError> {
        const MAX_TRIES: usize = 1000;
        for _ in 0..MAX_TRIES {
            let mut a = Assignment::new();
            loop {
                let mut grew = false;
                for d in &self.defs {
                    if !a.contains(&d.name) && self.active(&d.name, &a) {
                        a.set(&d.name, d.sample(rng));
                        grew = true;
                    }
                }
                if !grew {
                    break;
                }
            }
            if self.conjunctions.iter().all(|c| c.satisfied(&a)) {
                return Ok(a);
            }
        }
        Err(SpaceError::Unsatisfiable(MAX_TRIES))
    }

    /// PBT explore: perturb every active parameter of `a`.
    pub fn perturb(&self, a: &Assignment, rng: &mut Rng, factors: &[f64]) -> Assignment {
        let mut out = Assignment::new();
        for d in &self.defs {
            if let Some(v) = a.get(&d.name) {
                out.set(&d.name, d.perturb(v, rng, factors));
            }
        }
        out
    }

    /// PBT resample explore: fresh draw for every active parameter.
    pub fn resample(&self, a: &Assignment, rng: &mut Rng) -> Assignment {
        let mut out = Assignment::new();
        for d in &self.defs {
            if a.contains(&d.name) {
                out.set(&d.name, d.sample(rng));
            }
        }
        out
    }

    /// Encode an assignment as a feature vector in [0,1]^n (viz cluster
    /// view, PCA).  Numeric: normalized to hard bounds (log scale for
    /// log-uniform); categorical: index / (k-1); missing (inactive): -1.
    pub fn encode(&self, a: &Assignment) -> Vec<f64> {
        self.defs
            .iter()
            .map(|d| match a.get(&d.name) {
                None => -1.0,
                Some(v) => match (&d.dist, v) {
                    (Dist::Categorical, v) => {
                        let k = d.parameters.len().max(2);
                        let idx = d
                            .parameters
                            .iter()
                            .position(|p| values_match(p, v))
                            .unwrap_or(0);
                        idx as f64 / (k - 1) as f64
                    }
                    (Dist::LogUniform, v) => {
                        let (lo, hi) = d.hard_bounds().unwrap_or((1e-9, 1.0));
                        let x = v.as_f64().unwrap_or(lo).max(1e-300);
                        ((x.ln() - lo.ln()) / (hi.ln() - lo.ln()).max(1e-12)).clamp(0.0, 1.0)
                    }
                    (_, v) => {
                        let (lo, hi) = d.hard_bounds().unwrap_or((0.0, 1.0));
                        let x = v.as_f64().unwrap_or(lo);
                        ((x - lo) / (hi - lo).max(1e-12)).clamp(0.0, 1.0)
                    }
                },
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut hp = Json::obj();
        for d in &self.defs {
            hp.set(&d.name, d.to_json());
        }
        let conds = self
            .conditions
            .iter()
            .map(|c| {
                Json::obj()
                    .with("child", Json::Str(c.child.clone()))
                    .with("parent", Json::Str(c.parent.clone()))
                    .with(
                        "values",
                        Json::Arr(c.values.iter().map(|v| v.to_json()).collect()),
                    )
            })
            .collect();
        let conjs = self
            .conjunctions
            .iter()
            .map(|c| {
                let mut o = Json::obj();
                for (name, allowed) in &c.clauses {
                    o.set(name, Json::Arr(allowed.iter().map(|v| v.to_json()).collect()));
                }
                o
            })
            .collect();
        Json::obj()
            .with("h_params", hp)
            .with("h_params_conditions", Json::Arr(conds))
            .with("h_params_conjunctions", Json::Arr(conjs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lr_def() -> ParamDef {
        ParamDef {
            name: "lr".into(),
            ptype: ParamType::Float,
            dist: Dist::LogUniform,
            parameters: vec![Value::Float(0.01), Value::Float(0.09)],
            p_range: vec![0.001, 0.1],
        }
    }

    fn depth_def() -> ParamDef {
        ParamDef {
            name: "depth".into(),
            ptype: ParamType::Int,
            dist: Dist::Uniform,
            parameters: vec![Value::Int(5), Value::Int(10)],
            p_range: vec![5.0, 10.0],
        }
    }

    fn act_def() -> ParamDef {
        ParamDef {
            name: "activation".into(),
            ptype: ParamType::Str,
            dist: Dist::Categorical,
            parameters: vec![Value::Str("relu".into()), Value::Str("sigmoid".into())],
            p_range: vec![],
        }
    }

    fn space() -> Space {
        Space {
            defs: vec![lr_def(), depth_def(), act_def()],
            conditions: vec![],
            conjunctions: vec![],
        }
    }

    #[test]
    fn sample_within_bounds() {
        let s = space();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let a = s.sample(&mut rng).unwrap();
            let lr = a.f64("lr").unwrap();
            assert!((0.01..=0.09).contains(&lr), "lr={lr}");
            let d = a.i64("depth").unwrap();
            assert!((5..=10).contains(&d), "depth={d}");
            assert!(["relu", "sigmoid"].contains(&a.str("activation").unwrap()));
        }
    }

    #[test]
    fn int_sampling_covers_endpoints() {
        let s = space();
        let mut rng = Rng::new(2);
        let mut seen = [false; 6];
        for _ in 0..500 {
            let a = s.sample(&mut rng).unwrap();
            seen[(a.i64("depth").unwrap() - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x), "seen={seen:?}");
    }

    #[test]
    fn perturb_respects_p_range() {
        let s = space();
        let mut rng = Rng::new(3);
        let mut a = s.sample(&mut rng).unwrap();
        for _ in 0..300 {
            a = s.perturb(&a, &mut rng, &[0.8, 1.2]);
            let lr = a.f64("lr").unwrap();
            assert!((0.001..=0.1).contains(&lr), "lr={lr} escaped p_range");
            let d = a.i64("depth").unwrap();
            assert!((5..=10).contains(&d));
        }
    }

    #[test]
    fn int_perturb_always_moves_small_values() {
        // Regression: round(i × 0.8/1.2) left small ints (batch size 1–2)
        // frozen forever; a perturbation must step at least ±1 within the
        // hard bounds.
        let d = ParamDef {
            name: "batch".into(),
            ptype: ParamType::Int,
            dist: Dist::Uniform,
            parameters: vec![Value::Int(1), Value::Int(64)],
            p_range: vec![1.0, 64.0],
        };
        let mut rng = Rng::new(11);
        assert_eq!(d.perturb(&Value::Int(2), &mut rng, &[1.2]), Value::Int(3));
        assert_eq!(d.perturb(&Value::Int(2), &mut rng, &[0.8]), Value::Int(1));
        assert_eq!(d.perturb(&Value::Int(1), &mut rng, &[1.2]), Value::Int(2));
        // At the hard bound the bound wins (no escape below lo).
        assert_eq!(d.perturb(&Value::Int(1), &mut rng, &[0.8]), Value::Int(1));
        // Large values keep the multiplicative behavior.
        assert_eq!(d.perturb(&Value::Int(10), &mut rng, &[1.2]), Value::Int(12));
        assert_eq!(d.perturb(&Value::Int(10), &mut rng, &[0.8]), Value::Int(8));
        // A long random walk stays in bounds and is not stuck at 1.
        let mut v = Value::Int(1);
        let mut seen_above_one = false;
        for _ in 0..100 {
            v = d.perturb(&v, &mut rng, &[0.8, 1.2]);
            let i = match &v {
                Value::Int(i) => *i,
                _ => unreachable!(),
            };
            assert!((1..=64).contains(&i), "escaped bounds: {i}");
            seen_above_one |= i > 1;
        }
        assert!(seen_above_one, "walk never left the stagnation point");
    }

    #[test]
    fn conditions_gate_children() {
        let mut s = space();
        s.conditions.push(Condition {
            child: "depth".into(),
            parent: "activation".into(),
            values: vec![Value::Str("relu".into())],
        });
        let mut rng = Rng::new(4);
        let mut saw_active = false;
        let mut saw_inactive = false;
        for _ in 0..200 {
            let a = s.sample(&mut rng).unwrap();
            match a.str("activation").unwrap() {
                "relu" => {
                    assert!(a.contains("depth"));
                    saw_active = true;
                }
                _ => {
                    assert!(!a.contains("depth"));
                    saw_inactive = true;
                }
            }
        }
        assert!(saw_active && saw_inactive);
    }

    #[test]
    fn conjunctions_filter_samples() {
        let mut s = space();
        // Require activation == relu whenever depth >= 5 (i.e. always):
        // effectively forces relu.
        s.conjunctions.push(Conjunction {
            clauses: vec![(
                "activation".into(),
                vec![Value::Str("relu".into())],
            )],
        });
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let a = s.sample(&mut rng).unwrap();
            assert_eq!(a.str("activation"), Some("relu"));
        }
    }

    #[test]
    fn unsatisfiable_conjunction_errors() {
        let mut s = space();
        s.conjunctions.push(Conjunction {
            clauses: vec![("activation".into(), vec![Value::Str("gelu".into())])],
        });
        let mut rng = Rng::new(6);
        assert!(matches!(
            s.sample(&mut rng),
            Err(SpaceError::Unsatisfiable(_))
        ));
    }

    #[test]
    fn validate_catches_bad_defs() {
        let mut d = lr_def();
        d.parameters = vec![Value::Float(0.0), Value::Float(0.1)];
        assert!(d.validate().is_err(), "log_uniform lo=0 must fail");
        let mut d2 = depth_def();
        d2.parameters = vec![Value::Int(10)];
        assert!(d2.validate().is_err(), "single bound must fail");
        let mut s = space();
        s.conditions.push(Condition {
            child: "nope".into(),
            parent: "lr".into(),
            values: vec![],
        });
        assert!(matches!(s.validate(), Err(SpaceError::UnknownParam(_))));
    }

    #[test]
    fn encode_normalizes() {
        let s = space();
        let mut a = Assignment::new();
        a.set("lr", Value::Float(0.1)); // == hard hi
        a.set("depth", Value::Int(5)); // == hard lo
        a.set("activation", Value::Str("sigmoid".into())); // idx 1 of 2
        let e = s.encode(&a);
        assert_eq!(e.len(), 3);
        assert!((e[0] - 1.0).abs() < 1e-9, "lr at hi -> 1.0, got {}", e[0]);
        assert!((e[1] - 0.0).abs() < 1e-9);
        assert!((e[2] - 1.0).abs() < 1e-9);
        // Inactive param encodes -1.
        let mut b = Assignment::new();
        b.set("lr", Value::Float(0.01));
        let eb = s.encode(&b);
        assert_eq!(eb[1], -1.0);
    }

    #[test]
    fn json_roundtrip() {
        let s = space();
        let j = s.to_json();
        let lr = j.path("h_params.lr").unwrap();
        let def = ParamDef::from_json("lr", lr).unwrap();
        assert_eq!(def, lr_def());
    }

    #[test]
    fn gaussian_sampling_clips() {
        let d = ParamDef {
            name: "x".into(),
            ptype: ParamType::Float,
            dist: Dist::Gaussian,
            parameters: vec![Value::Float(-1.0), Value::Float(1.0)],
            p_range: vec![],
        };
        let mut rng = Rng::new(7);
        for _ in 0..500 {
            let v = d.sample(&mut rng).as_f64().unwrap();
            assert!((-1.0..=1.0).contains(&v));
        }
    }
}
