//! CHOPT session configuration (paper §3.4, Listing 1).
//!
//! A configuration is a JSON document with the exact shape of the paper's
//! python-dict listing: `h_params` + `h_params_conditions` +
//! `h_params_conjunctions` define the space; `measure`/`order` define the
//! goal; `step` controls early stopping (−1 disables); `population`,
//! `tune` and `termination` select and bound the optimization algorithm.
//! CHOPT needs *no user-code modification*: the model side only has to
//! accept hyperparameters as inputs (our AOT train-steps take them as
//! scalar runtime arguments).

mod chopt_config;

pub use chopt_config::{
    ChoptConfig, ConfigError, Order, Termination, TuneAlgo, DEFAULT_STOP_RATIO,
    LISTING1_EXAMPLE,
};
