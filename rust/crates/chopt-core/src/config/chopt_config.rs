//! Parsing/validation of the Listing-1 configuration document.

use crate::hparam::{Condition, Conjunction, ParamDef, Space, Value as HValue};
use crate::util::json::{self, Value as Json};

/// Default fraction of exited sessions that go to the stop pool (the rest
/// go to the dead pool) — paper §3.2.1 `stop ratio`.
pub const DEFAULT_STOP_RATIO: f64 = 0.5;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config json: {0}")]
    Json(#[from] json::JsonError),
    #[error("config space: {0}")]
    Space(#[from] crate::hparam::SpaceError),
    #[error("config field '{0}': {1}")]
    Field(String, String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

fn ferr(field: &str, msg: &str) -> ConfigError {
    ConfigError::Field(field.to_string(), msg.to_string())
}

/// Optimization goal direction for `measure`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    /// Higher is better (accuracy).
    Descending,
    /// Lower is better (loss).
    Ascending,
}

impl Order {
    pub fn parse(s: &str) -> Option<Order> {
        match s {
            "descending" | "desc" | "max" => Some(Order::Descending),
            "ascending" | "asc" | "min" => Some(Order::Ascending),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Order::Descending => "descending",
            Order::Ascending => "ascending",
        }
    }

    /// Is `a` strictly better than `b` under this order?
    pub fn better(self, a: f64, b: f64) -> bool {
        match self {
            Order::Descending => a > b,
            Order::Ascending => a < b,
        }
    }

    /// Worst possible score under this order.
    pub fn worst(self) -> f64 {
        match self {
            Order::Descending => f64::NEG_INFINITY,
            Order::Ascending => f64::INFINITY,
        }
    }
}

/// `tune` section: which HyperOpt algorithm hosts this session.
#[derive(Debug, Clone, PartialEq)]
pub enum TuneAlgo {
    /// Random search; early stopping governed by `step` (−1 = off).
    Random,
    /// Population Based Training (Jaderberg et al., 2017).
    Pbt {
        /// "truncation" | "binary_tournament"
        exploit: String,
        /// "perturb" | "resample"
        explore: String,
    },
    /// Hyperband (Li et al., 2017).
    Hyperband {
        /// Maximum resource (epochs) per configuration — R.
        max_resource: usize,
        /// Downsampling rate — eta.
        eta: usize,
    },
    /// Asynchronous Successive Halving (extension; future-work hook).
    Asha {
        min_resource: usize,
        max_resource: usize,
        eta: usize,
    },
}

impl TuneAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            TuneAlgo::Random => "random",
            TuneAlgo::Pbt { .. } => "pbt",
            TuneAlgo::Hyperband { .. } => "hyperband",
            TuneAlgo::Asha { .. } => "asha",
        }
    }
}

/// `termination` section: first condition reached stops the session.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Termination {
    /// Wall/virtual-time limit in hours.
    pub time_hours: Option<f64>,
    /// Maximum number of NSML sessions (models) ever created.
    pub max_session_number: Option<usize>,
    /// Stop as soon as the best score passes this threshold.
    pub performance_threshold: Option<f64>,
}

impl Termination {
    pub fn is_unbounded(&self) -> bool {
        self.time_hours.is_none()
            && self.max_session_number.is_none()
            && self.performance_threshold.is_none()
    }
}

/// A full CHOPT session configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ChoptConfig {
    pub space: Space,
    /// Metric key, e.g. "test/accuracy" or "test/em".
    pub measure: String,
    pub order: Order,
    /// Early-stopping check interval in epochs; −1 disables early stopping.
    pub step: i64,
    /// Population size (parallel NSML sessions).
    pub population: usize,
    pub tune: TuneAlgo,
    pub termination: Termination,
    /// Fraction of exited sessions routed to the stop pool (vs dead pool).
    pub stop_ratio: f64,
    /// Model selector: an AOT variant name (`ic_d2_w1`, `qa_bidaf`) or a
    /// surrogate family (`surrogate:resnet`, `surrogate:wrn`, ...).
    pub model: String,
    /// Maximum epochs a single NSML session trains (paper uses 300).
    pub max_epochs: usize,
    /// GPUs a single NSML session occupies.
    pub gpus_per_session: usize,
    /// Resource limit for this CHOPT session (live-pool cap), before
    /// Stop-and-Go adjustments.
    pub max_gpus: usize,
    /// Optional model-size constraint (Table 3): trials whose parameter
    /// count exceeds this are rejected before launch.
    pub max_params: Option<u64>,
    pub seed: u64,
}

impl ChoptConfig {
    pub fn early_stopping_enabled(&self) -> bool {
        self.step > 0
    }

    /// Parse from JSON text (the Listing-1 document).
    pub fn from_json_str(text: &str) -> Result<ChoptConfig, ConfigError> {
        let doc = json::parse(text)?;
        Self::from_json(&doc)
    }

    pub fn load(path: &str) -> Result<ChoptConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json(doc: &Json) -> Result<ChoptConfig, ConfigError> {
        // --- space ---------------------------------------------------
        let hp = doc.require("h_params")?;
        let mut defs = Vec::new();
        for (name, pj) in hp
            .as_obj()
            .ok_or_else(|| ferr("h_params", "must be an object"))?
        {
            defs.push(ParamDef::from_json(name, pj)?);
        }
        let conditions = match doc.get("h_params_conditions").and_then(|v| v.as_arr()) {
            None => Vec::new(),
            Some(items) => items
                .iter()
                .map(|c| parse_condition(c, &defs))
                .collect::<Result<Vec<_>, _>>()?,
        };
        let conjunctions = match doc.get("h_params_conjunctions").and_then(|v| v.as_arr()) {
            None => Vec::new(),
            Some(items) => items
                .iter()
                .map(|c| parse_conjunction(c, &defs))
                .collect::<Result<Vec<_>, _>>()?,
        };
        let space = Space {
            defs,
            conditions,
            conjunctions,
        };
        space.validate()?;

        // --- goal ----------------------------------------------------
        let measure = doc
            .require("measure")?
            .as_str()
            .ok_or_else(|| ferr("measure", "must be a string"))?
            .to_string();
        let order_s = doc
            .require("order")?
            .as_str()
            .ok_or_else(|| ferr("order", "must be a string"))?;
        let order = Order::parse(order_s)
            .ok_or_else(|| ferr("order", "expected 'descending' or 'ascending'"))?;

        // --- loop shape ----------------------------------------------
        let step = doc
            .get("step")
            .map(|v| v.as_i64().ok_or_else(|| ferr("step", "must be an int")))
            .transpose()?
            .unwrap_or(-1);
        if step == 0 || step < -1 {
            return Err(ferr("step", "must be a positive epoch interval or -1"));
        }
        let population = doc
            .get("population")
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| ferr("population", "must be a positive int"))
            })
            .transpose()?
            .unwrap_or(5);
        if population == 0 {
            return Err(ferr("population", "must be >= 1"));
        }

        let tune = parse_tune(doc.require("tune")?)?;
        let termination = parse_termination(doc.get("termination"))?;
        let stop_ratio = doc
            .get("stop_ratio")
            .map(|v| v.as_f64().ok_or_else(|| ferr("stop_ratio", "must be a number")))
            .transpose()?
            .unwrap_or(DEFAULT_STOP_RATIO);
        if !(0.0..=1.0).contains(&stop_ratio) {
            return Err(ferr("stop_ratio", "must be in [0, 1]"));
        }

        // --- platform ------------------------------------------------
        let model = doc
            .get("model")
            .and_then(|v| v.as_str())
            .unwrap_or("surrogate:resnet")
            .to_string();
        let max_epochs = doc
            .get("max_epochs")
            .map(|v| v.as_usize().ok_or_else(|| ferr("max_epochs", "must be a positive int")))
            .transpose()?
            .unwrap_or(300);
        if max_epochs == 0 {
            return Err(ferr("max_epochs", "must be >= 1"));
        }
        let gpus_per_session = doc
            .get("gpus_per_session")
            .and_then(|v| v.as_usize())
            .unwrap_or(1)
            .max(1);
        let max_gpus = doc
            .get("max_gpus")
            .and_then(|v| v.as_usize())
            .unwrap_or(population * gpus_per_session);
        let max_params = doc
            .get("max_params")
            .and_then(|v| v.as_i64())
            .map(|v| v as u64);
        // Seed accepts a string or a number: `to_json` writes a string
        // (JSON numbers are f64 and corrupt seeds ≥ 2^53, which would
        // silently break snapshot-restore determinism), while
        // hand-written configs keep using plain numbers.
        let seed = match doc.get("seed") {
            None => 0,
            Some(v) => match v.as_str() {
                Some(s) => s
                    .parse::<u64>()
                    .map_err(|_| ferr("seed", "string seed is not a u64"))?,
                None => v
                    .as_i64()
                    .ok_or_else(|| ferr("seed", "must be a u64 or string"))?
                    as u64,
            },
        };

        Ok(ChoptConfig {
            space,
            measure,
            order,
            step,
            population,
            tune,
            termination,
            stop_ratio,
            model,
            max_epochs,
            gpus_per_session,
            max_gpus,
            max_params,
            seed,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut doc = self.space.to_json();
        doc.set("measure", Json::Str(self.measure.clone()));
        doc.set("order", Json::Str(self.order.name().to_string()));
        doc.set("step", Json::Num(self.step as f64));
        doc.set("population", Json::Num(self.population as f64));
        let tune = match &self.tune {
            TuneAlgo::Random => Json::obj().with("random", Json::obj()),
            TuneAlgo::Pbt { exploit, explore } => Json::obj().with(
                "pbt",
                Json::obj()
                    .with("exploit", Json::Str(exploit.clone()))
                    .with("explore", Json::Str(explore.clone())),
            ),
            TuneAlgo::Hyperband { max_resource, eta } => Json::obj().with(
                "hyperband",
                Json::obj()
                    .with("max_resource", Json::Num(*max_resource as f64))
                    .with("eta", Json::Num(*eta as f64)),
            ),
            TuneAlgo::Asha {
                min_resource,
                max_resource,
                eta,
            } => Json::obj().with(
                "asha",
                Json::obj()
                    .with("min_resource", Json::Num(*min_resource as f64))
                    .with("max_resource", Json::Num(*max_resource as f64))
                    .with("eta", Json::Num(*eta as f64)),
            ),
        };
        doc.set("tune", tune);
        let mut term = Json::obj();
        if let Some(t) = self.termination.time_hours {
            term.set("time", Json::Num(t));
        }
        if let Some(n) = self.termination.max_session_number {
            term.set("max_session_number", Json::Num(n as f64));
        }
        if let Some(p) = self.termination.performance_threshold {
            term.set("performance_threshold", Json::Num(p));
        }
        doc.set("termination", term);
        doc.set("stop_ratio", Json::Num(self.stop_ratio));
        doc.set("model", Json::Str(self.model.clone()));
        doc.set("max_epochs", Json::Num(self.max_epochs as f64));
        doc.set("gpus_per_session", Json::Num(self.gpus_per_session as f64));
        doc.set("max_gpus", Json::Num(self.max_gpus as f64));
        if let Some(p) = self.max_params {
            doc.set("max_params", Json::Num(p as f64));
        }
        // String, not Num: an f64 corrupts seeds ≥ 2^53 (see from_json).
        doc.set("seed", Json::Str(self.seed.to_string()));
        doc
    }
}

fn parse_condition(c: &Json, defs: &[ParamDef]) -> Result<Condition, ConfigError> {
    let child = c
        .require("child")?
        .as_str()
        .ok_or_else(|| ferr("h_params_conditions.child", "must be a string"))?
        .to_string();
    let parent = c
        .require("parent")?
        .as_str()
        .ok_or_else(|| ferr("h_params_conditions.parent", "must be a string"))?
        .to_string();
    let values = parse_hvalues(
        c.require("values")?,
        defs,
        &parent,
        "h_params_conditions.values",
    )?;
    Ok(Condition {
        child,
        parent,
        values,
    })
}

fn parse_conjunction(c: &Json, defs: &[ParamDef]) -> Result<Conjunction, ConfigError> {
    let pairs = c
        .as_obj()
        .ok_or_else(|| ferr("h_params_conjunctions", "entries must be objects"))?;
    let mut clauses = Vec::new();
    for (name, allowed) in pairs {
        clauses.push((
            name.clone(),
            parse_hvalues(allowed, defs, name, "h_params_conjunctions")?,
        ));
    }
    Ok(Conjunction { clauses })
}

fn parse_hvalues(
    j: &Json,
    defs: &[ParamDef],
    param: &str,
    ctx: &str,
) -> Result<Vec<HValue>, ConfigError> {
    let ptype = defs
        .iter()
        .find(|d| d.name == param)
        .map(|d| d.ptype)
        .unwrap_or(crate::hparam::ParamType::Str);
    j.as_arr()
        .ok_or_else(|| ferr(ctx, "must be an array"))?
        .iter()
        .map(|v| HValue::from_json(v, ptype).ok_or_else(|| ferr(ctx, "bad value")))
        .collect()
}

fn parse_tune(j: &Json) -> Result<TuneAlgo, ConfigError> {
    let pairs = j
        .as_obj()
        .ok_or_else(|| ferr("tune", "must be an object like {'pbt': {...}}"))?;
    if pairs.len() != 1 {
        return Err(ferr("tune", "must contain exactly one algorithm"));
    }
    let (name, body) = &pairs[0];
    match name.as_str() {
        "random" => Ok(TuneAlgo::Random),
        "pbt" => Ok(TuneAlgo::Pbt {
            exploit: body
                .get("exploit")
                .and_then(|v| v.as_str())
                .unwrap_or("truncation")
                .to_string(),
            explore: body
                .get("explore")
                .and_then(|v| v.as_str())
                .unwrap_or("perturb")
                .to_string(),
        }),
        "hyperband" => Ok(TuneAlgo::Hyperband {
            max_resource: body
                .get("max_resource")
                .and_then(|v| v.as_usize())
                .unwrap_or(81),
            eta: body.get("eta").and_then(|v| v.as_usize()).unwrap_or(3).max(2),
        }),
        "asha" => Ok(TuneAlgo::Asha {
            min_resource: body
                .get("min_resource")
                .and_then(|v| v.as_usize())
                .unwrap_or(1)
                .max(1),
            max_resource: body
                .get("max_resource")
                .and_then(|v| v.as_usize())
                .unwrap_or(81),
            eta: body.get("eta").and_then(|v| v.as_usize()).unwrap_or(3).max(2),
        }),
        other => Err(ferr("tune", &format!("unknown algorithm '{other}'"))),
    }
}

fn parse_termination(j: Option<&Json>) -> Result<Termination, ConfigError> {
    let mut t = Termination::default();
    let Some(j) = j else { return Ok(t) };
    if let Some(v) = j.get("time") {
        t.time_hours = Some(
            v.as_f64()
                .ok_or_else(|| ferr("termination.time", "must be hours (number)"))?,
        );
    }
    if let Some(v) = j.get("max_session_number") {
        t.max_session_number = Some(
            v.as_usize()
                .ok_or_else(|| ferr("termination.max_session_number", "must be an int"))?,
        );
    }
    if let Some(v) = j.get("performance_threshold") {
        t.performance_threshold = Some(
            v.as_f64()
                .ok_or_else(|| ferr("termination.performance_threshold", "must be a number"))?,
        );
    }
    Ok(t)
}

// ---------------------------------------------------------------------------

/// The paper's Listing-1 example, as a ready-to-use config string (used by
/// tests, docs, and `chopt example-config`).
pub const LISTING1_EXAMPLE: &str = r#"{
  "h_params": {
    "lr": {"parameters": [0.01, 0.09], "distribution": "log_uniform",
           "type": "float", "p_range": [0.001, 0.1]},
    "depth": {"parameters": [5, 10], "distribution": "uniform", "type": "int",
              "p_range": [5, 10]},
    "activation": {"parameters": ["relu", "sigmoid"], "distribution": "categorical",
                   "type": "str", "p_range": []}
  },
  "h_params_conditions": [],
  "h_params_conjunctions": [],
  "measure": "test/accuracy",
  "order": "descending",
  "step": 5,
  "population": 5,
  "tune": {"pbt": {"exploit": "truncation", "explore": "perturb"}},
  "termination": {"max_session_number": 50}
}"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_listing1() {
        let c = ChoptConfig::from_json_str(LISTING1_EXAMPLE).unwrap();
        assert_eq!(c.measure, "test/accuracy");
        assert_eq!(c.order, Order::Descending);
        assert_eq!(c.step, 5);
        assert!(c.early_stopping_enabled());
        assert_eq!(c.population, 5);
        assert_eq!(
            c.tune,
            TuneAlgo::Pbt {
                exploit: "truncation".into(),
                explore: "perturb".into()
            }
        );
        assert_eq!(c.termination.max_session_number, Some(50));
        assert_eq!(c.space.defs.len(), 3);
        assert_eq!(c.stop_ratio, DEFAULT_STOP_RATIO);
    }

    #[test]
    fn step_minus_one_disables_early_stopping() {
        let text = LISTING1_EXAMPLE.replace("\"step\": 5", "\"step\": -1");
        let c = ChoptConfig::from_json_str(&text).unwrap();
        assert!(!c.early_stopping_enabled());
    }

    #[test]
    fn rejects_bad_step() {
        let text = LISTING1_EXAMPLE.replace("\"step\": 5", "\"step\": 0");
        assert!(ChoptConfig::from_json_str(&text).is_err());
    }

    #[test]
    fn rejects_unknown_tune() {
        let text = LISTING1_EXAMPLE.replace("\"pbt\"", "\"cma_es\"");
        assert!(ChoptConfig::from_json_str(&text).is_err());
    }

    #[test]
    fn rejects_bad_order_and_measure() {
        let t1 = LISTING1_EXAMPLE.replace("\"descending\"", "\"sideways\"");
        assert!(ChoptConfig::from_json_str(&t1).is_err());
        let t2 = LISTING1_EXAMPLE.replace("\"measure\": \"test/accuracy\",", "");
        assert!(ChoptConfig::from_json_str(&t2).is_err());
    }

    #[test]
    fn order_better() {
        assert!(Order::Descending.better(0.9, 0.8));
        assert!(Order::Ascending.better(0.1, 0.2));
        assert!(!Order::Descending.better(0.8, 0.8));
        assert_eq!(Order::Descending.worst(), f64::NEG_INFINITY);
    }

    #[test]
    fn json_roundtrip() {
        let c = ChoptConfig::from_json_str(LISTING1_EXAMPLE).unwrap();
        let j = c.to_json().to_string_pretty();
        let c2 = ChoptConfig::from_json_str(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn seed_survives_past_f64_precision() {
        // Regression: seeds ≥ 2^53 used to round-trip through Json::Num
        // (an f64) and come back rounded, silently breaking the RNG
        // stream on snapshot restore.
        let big = (1u64 << 53) + 1;
        let mut c = ChoptConfig::from_json_str(LISTING1_EXAMPLE).unwrap();
        c.seed = big;
        let text = c.to_json().to_string_pretty();
        let back = ChoptConfig::from_json_str(&text).unwrap();
        assert_eq!(back.seed, big);
        // Plain numeric seeds in hand-written configs still parse.
        let t2 = LISTING1_EXAMPLE.replace(
            "\"termination\": {\"max_session_number\": 50}",
            "\"termination\": {\"max_session_number\": 50},\n  \"seed\": 7",
        );
        assert_eq!(ChoptConfig::from_json_str(&t2).unwrap().seed, 7);
    }

    #[test]
    fn hyperband_defaults() {
        let text = LISTING1_EXAMPLE.replace(
            "\"tune\": {\"pbt\": {\"exploit\": \"truncation\", \"explore\": \"perturb\"}}",
            "\"tune\": {\"hyperband\": {}}",
        );
        let c = ChoptConfig::from_json_str(&text).unwrap();
        assert_eq!(
            c.tune,
            TuneAlgo::Hyperband {
                max_resource: 81,
                eta: 3
            }
        );
    }

    #[test]
    fn conditions_parse() {
        let text = LISTING1_EXAMPLE.replace(
            "\"h_params_conditions\": []",
            r#""h_params_conditions": [{"child": "lr", "parent": "activation", "values": ["relu"]}]"#,
        );
        let c = ChoptConfig::from_json_str(&text).unwrap();
        assert_eq!(c.space.conditions.len(), 1);
        assert_eq!(c.space.conditions[0].child, "lr");
    }

    #[test]
    fn conjunctions_parse() {
        let text = LISTING1_EXAMPLE.replace(
            "\"h_params_conjunctions\": []",
            r#""h_params_conjunctions": [{"activation": ["relu"], "depth": [5, 6]}]"#,
        );
        let c = ChoptConfig::from_json_str(&text).unwrap();
        assert_eq!(c.space.conjunctions.len(), 1);
        assert_eq!(c.space.conjunctions[0].clauses.len(), 2);
    }

    #[test]
    fn defaults_for_platform_fields() {
        let c = ChoptConfig::from_json_str(LISTING1_EXAMPLE).unwrap();
        assert_eq!(c.max_epochs, 300);
        assert_eq!(c.gpus_per_session, 1);
        assert_eq!(c.max_gpus, 5);
        assert_eq!(c.model, "surrogate:resnet");
    }
}
