//! Surrogate trainer: parametric learning curves in virtual time.
//!
//! Substitutes real CIFAR-100 / SQuAD training for the paper's
//! cluster-scale experiments (DESIGN.md §Substitutions item 3).  The
//! response surface is calibrated so that
//!
//! * the *reference* (human-tuned) configurations land near the paper's
//!   Table-2 reference numbers, and well-tuned configurations land near
//!   the CHOPT numbers (who-wins shape, not absolute-value claims);
//! * deeper models start slower but end higher (delay and time-constant
//!   grow with depth, final accuracy grows with log-depth) — the exact
//!   structure that makes naive early stopping prune deep models (Fig. 2)
//!   and makes step size trade GPU-time for accuracy (Table 4);
//! * parameter count follows `13036 · depth · widen²`, which reproduces
//!   the paper's Table-3 sizes (WRN-28-10 → 36.5M, the unconstrained
//!   172.07M ↔ depth 132 × widen 10).
//!
//! All randomness (per-session luck, per-epoch jitter) is deterministic in
//! (session id, epoch), so sim runs are exactly reproducible.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::hparam::Assignment;
use crate::nsml::SessionId;
use crate::util::rng::Rng;

use super::{EpochResult, Trainer};

/// Model family behind a `surrogate:<family>` selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Resnet,
    Wrn,
    ResnetRe,
    WrnRe,
    Bidaf,
}

impl Family {
    pub fn parse(model: &str) -> Result<Family> {
        let name = model.strip_prefix("surrogate:").unwrap_or(model);
        match name {
            "resnet" => Ok(Family::Resnet),
            "wrn" => Ok(Family::Wrn),
            "resnet_re" => Ok(Family::ResnetRe),
            "wrn_re" => Ok(Family::WrnRe),
            "bidaf" => Ok(Family::Bidaf),
            other => Err(anyhow!("unknown surrogate family '{other}'")),
        }
    }

    fn base(self) -> f64 {
        match self {
            Family::Resnet | Family::ResnetRe => 75.0,
            Family::Wrn | Family::WrnRe => 76.4,
            Family::Bidaf => 76.5,
        }
    }

    fn has_re(self) -> bool {
        matches!(self, Family::ResnetRe | Family::WrnRe)
    }

    fn lr_opt(self) -> f64 {
        match self {
            Family::Bidaf => 0.001,
            _ => 0.05,
        }
    }

    fn default_depth(self) -> f64 {
        match self {
            Family::Resnet | Family::ResnetRe => 20.0,
            Family::Wrn | Family::WrnRe => 28.0,
            Family::Bidaf => 1.0,
        }
    }

    fn default_widen(self) -> f64 {
        match self {
            Family::Wrn | Family::WrnRe => 10.0,
            _ => 1.0,
        }
    }
}

/// Gaussian quality kernel in [0, 1]; 1 at the optimum.
fn bump(x: f64, opt: f64, sigma: f64) -> f64 {
    (-((x - opt) * (x - opt)) / (2.0 * sigma * sigma)).exp()
}

/// The resolved hyperparameters of one configuration.
#[derive(Debug, Clone, Copy)]
pub struct Resolved {
    pub depth: f64,
    pub widen: f64,
    pub lr: f64,
    pub momentum: f64,
    pub prob: f64,
    pub sh: f64,
    pub dropout: f64,
}

pub fn resolve(family: Family, hp: &Assignment) -> Resolved {
    Resolved {
        depth: hp.f64("depth").unwrap_or(family.default_depth()).max(1.0),
        widen: hp.f64("widen").unwrap_or(family.default_widen()).max(1.0),
        lr: hp.f64("lr").unwrap_or(family.lr_opt()).max(1e-8),
        momentum: hp.f64("momentum").unwrap_or(0.9),
        prob: hp.f64("prob").unwrap_or(0.0),
        sh: hp.f64("sh").unwrap_or(0.4),
        dropout: hp.f64("dropout").unwrap_or(0.2),
    }
}

/// Asymptotic accuracy (%) for a configuration, before luck/jitter.
pub fn final_accuracy(family: Family, r: &Resolved) -> f64 {
    let lr_q = bump(r.lr.ln(), family.lr_opt().ln(), 3.0f64.ln());
    let mom_q = bump(r.momentum, 0.92, 0.08);
    let mut acc = family.base() + 4.0 * (lr_q - 1.0) + 2.0 * (mom_q - 1.0);
    match family {
        Family::Bidaf => {
            let d_q = bump(r.dropout, 0.2, 0.15);
            acc += 1.5 * d_q;
        }
        _ => {
            acc += 2.5 * (r.depth / 20.0).ln() / 7.0f64.ln();
            acc += 5.5 * r.widen.ln() / 10.0f64.ln();
            if family.has_re() && r.prob > 0.0 {
                let p_q = bump(r.prob, 0.3, 0.15);
                let s_q = bump(r.sh, 0.28, 0.10);
                acc += 0.8 + 1.2 * p_q * s_q;
            }
        }
    }
    acc.clamp(1.0, 99.9)
}

/// Saturation of the learning curve at epoch `e` for a given depth:
/// deeper ⇒ later start (`delay`) and slower rise (`tau`).
pub fn saturation(e: f64, depth: f64) -> f64 {
    let delay = 0.04 * depth;
    let tau = 12.0 + 0.35 * depth;
    1.0 - (-((e - delay).max(0.0)) / tau).exp()
}

#[derive(Debug, Clone)]
struct State {
    epochs: usize,
    /// Per-session fixed offset (draws once per session).
    luck: f64,
    /// EMA of configuration quality — path dependence for PBT schedules.
    qual_ema: f64,
    seeded: bool,
}

/// The surrogate trainer.
pub struct SurrogateTrainer {
    states: HashMap<SessionId, State>,
    /// Global seed mixed into per-session streams.
    pub seed: u64,
    /// Per-session luck std in accuracy points.
    pub luck_std: f64,
    /// Per-epoch measurement jitter std.
    pub jitter_std: f64,
}

/// The trainer factory the single-study CLI surfaces share (`chopt
/// watch`, `watch --restore`, `serve --live --config`, `serve --store`
/// on a watch-style run directory).  Restore-by-replay requires the
/// factory the original run used, so every entry point that may restore
/// another's snapshot must resolve to this one definition.
pub fn default_factory(id: u64) -> Box<dyn Trainer> {
    Box::new(SurrogateTrainer::new(id))
}

/// The multi-study twin of [`default_factory`] (`chopt multi`,
/// `multi --restore`, `serve --live --manifest`, `serve --store` on a
/// multi-study run directory): one decorrelated surrogate stream per
/// (study, chopt id).  Multi-study trainers are `Send` so the scheduler
/// can step independent studies on worker threads.
pub fn default_multi_factory(study: usize, id: u64) -> Box<dyn Trainer + Send> {
    Box::new(SurrogateTrainer::new(((study as u64 + 1) << 16) ^ id))
}

impl SurrogateTrainer {
    pub fn new(seed: u64) -> SurrogateTrainer {
        SurrogateTrainer {
            states: HashMap::new(),
            seed,
            luck_std: 0.25,
            jitter_std: 0.15,
        }
    }

    fn state_mut(&mut self, id: SessionId) -> &mut State {
        self.states.entry(id).or_insert(State {
            epochs: 0,
            luck: 0.0,
            qual_ema: 0.0,
            seeded: false,
        })
    }

    fn measure_at(&self, id: SessionId, family: Family, r: &Resolved, epoch: usize, st: &State) -> (f64, f64) {
        let fin = final_accuracy(family, r);
        // Blend instantaneous quality with the trajectory EMA so PBT
        // schedules (good-late after bad-early) don't get full credit.
        let fin_eff = 0.7 * fin + 0.3 * (family.base() + st.qual_ema);
        let sat = saturation(epoch as f64, r.depth);
        let mut jrng = Rng::new(
            self.seed
                ^ id.0.wrapping_mul(0xA24B_AED4_963E_E407)
                ^ (epoch as u64).wrapping_mul(0x9FB2_1C65_1E98_DF25),
        );
        let jitter = jrng.normal() * self.jitter_std;
        let acc = (fin_eff * sat + st.luck + jitter).clamp(0.5, 99.9);
        // Loss decays toward a floor set by configuration quality.
        let floor = 0.05 + (99.9 - fin) * 0.02;
        let loss = (4.6 * (1.0 - sat) + floor + jitter.abs() * 0.02).max(0.01);
        (acc, loss)
    }
}

impl Trainer for SurrogateTrainer {
    fn train(
        &mut self,
        id: SessionId,
        model: &str,
        hparams: &Assignment,
        to_epoch: usize,
    ) -> Result<EpochResult> {
        let family = Family::parse(model)?;
        let r = resolve(family, hparams);
        let seed = self.seed;
        let st = self.state_mut(id);
        if !st.seeded {
            let mut rng = Rng::new(seed ^ id.0.wrapping_mul(0xD6E8_FEB8_6659_FD93));
            st.luck = rng.normal() * 0.25;
            st.qual_ema = final_accuracy(family, &r) - family.base();
            st.seeded = true;
        }
        let from = st.epochs;
        let to = to_epoch.max(from);
        // Quality EMA advances once per trained epoch.
        let q_now = final_accuracy(family, &r) - family.base();
        for _ in from..to {
            st.qual_ema = 0.98 * st.qual_ema + 0.02 * q_now;
        }
        st.epochs = to;
        let st = st.clone();
        let (measure, loss) = self.measure_at(id, family, &r, to, &st);
        Ok(EpochResult { measure, loss })
    }

    fn clone_state(&mut self, src: SessionId, dst: SessionId) -> Result<()> {
        let s = self
            .states
            .get(&src)
            .ok_or_else(|| anyhow!("clone_state: no state for {src}"))?
            .clone();
        // The clone inherits weights (epochs + trajectory) but rolls its
        // own luck, like re-initializing data order on a copied checkpoint.
        let mut rng = Rng::new(self.seed ^ dst.0.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        let luck = rng.normal() * self.luck_std;
        self.states.insert(
            dst,
            State {
                epochs: s.epochs,
                luck,
                qual_ema: s.qual_ema,
                seeded: true,
            },
        );
        Ok(())
    }

    fn drop_state(&mut self, id: SessionId) {
        self.states.remove(&id);
    }

    fn epochs_done(&self, id: SessionId) -> usize {
        self.states.get(&id).map(|s| s.epochs).unwrap_or(0)
    }

    fn epoch_seconds(&self, model: &str, hparams: &Assignment) -> f64 {
        let family = Family::parse(model).unwrap_or(Family::Resnet);
        let r = resolve(family, hparams);
        match family {
            Family::Bidaf => 45.0,
            // Compute scales ~linearly with depth and ~w^0.75 with width
            // (wider layers amortize better): depth 20/w1 ≈ 60 s/epoch.
            _ => 60.0 * (r.depth / 20.0).powf(0.9) * r.widen.powf(0.75),
        }
    }

    fn param_count(&self, model: &str, hparams: &Assignment) -> u64 {
        let family = Family::parse(model).unwrap_or(Family::Resnet);
        let r = resolve(family, hparams);
        match family {
            Family::Bidaf => 2_695_851, // BiDAF-ish scale marker
            _ => (13036.0 * r.depth * r.widen * r.widen) as u64,
        }
    }

    fn state_count(&self) -> usize {
        self.states.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hparam::Value;

    fn hp(pairs: &[(&str, f64)]) -> Assignment {
        let mut a = Assignment::new();
        for (k, v) in pairs {
            a.set(k, Value::Float(*v));
        }
        a
    }

    #[test]
    fn family_parsing() {
        assert_eq!(Family::parse("surrogate:wrn_re").unwrap(), Family::WrnRe);
        assert_eq!(Family::parse("resnet").unwrap(), Family::Resnet);
        assert!(Family::parse("surrogate:alexnet").is_err());
    }

    #[test]
    fn reference_configs_near_paper_table2() {
        // Human-tuned reference configs (paper Table 2 left column).
        let resnet_ref = resolve(
            Family::Resnet,
            &hp(&[("depth", 110.0), ("lr", 0.1), ("momentum", 0.9)]),
        );
        let a = final_accuracy(Family::Resnet, &resnet_ref);
        assert!((a - 76.27).abs() < 1.0, "resnet ref {a} vs 76.27");

        let wrn_ref = resolve(
            Family::Wrn,
            &hp(&[("depth", 28.0), ("widen", 10.0), ("lr", 0.1), ("momentum", 0.9)]),
        );
        let w = final_accuracy(Family::Wrn, &wrn_ref);
        assert!((w - 81.51).abs() < 1.2, "wrn ref {w} vs 81.51");

        // Tuned configs beat the references (the paper's headline claim).
        let resnet_tuned = resolve(
            Family::Resnet,
            &hp(&[("depth", 140.0), ("lr", 0.05), ("momentum", 0.92)]),
        );
        assert!(final_accuracy(Family::Resnet, &resnet_tuned) > a);
    }

    #[test]
    fn re_helps_when_tuned() {
        let base = resolve(Family::ResnetRe, &hp(&[("prob", 0.0)]));
        let tuned = resolve(Family::ResnetRe, &hp(&[("prob", 0.3), ("sh", 0.28)]));
        let bad = resolve(Family::ResnetRe, &hp(&[("prob", 0.95), ("sh", 0.9)]));
        let a0 = final_accuracy(Family::ResnetRe, &base);
        let a1 = final_accuracy(Family::ResnetRe, &tuned);
        let a2 = final_accuracy(Family::ResnetRe, &bad);
        assert!(a1 > a0 + 1.5, "tuned RE should add ~2: {a0} -> {a1}");
        assert!(a2 > a0 && a2 < a1, "bad RE between: {a0} < {a2} < {a1}");
    }

    #[test]
    fn deep_models_start_slow_end_high() {
        // The Fig. 2 phenomenon.
        let shallow = saturation(7.0, 20.0);
        let deep = saturation(7.0, 140.0);
        assert!(
            shallow > 4.0 * deep,
            "early: shallow {shallow} vs deep {deep}"
        );
        assert!(saturation(300.0, 140.0) > 0.99 * saturation(300.0, 20.0) - 0.02);
        let f_shallow = final_accuracy(Family::Resnet, &resolve(Family::Resnet, &hp(&[("depth", 20.0)])));
        let f_deep = final_accuracy(Family::Resnet, &resolve(Family::Resnet, &hp(&[("depth", 140.0)])));
        assert!(f_deep > f_shallow + 2.0);
    }

    #[test]
    fn param_count_matches_table3() {
        let t = SurrogateTrainer::new(0);
        let wrn2810 = t.param_count("surrogate:wrn_re", &hp(&[("depth", 28.0), ("widen", 10.0)]));
        assert!((wrn2810 as f64 - 36.5e6).abs() < 0.2e6, "got {wrn2810}");
        let big = t.param_count("surrogate:wrn_re", &hp(&[("depth", 132.0), ("widen", 10.0)]));
        assert!((big as f64 - 172.07e6).abs() < 0.2e6, "got {big}");
    }

    #[test]
    fn train_is_deterministic_and_monotone_epochs() {
        let mut t1 = SurrogateTrainer::new(7);
        let mut t2 = SurrogateTrainer::new(7);
        let hp = hp(&[("depth", 20.0), ("lr", 0.05)]);
        let r1 = t1.train(SessionId(1), "surrogate:resnet", &hp, 10).unwrap();
        let r2 = t2.train(SessionId(1), "surrogate:resnet", &hp, 10).unwrap();
        assert_eq!(r1, r2);
        assert_eq!(t1.epochs_done(SessionId(1)), 10);
        // Accuracy grows with epochs (on average; check well-separated).
        let late = t1.train(SessionId(1), "surrogate:resnet", &hp, 200).unwrap();
        assert!(late.measure > r1.measure + 5.0);
        assert!(late.loss < r1.loss);
    }

    #[test]
    fn clone_state_inherits_progress() {
        let mut t = SurrogateTrainer::new(3);
        let hp = hp(&[("depth", 20.0)]);
        t.train(SessionId(1), "surrogate:resnet", &hp, 50).unwrap();
        t.clone_state(SessionId(1), SessionId(2)).unwrap();
        assert_eq!(t.epochs_done(SessionId(2)), 50);
        assert_eq!(t.state_count(), 2);
        t.drop_state(SessionId(1));
        assert_eq!(t.state_count(), 1);
        assert!(t.clone_state(SessionId(1), SessionId(3)).is_err());
    }

    #[test]
    fn epoch_seconds_scale_with_size() {
        let t = SurrogateTrainer::new(0);
        let small = t.epoch_seconds("surrogate:resnet", &hp(&[("depth", 20.0)]));
        let deep = t.epoch_seconds("surrogate:resnet", &hp(&[("depth", 140.0)]));
        assert!(deep > 4.0 * small, "{small} vs {deep}");
        assert!((small - 60.0).abs() < 1.0);
    }
}
