//! Trainers: turn "train this NSML session for k epochs" into metrics.
//!
//! Two implementations behind one trait:
//!
//! * [`surrogate::SurrogateTrainer`] — parametric learning curves in
//!   virtual time, for the paper's cluster-scale experiments (hundreds of
//!   models × 300 epochs; see DESIGN.md §Substitutions item 3).
//! * `RealTrainer` (in the `chopt` facade crate, `chopt::trainer::real`)
//!   — the AOT PJRT path: executes the compiled `train_step`/`eval_step`
//!   artifacts on synthetic data, holding model state per session (the
//!   end-to-end examples use this).  It lives outside `chopt-core` so
//!   this crate stays free of the PJRT runtime dependency.
//!
//! Trainers own all model state keyed by [`SessionId`], so PBT's exploit
//! (weight copy) and the dead pool's GC are trainer operations.

pub mod surrogate;

use crate::hparam::Assignment;
use crate::nsml::SessionId;

/// Metrics from one training interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochResult {
    /// Objective measure at the end of the interval (e.g. test accuracy).
    pub measure: f64,
    /// Training loss at the end of the interval.
    pub loss: f64,
}

/// The trainer interface the coordinator drives.
///
/// Deliberately not `Send`: the real trainer wraps a PJRT client (raw C
/// pointers).  Agent threads construct their own trainer instance inside
/// the thread instead of sharing one.
pub trait Trainer {
    /// Train `id` (model `model`, hyperparameters `hparams`) from its
    /// current epoch up to `to_epoch`. Creates state on first call.
    fn train(
        &mut self,
        id: SessionId,
        model: &str,
        hparams: &Assignment,
        to_epoch: usize,
    ) -> anyhow::Result<EpochResult>;

    /// Copy model state (weights) from `src` into `dst` (PBT exploit).
    fn clone_state(&mut self, src: SessionId, dst: SessionId) -> anyhow::Result<()>;

    /// Discard state (dead-pool GC). Idempotent.
    fn drop_state(&mut self, id: SessionId);

    /// Epochs of training already materialized for `id`.
    fn epochs_done(&self, id: SessionId) -> usize;

    /// Virtual seconds one epoch takes on one GPU (sim-time + GPU-hours
    /// accounting; for the real trainer this is measured wall time).
    fn epoch_seconds(&self, model: &str, hparams: &Assignment) -> f64;

    /// Trainable-parameter count of this configuration (Table 3).
    fn param_count(&self, model: &str, hparams: &Assignment) -> u64;

    /// Number of sessions with live state (storage accounting).
    fn state_count(&self) -> usize;
}
