//! Canned experiment setups shared by the paper-reproduction benches
//! (`rust/benches/`), the examples, and the integration tests.
//!
//! Each function returns the configuration(s) for one table/figure of the
//! paper's evaluation; the bench binaries run them and print the same
//! rows the paper reports.  See DESIGN.md §Experiment-index.

use crate::config::ChoptConfig;
use crate::hparam::{Assignment, Value};

/// Model families of Table 2 with their paper-reported numbers.
#[derive(Debug, Clone, Copy)]
pub struct Table2Row {
    pub task: &'static str,
    pub label: &'static str,
    pub family: &'static str,
    pub paper_reference: f64,
    pub paper_chopt: f64,
}

pub const TABLE2_ROWS: [Table2Row; 5] = [
    Table2Row {
        task: "IC",
        label: "ResNet",
        family: "surrogate:resnet",
        paper_reference: 76.27,
        paper_chopt: 77.75,
    },
    Table2Row {
        task: "IC",
        label: "WRN",
        family: "surrogate:wrn",
        paper_reference: 81.51,
        paper_chopt: 81.66,
    },
    Table2Row {
        task: "IC",
        label: "ResNet with RE",
        family: "surrogate:resnet_re",
        paper_reference: 77.9,
        paper_chopt: 79.45,
    },
    Table2Row {
        task: "IC",
        label: "WRN with RE",
        family: "surrogate:wrn_re",
        paper_reference: 82.27,
        paper_chopt: 83.1,
    },
    Table2Row {
        task: "QA",
        label: "BiDAF",
        family: "surrogate:bidaf",
        paper_reference: 77.3,
        paper_chopt: 77.93,
    },
];

/// The human-tuned reference configuration per family (the paper's
/// "REFERENCES" column: the authors' published hyperparameters).
pub fn reference_assignment(family: &str) -> Assignment {
    let mut a = Assignment::new();
    match family {
        "surrogate:resnet" => {
            a.set("depth", Value::Float(110.0));
            a.set("lr", Value::Float(0.1));
            a.set("momentum", Value::Float(0.9));
        }
        "surrogate:wrn" => {
            a.set("depth", Value::Float(28.0));
            a.set("widen", Value::Float(10.0));
            a.set("lr", Value::Float(0.1));
            a.set("momentum", Value::Float(0.9));
        }
        "surrogate:resnet_re" => {
            a.set("depth", Value::Float(110.0));
            a.set("lr", Value::Float(0.1));
            a.set("momentum", Value::Float(0.9));
            a.set("prob", Value::Float(0.5));
            a.set("sh", Value::Float(0.4));
        }
        "surrogate:wrn_re" => {
            a.set("depth", Value::Float(28.0));
            a.set("widen", Value::Float(10.0));
            a.set("lr", Value::Float(0.1));
            a.set("momentum", Value::Float(0.9));
            a.set("prob", Value::Float(0.5));
            a.set("sh", Value::Float(0.4));
        }
        "surrogate:bidaf" => {
            a.set("lr", Value::Float(0.001));
            a.set("momentum", Value::Float(0.9));
            a.set("dropout", Value::Float(0.1));
        }
        other => panic!("unknown family {other}"),
    }
    a
}

/// Search-space config for one Table-2 family.
///
/// `tune` is a tune-section JSON fragment, e.g. `{"pbt": {...}}`.
pub fn table2_config(family: &str, tune: &str, max_sessions: usize, seed: u64) -> ChoptConfig {
    let (hparams, measure) = match family {
        "surrogate:bidaf" => (
            r#"
            "lr": {"parameters": [0.0002, 0.005], "distribution": "log_uniform",
                   "type": "float", "p_range": [0.0001, 0.01]},
            "momentum": {"parameters": [0.5, 0.99], "distribution": "uniform",
                   "type": "float", "p_range": [0.0, 0.999]},
            "dropout": {"parameters": [0.0, 0.5], "distribution": "uniform",
                   "type": "float", "p_range": [0.0, 0.7]}"#,
            "test/em",
        ),
        fam => {
            let has_widen = fam.contains("wrn");
            let has_re = fam.ends_with("_re");
            let mut s = String::from(
                r#"
            "lr": {"parameters": [0.01, 0.2], "distribution": "log_uniform",
                   "type": "float", "p_range": [0.001, 0.5]},
            "momentum": {"parameters": [0.5, 0.99], "distribution": "uniform",
                   "type": "float", "p_range": [0.0, 0.999]},
            "depth": {"parameters": [20, 140], "distribution": "uniform",
                   "type": "int", "p_range": [14, 160]}"#,
            );
            if has_widen {
                s.push_str(
                    r#",
            "widen": {"parameters": [4, 12], "distribution": "uniform",
                   "type": "int", "p_range": [1, 14]}"#,
                );
            }
            if has_re {
                s.push_str(
                    r#",
            "prob": {"parameters": [0.0, 0.9], "distribution": "uniform",
                   "type": "float", "p_range": [0.0, 1.0]},
            "sh": {"parameters": [0.1, 0.9], "distribution": "uniform",
                   "type": "float", "p_range": [0.02, 1.0]}"#,
                );
            }
            (Box::leak(s.into_boxed_str()) as &str, "test/accuracy")
        }
    };
    let text = format!(
        r#"{{
          "h_params": {{{hparams}}},
          "measure": "{measure}",
          "order": "descending",
          "step": 10,
          "population": 8,
          "tune": {tune},
          "termination": {{"max_session_number": {max_sessions}}},
          "model": "{family}",
          "max_epochs": 300,
          "max_gpus": 8,
          "seed": {seed}
        }}"#
    );
    ChoptConfig::from_json_str(&text).unwrap()
}

/// Table-4 config: ResNet+RE, 200 models, 300 epochs, given ES step.
pub fn table4_config(step: i64, tune: &str, seed: u64) -> ChoptConfig {
    let mut cfg = table2_config("surrogate:resnet_re", tune, 200, seed);
    cfg.step = step;
    cfg
}

/// Fig-2 config: depth-heavy random search with step-7 early stopping.
pub fn fig2_config(step: i64, max_sessions: usize, seed: u64) -> ChoptConfig {
    let mut cfg = table2_config("surrogate:resnet", "{\"random\": {}}", max_sessions, seed);
    cfg.step = step;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::surrogate::{final_accuracy, resolve, Family};

    #[test]
    fn reference_assignments_land_near_paper_numbers() {
        // The calibration contract: reference configs within ~1.5 points
        // of the paper's reference column (shape, not absolute, is the
        // claim — but the surrogate is calibrated to be close).
        for row in TABLE2_ROWS {
            let fam = Family::parse(row.family).unwrap();
            let hp = reference_assignment(row.family);
            let acc = final_accuracy(fam, &resolve(fam, &hp));
            assert!(
                (acc - row.paper_reference).abs() < 1.6,
                "{}: surrogate ref {acc:.2} vs paper {}",
                row.label,
                row.paper_reference
            );
        }
    }

    #[test]
    fn table2_configs_valid() {
        for row in TABLE2_ROWS {
            let cfg = table2_config(row.family, "{\"random\": {}}", 10, 1);
            cfg.space.validate().unwrap();
            assert_eq!(cfg.model, row.family);
        }
    }

    #[test]
    fn table4_step_override() {
        assert_eq!(table4_config(-1, "{\"random\": {}}", 1).step, -1);
        assert_eq!(table4_config(25, "{\"random\": {}}", 1).step, 25);
        assert_eq!(
            table4_config(25, "{\"random\": {}}", 1)
                .termination
                .max_session_number,
            Some(200)
        );
    }
}
