//! Leveled logger substrate (vendor set has `log` but no emitter; this is
//! both).  Thread-safe, level-filtered via `CHOPT_LOG` env or code, with
//! elapsed-since-start timestamps — convenient when correlating with the
//! virtual clock in sim runs.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::LazyLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static START: LazyLock<Instant> = LazyLock::new(Instant::now);
static MAX_LEVEL: LazyLock<AtomicU8> = LazyLock::new(|| {
    let lvl = std::env::var("CHOPT_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info);
    AtomicU8::new(lvl as u8)
});

/// Set the global level programmatically (tests, CLI `--log-level`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record; prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, msg: &str) {
    if !level_enabled(level) {
        return;
    }
    let t = START.elapsed();
    let line = format!(
        "[{:>9.3}s {:5} {}] {}\n",
        t.as_secs_f64(),
        level.name(),
        target,
        msg
    );
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(line.as_bytes());
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parse() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Warn);
        assert!(level_enabled(Level::Error));
        assert!(level_enabled(Level::Warn));
        assert!(!level_enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
