//! Deterministic PRNG substrate (no `rand` in the vendor set).
//!
//! [`Rng`] is xoshiro256** seeded through SplitMix64 — the standard
//! combination: SplitMix64 decorrelates arbitrary user seeds, xoshiro
//! provides the long-period stream.  Everything in CHOPT that samples
//! (hyperparameter spaces, PBT perturbation, Stop-and-Go's random
//! stop/dead split, workload traces, synthetic data) goes through this
//! type, so runs are reproducible from a single seed.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 (SplitMix64-expanded).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent child stream (for per-session RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Log-uniform in [lo, hi); requires 0 < lo <= hi.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi >= lo, "log_uniform needs 0 < lo <= hi");
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Standard normal (Box–Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Uniform integer in [lo, hi] inclusive (Lemire-style rejection-free
    /// for our scale; modulo bias is negligible for ranges << 2^64 but we
    /// reject anyway for exactness).
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // Full u64 span: any value works.
            return self.next_u64() as i64;
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    /// Uniform index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.int_range(0, n as i64 - 1) as usize
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// Categorical draw from (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform(10.0, 20.0)).sum::<f64>() / n as f64;
        assert!((mean - 15.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn log_uniform_bounds_and_shape() {
        let mut r = Rng::new(3);
        let mut below_geo_mean = 0;
        let n = 20_000;
        let geo_mean = (0.001f64 * 0.1).sqrt();
        for _ in 0..n {
            let v = r.log_uniform(0.001, 0.1);
            assert!((0.001..0.1).contains(&v));
            if v < geo_mean {
                below_geo_mean += 1;
            }
        }
        // Log-uniform: geometric mean is the median.
        let frac = below_geo_mean as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn int_range_covers_all() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.int_range(5, 14);
            assert!((5..=14).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "f2={f2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        for _ in 0..100 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
        // k > n clamps.
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(9);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..50).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
