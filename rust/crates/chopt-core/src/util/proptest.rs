//! Property-testing harness (the vendor set has no `proptest`).
//!
//! Seeded random-input generation with failure shrinking over a scalar
//! "size" knob: when a case fails, the harness retries with progressively
//! smaller sizes to report a minimal-ish reproduction, and always prints
//! the failing seed so the case can be replayed exactly.
//!
//! Used by the coordinator/cluster invariant tests (GPU conservation,
//! pool-transition legality, tuner budget accounting).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" passed to the generator (e.g. number of events).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xC0FFEE,
            max_size: 64,
        }
    }
}

/// Outcome of a failed property with its reproduction info.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop(rng, size)` for `cfg.cases` random (seed, size) pairs.
///
/// `prop` returns `Err(msg)` to signal a violated invariant. On failure the
/// harness shrinks `size` toward 1 (halving) while the failure reproduces,
/// then panics with the smallest reproduction found.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        // Sizes sweep small -> large so early failures are already small.
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            let shrunk = shrink(&mut prop, case_seed, size, msg);
            panic!(
                "property '{name}' failed: {}\n  reproduce with seed={} size={}",
                shrunk.message, shrunk.seed, shrunk.size
            );
        }
    }
}

fn shrink<F>(prop: &mut F, seed: u64, size: usize, first_msg: String) -> Failure
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut best = Failure {
        seed,
        size,
        message: first_msg,
    };
    let mut s = size;
    while s > 1 {
        s /= 2;
        let mut rng = Rng::new(seed);
        match prop(&mut rng, s) {
            Err(msg) => {
                best = Failure {
                    seed,
                    size: s,
                    message: msg,
                };
            }
            Ok(()) => break, // smaller size passes; stop shrinking
        }
    }
    best
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", Config::default(), |rng, size| {
            let xs: Vec<i64> = (0..size).map(|_| rng.int_range(-100, 100)).collect();
            let a: i64 = xs.iter().sum();
            let b: i64 = xs.iter().rev().sum();
            prop_assert!(a == b, "sum not commutative: {a} vs {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_repro() {
        check(
            "always-fails",
            Config {
                cases: 4,
                ..Config::default()
            },
            |_rng, size| Err(format!("boom at size {size}")),
        );
    }

    #[test]
    fn shrink_reduces_size() {
        // Fails whenever size >= 4; shrink should land at 4's neighborhood.
        let mut calls = Vec::new();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(
                "ge4",
                Config {
                    cases: 16,
                    max_size: 64,
                    seed: 9,
                },
                |_rng, size| {
                    calls.push(size);
                    if size >= 4 {
                        Err(format!("size {size} >= 4"))
                    } else {
                        Ok(())
                    }
                },
            )
        }));
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("size="), "panic message should carry repro: {msg}");
    }
}
