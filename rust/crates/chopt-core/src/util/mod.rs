//! Foundational substrates built in-repo (the offline vendor set has no
//! serde_json / rand / clap / criterion / proptest — see DESIGN.md
//! §Offline-vendor substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
