//! Small statistics substrate: summaries, percentiles, online accumulation,
//! and time-series helpers used by metrics, benches, and the viz tool.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice (q in [0,1]).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Welford online mean/variance accumulator (single pass, numerically
/// stable; used by utilization tracking where samples stream in).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponential moving average (smoothing for utilization control loops).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// `alpha` in (0, 1]: weight of the newest sample.
    pub fn new(alpha: f64) -> Ema {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Pearson correlation between two equal-length samples (parameter
/// analytic view of the viz tool). Returns 0 for degenerate inputs.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx.sqrt() * syy.sqrt())
    }
}

/// Histogram with fixed equal-width bins (viz parameter distributions).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn build(xs: &[f64], bins: usize) -> Histogram {
        assert!(bins > 0);
        let (lo, hi) = xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
        let (lo, hi) = if xs.is_empty() || lo >= hi {
            (0.0, 1.0)
        } else {
            (lo, hi)
        };
        let mut counts = vec![0u64; bins];
        for &x in xs {
            let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
            let idx = ((t * bins as f64) as usize).min(bins - 1);
            counts[idx] += 1;
        }
        Histogram { lo, hi, counts }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 4.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        e.push(0.0);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
        assert_eq!(pearson(&xs, &vec![3.0; 20]), 0.0);
    }

    #[test]
    fn histogram_bins() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let h = Histogram::build(&xs, 10);
        assert_eq!(h.total(), 100);
        assert!(h.counts.iter().all(|&c| c == 10));
        // Degenerate input.
        let h2 = Histogram::build(&[], 4);
        assert_eq!(h2.total(), 0);
        let h3 = Histogram::build(&[5.0, 5.0], 4);
        assert_eq!(h3.total(), 2);
    }
}
